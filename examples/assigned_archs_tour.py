"""Tour of the 10 assigned architectures (--arch selectable configs).

For each arch: print the exact full config + parameter counts, then run one
forward and a short greedy decode on the REDUCED smoke variant (CPU). The
FULL configs are exercised compile-only by `repro.launch.dryrun`.

    PYTHONPATH=src python examples/assigned_archs_tour.py [--arch <id>]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ASSIGNED, get_config, smoke
from repro.models import Model


def tour(arch: str):
    full = get_config(arch)
    cfg = smoke(arch)
    print(f"== {arch} [{full.arch_type}]  ({full.source})")
    print(f"   full: L={full.num_layers} d={full.d_model} "
          f"H={full.num_heads}/kv{full.num_kv_heads} ff={full.d_ff} "
          f"V={full.vocab_size} params={full.param_count()/1e9:.2f}B "
          f"active={full.active_param_count()/1e9:.2f}B")
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    b, s = 1, 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    if cfg.multimodal:
        embeds = jnp.asarray(rng.normal(size=(b, s, cfg.d_model)),
                             jnp.float32)
        _, cache = model.prefill(params, embeds=embeds, max_len=48)
        print("   frontend stub: prefill over precomputed "
              f"{'patch' if cfg.arch_type == 'vlm' else 'frame'} embeddings")
    else:
        _, cache = model.prefill(params, tokens=toks, max_len=48)
    cur, pos, out = int(toks[0, -1]), s, []
    for _ in range(8):
        logits, cache, _ = model.decode_step(params, jnp.array([cur]), cache,
                                             jnp.array([pos]))
        cur = int(jnp.argmax(logits[0]))
        out.append(cur)
        pos += 1
    state_kind = []
    if cfg.uses_attention:
        state_kind.append(f"KV cache[{cache['k'].shape[2]}]")
    if cfg.uses_ssm:
        state_kind.append(f"SSD state[{cfg.ssm_heads}x{cfg.ssm_head_dim}"
                          f"x{cfg.ssm_state}]")
    print(f"   smoke decode ok: tokens={out}  state: {', '.join(state_kind)}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=[None] + ASSIGNED)
    args = ap.parse_args()
    for arch in ([args.arch] if args.arch else ASSIGNED):
        tour(arch)


if __name__ == "__main__":
    main()
