"""Serve the trained reasoner with SART and watch the two mechanisms work.

Run examples/train_tiny_reasoner.py first (or point --ckpt elsewhere).

    PYTHONPATH=src python examples/serve_reasoning.py --policy sart --n 8
"""
import argparse
import json

from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="sart")
    ap.add_argument("--n", type=int, default=8)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--ckpt", default="checkpoints/reasoner")
    ap.add_argument("--prm", default="head", choices=["oracle", "head"])
    args = ap.parse_args()
    out = serve(policy=args.policy, n=args.n, num_requests=args.requests,
                rate_gap=8, ckpt=args.ckpt, prm_kind=args.prm, window=8,
                max_tokens=96, max_slots=16, seed=0, temperature=0.9)
    print(json.dumps(out, indent=2))


if __name__ == "__main__":
    main()
