"""Quickstart: the SART serving loop in ~40 lines.

Builds a tiny reasoner (untrained — this demo shows the *scheduling*
machinery), submits a few synthetic reasoning requests, and serves them with
redundant sampling (N=8, early stop at M=4) + two-phase pruning.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core import OraclePRM, Scheduler, SchedulerConfig
from repro.core.scheduler import percentile_latency
from repro.data import tasks
from repro.data import tokenizer as tk
from repro.models import Model, ModelConfig
from repro.serving import Engine, EngineConfig, SamplingParams

# 1. a model (any of the 10 assigned archs works via repro.configs.smoke)
cfg = ModelConfig(name="demo", arch_type="dense", num_layers=2, d_model=128,
                  vocab_size=tk.VOCAB_SIZE, num_heads=4, num_kv_heads=2,
                  d_ff=512)
model = Model(cfg)
params = model.init_params(jax.random.PRNGKey(0))

# 2. the serving engine: paged KV cache with ref-counted prefix sharing
engine = Engine(model, params, EngineConfig(
    page_size=8, num_pages=512, max_slots=16, max_pages_per_branch=16,
    eos_id=tk.EOS, sampling=SamplingParams(temperature=1.0, top_p=0.95)))

# 3. SART: Algorithm 1 with N=8 branches, early stop at M=4, PRM pruning
prm = OraclePRM(tasks.oracle_grader, noise=0.05)
scheduler = Scheduler(
    engine, prm,
    SchedulerConfig(policy="sart", n=8, m=4, window=8, max_tokens=64),
    answer_fn=tasks.extract_answer)

# 4. submit reasoning requests (synthetic verifiable arithmetic chains)
rng = np.random.default_rng(0)
problems = [tasks.gen_problem(rng) for _ in range(6)]
for i, prob in enumerate(problems):
    print(f"request {i}: {tk.decode(prob.prompt_tokens())}  "
          f"(answer: {prob.answer})")
    scheduler.submit(prob.prompt_tokens(), payload=prob, arrival=i * 4)

# 5. serve
metrics = scheduler.run()
for r, prob in zip(metrics["requests"], problems):
    ok = tasks.is_correct(prob, r["answer"])
    print(f"request {r['request_id']}: answer={r['answer']} "
          f"({'correct' if ok else 'wrong — untrained model'}) "
          f"e2e={r['e2e']} steps, queued={r['queue']}, "
          f"completed={r['num_completed']}, pruned={r['num_pruned']}")
print(f"P97 latency: {percentile_latency(metrics, 97):.0f} decode steps")
assert engine.allocator.used_pages == 0, "page leak!"
print("all KV pages released — no leaks")
