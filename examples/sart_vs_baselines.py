"""Live engine comparison (paper Fig. 5, small scale): Vanilla vs
Self-Consistency vs Rebase vs SART on the trained tiny reasoner.

    PYTHONPATH=src python examples/sart_vs_baselines.py
"""
import argparse

from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--n", type=int, default=8)
    ap.add_argument("--ckpt", default="checkpoints/reasoner")
    args = ap.parse_args()
    print(f"{'policy':14s} {'N':>2s} {'acc':>5s} {'P50':>6s} {'P97':>6s} "
          f"{'queueP50':>8s} steps")
    for policy, n in [("vanilla", 1), ("sc", args.n), ("rebase", args.n),
                      ("sart", args.n)]:
        out = serve(policy=policy, n=n, num_requests=args.requests,
                    rate_gap=6, ckpt=args.ckpt, prm_kind="oracle", window=8,
                    max_tokens=96, max_slots=16, seed=0, temperature=0.9)
        print(f"{policy:14s} {n:2d} {out['accuracy']:5.2f} "
              f"{out['p50']:6.0f} {out['p97']:6.0f} "
              f"{out['queue_p50']:8.0f} {out['decode_steps']}")


if __name__ == "__main__":
    main()
