"""End-to-end driver: train a ~1M-param reasoner for a few hundred steps on
the synthetic CoT task, fit the PRM reward head on its hidden states, save a
checkpoint, and evaluate greedy accuracy.

    PYTHONPATH=src python examples/train_tiny_reasoner.py [--steps 400]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import tasks
from repro.data import tokenizer as tk
from repro.launch.train import train_reasoner
from repro.models import Model, ModelConfig


def greedy_eval(model, params, n=30, seed=123, max_new=96):
    rng = np.random.default_rng(seed)
    correct = 0
    for _ in range(n):
        prob = tasks.gen_problem(rng, 3, 6)
        toks = prob.prompt_tokens()
        lg, cache = model.prefill(params, tokens=jnp.asarray(toks)[None],
                                  max_len=256)
        cur = int(jnp.argmax(lg[0]))
        out, pos = [], len(toks)
        while len(out) < max_new and cur != tk.EOS:
            out.append(cur)
            lg2, cache, _ = model.decode_step(
                params, jnp.array([cur]), cache, jnp.array([pos]))
            cur = int(jnp.argmax(lg2[0]))
            pos += 1
        if tasks.extract_answer(out) == prob.answer:
            correct += 1
    return correct / n


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--prm-steps", type=int, default=200)
    ap.add_argument("--out", default="checkpoints/reasoner")
    args = ap.parse_args()

    params, head = train_reasoner(args.steps, args.prm_steps, args.out,
                                  d_model=128, num_layers=2, seed=0)
    cfg = ModelConfig(name="tiny-reasoner", arch_type="dense", num_layers=2,
                      d_model=128, vocab_size=tk.VOCAB_SIZE, num_heads=4,
                      num_kv_heads=2, d_ff=512, max_seq_len=512)
    model = Model(cfg)
    acc = greedy_eval(model, params)
    print(f"[eval] greedy accuracy on held-out problems: {acc:.2f}")


if __name__ == "__main__":
    main()
