import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe import (_capacity, apply_moe, apply_moe_dense_eval,
                              init_moe, router_probs)

from conftest import tiny_config


def _moe_cfg(**kw):
    base = dict(arch_type="moe", d_ff=96, num_experts=4,
                num_experts_per_tok=2, moe_capacity_factor=4.0)
    base.update(kw)
    return tiny_config(**base)


@pytest.mark.parametrize("e,k", [(4, 1), (4, 2), (8, 2), (8, 4)])
def test_dispatch_matches_dense_eval(e, k):
    cfg = _moe_cfg(num_experts=e, num_experts_per_tok=k)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y1, _ = apply_moe(cfg, p, x)
    y2 = apply_moe_dense_eval(cfg, p, x)
    np.testing.assert_allclose(y1, y2, atol=2e-5)


def test_gates_normalized():
    cfg = _moe_cfg()
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, cfg.d_model))
    gates, ids, aux = router_probs(cfg, p, x)
    np.testing.assert_allclose(gates.sum(-1), 1.0, atol=1e-5)
    assert (ids >= 0).all() and (ids < cfg.num_experts).all()
    assert float(aux) >= 0


def test_capacity_drop_bounds_output():
    """With capacity 1.0 some tokens drop; output stays finite and within
    the convex hull scale of expert outputs."""
    cfg = _moe_cfg(moe_capacity_factor=0.5)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    y, _ = apply_moe(cfg, p, x)
    assert jnp.isfinite(y).all()
    # dropped tokens contribute zero, so norm <= dense-eval norm * (1+eps)
    dense = apply_moe_dense_eval(cfg, p, x)
    assert float(jnp.linalg.norm(y)) <= float(jnp.linalg.norm(dense)) * 1.5


def test_capacity_rounding():
    cfg = _moe_cfg()
    assert _capacity(cfg, 16) % 8 == 0
    assert _capacity(cfg, 16) >= 8


def test_identical_tokens_identical_outputs():
    """Permutation-ish invariance: same token vector -> same expert mix."""
    cfg = _moe_cfg()
    p = init_moe(jax.random.PRNGKey(0), cfg)
    tok = jax.random.normal(jax.random.PRNGKey(1), (cfg.d_model,))
    x = jnp.broadcast_to(tok, (1, 8, cfg.d_model))
    y, _ = apply_moe(cfg, p, x)
    np.testing.assert_allclose(y[0, 0], y[0, -1], atol=1e-5)


def test_aux_loss_favors_balance():
    cfg = _moe_cfg(router_aux_coef=1.0)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, cfg.d_model))
    # collapse the router to one expert -> aux rises
    p_collapsed = dict(p)
    r = np.zeros_like(np.asarray(p["router"]))
    r[:, 0] = 10.0
    p_collapsed["router"] = jnp.asarray(r)
    _, _, aux_bal = router_probs(cfg, p, x)
    _, _, aux_col = router_probs(cfg, p_collapsed, x)
    assert float(aux_col) > float(aux_bal)


def test_shard_local_dispatch_matches_dense_eval():
    """The perf-lever dispatch (moe_dp_chunks > 1) is semantics-preserving
    (same routing, per-shard capacity)."""
    import jax
    from repro.distributed.logical import activation_rules

    cfg = _moe_cfg(moe_capacity_factor=8.0)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    ref = apply_moe_dense_eval(cfg, p, x)
    # no mesh needed: the rules map alone activates the grouped path
    with activation_rules(None, {"_moe_dp": 4}):
        # mesh None with no matching spec names -> constrain() only consults
        # "_moe_dp"; give it a map without tensor rules
        y, _ = apply_moe(cfg, p, x)
    np.testing.assert_allclose(y, ref, atol=2e-5)
