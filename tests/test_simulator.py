"""Trace-driven simulator: same Scheduler, simulated engine."""
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # sim-/training-heavy: not in the CI fast lane

from repro.core.scheduler import percentile_latency
from repro.serving.simulator import (SimEngine, SimEngineConfig, SimWorkload,
                                     run_sim_experiment)


def _fast_workload(**kw):
    base = dict(mean_len=120, sigma_len=0.5, overthink_p=0.15,
                overthink_mult=4.0, prompt_len=16)
    base.update(kw)
    return SimWorkload(**base)


def _cfg(**kw):
    base = dict(max_slots=16, page_size=8, num_pages=4096)
    base.update(kw)
    return SimEngineConfig(**base)


@pytest.mark.parametrize("policy,n", [("vanilla", 1), ("sc", 4),
                                      ("sart", 8), ("sart_noprune", 8),
                                      ("rebase", 4)])
def test_sim_policies_complete(policy, n):
    m, acc = run_sim_experiment(policy, n, num_requests=10, arrival_gap=20,
                                workload=_fast_workload(),
                                engine_cfg=_cfg(), window=25, seed=0)
    assert len(m["requests"]) == 10
    assert 0.0 <= acc <= 1.0


def test_sart_beats_sc_latency_at_same_n():
    w = _fast_workload()
    m_sc, _ = run_sim_experiment("sc", 4, num_requests=20, arrival_gap=15,
                                 workload=w, engine_cfg=_cfg(), window=25,
                                 seed=1)
    m_sart, _ = run_sim_experiment("sart", 8, num_requests=20,
                                   arrival_gap=15, workload=w,
                                   engine_cfg=_cfg(), window=25, seed=1)
    assert percentile_latency(m_sart, 50) < percentile_latency(m_sc, 50)


def test_early_stopping_shortens_tail():
    """Paper Fig. 7: tail latency improves with redundant sampling.

    Averaged over seeds: a single p97-of-30 comparison is one draw of the
    overthink tail and can flip on any change to the rng stream (a request
    whose pruner kills everything but an overthinker loses by itself)."""
    w = _fast_workload(overthink_p=0.3)

    def p97(policy, n, seed):
        m, _ = run_sim_experiment(policy, n, num_requests=30,
                                  arrival_gap=30, workload=w,
                                  engine_cfg=_cfg(max_slots=32), window=25,
                                  seed=seed)
        return percentile_latency(m, 97, "inference")

    seeds = (0, 1, 2)
    tail_vanilla = np.mean([p97("vanilla", 1, s) for s in seeds])
    tail_sart = np.mean([p97("sart", 8, s) for s in seeds])
    assert tail_sart < tail_vanilla


def test_pruning_reduces_queue_vs_noprune():
    """Paper Fig. 6: pruning shrinks queuing time under load."""
    w = _fast_workload()
    kw = dict(num_requests=24, arrival_gap=5, workload=w,
              engine_cfg=_cfg(max_slots=8), window=25, seed=3)
    m_np, _ = run_sim_experiment("sart_noprune", 8, **kw)
    m_p, _ = run_sim_experiment("sart", 8, **kw)
    assert percentile_latency(m_p, 90, "queue") <= \
        percentile_latency(m_np, 90, "queue")


def test_prm_discriminates_quality():
    eng = SimEngine(_cfg(), _fast_workload(prm_noise=0.0, prm_drift=6.0),
                    seed=0)
    blocks, lg, ssm = eng.prefill([0] * 16)
    goods, bads = [], []
    for _ in range(40):
        h = eng.spawn_branch(0, blocks, lg, ssm, 16)
        spec = eng._specs[h.branch_id]
        h.tokens = [0] * max(spec.length - 1, 1)
        (goods if spec.correct else bads).append(eng.reward_of(h))
        eng.free_branch(h)
    eng.release_prefix(blocks)
    if goods and bads:
        assert np.mean(goods) > np.mean(bads)


def test_sim_engine_memory_accounting():
    eng = SimEngine(_cfg(num_pages=64, max_slots=4), _fast_workload(),
                    seed=0)
    blocks, lg, ssm = eng.prefill([0] * 16)
    hs = [eng.spawn_branch(0, blocks, lg, ssm, 16) for _ in range(3)]
    for _ in range(5):
        eng.decode_step()
    assert eng.live_tokens() == 3 * (16 + 5)
    for h in hs:
        eng.free_branch(h)
    eng.release_prefix(blocks)
    assert eng.allocator.used_pages == 0
