"""End-to-end behaviour of the paper's system: train a tiny reasoner,
serve it with SART vs baselines, check the paper's qualitative claims."""
import jax
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # sim-/training-heavy: not in the CI fast lane

from repro.core import OraclePRM, RewardHeadPRM, Scheduler, SchedulerConfig
from repro.core.scheduler import percentile_latency
from repro.data import DataConfig, padded_batches, prm_batches, tasks
from repro.data import tokenizer as tk
from repro.models import Model, ModelConfig
from repro.serving import Engine, EngineConfig, SamplingParams
from repro.training import AdamWConfig, train_lm, train_prm_head


@pytest.fixture(scope="module")
def trained():
    cfg = ModelConfig(name="sys", arch_type="dense", num_layers=2,
                      d_model=96, vocab_size=tk.VOCAB_SIZE, num_heads=4,
                      num_kv_heads=2, d_ff=256, max_seq_len=512)
    model = Model(cfg)
    data = padded_batches(DataConfig(batch_size=24, seq_len=96, seed=0))
    params, hist = train_lm(model, data, steps=150,
                            opt_cfg=AdamWConfig(lr=2e-3, warmup_steps=20,
                                                total_steps=150),
                            log_every=149)
    head, _ = train_prm_head(model, params,
                             prm_batches(DataConfig(batch_size=8,
                                                    seq_len=96, seed=0)),
                             steps=80, lr=0.05)
    return cfg, model, params, head, hist


def _serve(model, params, head, policy, n, probs, seed=0, prm="oracle"):
    eng = Engine(model, params, EngineConfig(
        page_size=8, num_pages=512, max_slots=12, max_pages_per_branch=16,
        eos_id=tk.EOS, sampling=SamplingParams(temperature=0.8, top_p=0.95),
        seed=seed), prm_params=head)
    if prm == "head":
        scorer = RewardHeadPRM(eng)
    else:
        scorer = OraclePRM(tasks.oracle_grader, noise=0.05, seed=seed + 1)
    sch = Scheduler(eng, scorer,
                    SchedulerConfig(policy=policy, n=n, window=8,
                                    max_tokens=80),
                    answer_fn=tasks.extract_answer)
    for i, p in enumerate(probs):
        sch.submit(p.prompt_tokens(), payload=p, arrival=i * 4)
    m = sch.run(max_steps=60000)
    correct = sum(1 for r, p in zip(m["requests"], probs)
                  if tasks.is_correct(p, r["answer"]))
    assert eng.allocator.used_pages == 0
    return m, correct / len(probs)


def test_lm_learns_the_task(trained):
    _, _, _, _, hist = trained
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.6


def test_sart_serves_accurately_and_fast(trained):
    cfg, model, params, head, _ = trained
    rng = np.random.default_rng(7)
    probs = [tasks.gen_problem(rng, 3, 5) for _ in range(6)]
    m_sart, acc_sart = _serve(model, params, head, "sart", 4, probs)
    m_sc, acc_sc = _serve(model, params, head, "sc", 4, probs)
    # scheduling claim (robust): SART's P97 e2e <= SC's (early stop + prune)
    assert percentile_latency(m_sart, 97) <= percentile_latency(m_sc, 97)
    assert 0.0 <= acc_sart <= 1.0 and 0.0 <= acc_sc <= 1.0


def test_reward_head_prm_end_to_end(trained):
    """The trained PRM head drives pruning without crashing or leaking."""
    cfg, model, params, head, _ = trained
    rng = np.random.default_rng(8)
    probs = [tasks.gen_problem(rng, 3, 4) for _ in range(3)]
    m, acc = _serve(model, params, head, "sart", 4, probs, prm="head")
    assert len(m["requests"]) == 3
    assert 0.0 <= acc <= 1.0
