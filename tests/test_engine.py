"""Serving engine integration: the paged decode path must reproduce the
dense-model generation token-for-token (greedy), prefix sharing must be
exact, and slot/page bookkeeping must never leak."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import Model
from repro.serving import Engine, EngineConfig, SamplingParams

from conftest import tiny_config


def _engine(cfg, temperature=0.0, slots=4, seed=0):
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(seed))
    eng = Engine(model, params, EngineConfig(
        page_size=4, num_pages=128, max_slots=slots,
        max_pages_per_branch=24, eos_id=1,
        sampling=SamplingParams(temperature=temperature), seed=seed))
    return model, params, eng


def _greedy_reference(model, params, prompt, steps):
    """Dense-cache greedy generation as ground truth."""
    toks = jnp.asarray(prompt)[None]
    lg, cache = model.prefill(params, tokens=toks, max_len=96)
    out = []
    cur = int(jnp.argmax(lg[0]))
    pos = len(prompt)
    for _ in range(steps):
        out.append(cur)
        lg2, cache, _ = model.decode_step(params, jnp.array([cur]), cache,
                                          jnp.array([pos]))
        cur = int(jnp.argmax(lg2[0]))
        pos += 1
    return out


@pytest.mark.parametrize("family_kw", [
    dict(),                                                     # dense
    dict(arch_type="ssm", d_ff=0, ssm_state=16, ssm_head_dim=32,
         ssm_chunk=8),
    dict(arch_type="hybrid", ssm_state=16, ssm_head_dim=32, ssm_chunk=8),
])
def test_paged_decode_matches_dense_greedy(family_kw):
    cfg = tiny_config(**family_kw)
    model, params, eng = _engine(cfg, temperature=0.0)
    prompt = [2, 5, 9, 13, 7, 3, 11]        # crosses a page boundary (ps=4)
    steps = 10
    ref = _greedy_reference(model, params, prompt, steps)

    blocks, logits, ssm = eng.prefill(prompt)
    h = eng.spawn_branch(0, blocks, logits, ssm, len(prompt))
    assert h is not None
    assert h.tokens[0] == ref[0], "first sampled token mismatch"
    for _ in range(steps - 1):
        eng.decode_step()
    assert h.tokens[:steps] == ref, f"{cfg.arch_type}: paged != dense"
    eng.free_branch(h)
    eng.release_prefix(blocks)
    assert eng.allocator.used_pages == 0


def test_sibling_branches_greedy_identical():
    """With temperature 0 all forks of one prefix generate identically —
    the shared-prefix pages and CoW bookkeeping must be bit-exact."""
    cfg = tiny_config()
    model, params, eng = _engine(cfg, temperature=0.0)
    prompt = [2, 5, 9]                       # partial page -> CoW on fork
    blocks, logits, ssm = eng.prefill(prompt)
    hs = [eng.spawn_branch(0, blocks, logits, ssm, len(prompt))
          for _ in range(3)]
    for _ in range(8):
        eng.decode_step()
    assert hs[0].tokens == hs[1].tokens == hs[2].tokens
    for h in hs:
        eng.free_branch(h)
    eng.release_prefix(blocks)
    assert eng.allocator.used_pages == 0


def test_stochastic_branches_diverge():
    cfg = tiny_config()
    model, params, eng = _engine(cfg, temperature=1.5, seed=3)
    prompt = [2, 5, 9, 4]
    blocks, logits, ssm = eng.prefill(prompt)
    hs = [eng.spawn_branch(0, blocks, logits, ssm, len(prompt))
          for _ in range(4)]
    for _ in range(12):
        eng.decode_step()
    seqs = {tuple(h.tokens) for h in hs}
    assert len(seqs) > 1, "temperature sampling should diverge branches"


def test_slot_reuse_after_free():
    cfg = tiny_config()
    model, params, eng = _engine(cfg, slots=2)
    b1, l1, s1 = eng.prefill([2, 3, 4])
    h1 = eng.spawn_branch(0, b1, l1, s1, 3)
    h2 = eng.spawn_branch(0, b1, l1, s1, 3)
    assert eng.spawn_branch(0, b1, l1, s1, 3) is None  # full
    eng.free_branch(h1)
    h3 = eng.spawn_branch(1, b1, l1, s1, 3)
    assert h3 is not None and h3.slot == h1.slot
    eng.free_branch(h2)
    eng.free_branch(h3)
    eng.release_prefix(b1)
    assert eng.allocator.used_pages == 0


def test_fork_branch_continues_context():
    """Mid-generation fork (Rebase): child's greedy continuation equals
    the parent's (same context, greedy)."""
    cfg = tiny_config()
    model, params, eng = _engine(cfg, temperature=0.0)
    prompt = [2, 5, 9, 13]
    blocks, logits, ssm = eng.prefill(prompt)
    parent = eng.spawn_branch(0, blocks, logits, ssm, len(prompt))
    for _ in range(5):
        eng.decode_step()
    child = eng.fork_branch(parent)
    assert child.tokens == parent.tokens
    for _ in range(5):
        eng.decode_step()
    assert child.tokens == parent.tokens     # greedy => identical futures
    for h in (parent, child):
        eng.free_branch(h)
    eng.release_prefix(blocks)
    assert eng.allocator.used_pages == 0


def test_live_tokens_accounting():
    cfg = tiny_config()
    model, params, eng = _engine(cfg)
    b1, l1, s1 = eng.prefill([2, 3, 4, 5, 6])
    h = eng.spawn_branch(0, b1, l1, s1, 5)
    assert eng.live_tokens() == 5
    eng.decode_step()
    assert eng.live_tokens() == 6
    eng.free_branch(h)
    assert eng.live_tokens() == 0
    eng.release_prefix(b1)


def test_cow_arrays_reuses_sentinel_pair_when_no_cow():
    """The common no-CoW step must reuse one cached (src, dst) sentinel
    pair instead of re-staging two host arrays per decode step; real CoW
    steps still build fresh index arrays."""
    _, _, eng = _engine(tiny_config())
    s1 = eng._cow_arrays([])
    s2 = eng._cow_arrays([])
    assert s1[0] is s2[0] and s1[1] is s2[1]
    assert int(s1[0][0]) == eng.cfg.num_pages    # OOB sentinel everywhere
    real = eng._cow_arrays([(3, 7)])
    assert real[0] is not s1[0]
    assert int(real[0][0]) == 3 and int(real[1][0]) == 7
    assert int(real[0][1]) == eng.cfg.num_pages  # tail stays sentinel
    # and the cached pair was not clobbered by the real-CoW call
    again = eng._cow_arrays([])
    assert again[0] is s1[0] and int(again[0][0]) == eng.cfg.num_pages
