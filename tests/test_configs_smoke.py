"""Per-assigned-architecture smoke tests: a REDUCED variant of the same
family (2 layers, d_model<=512, <=4 experts) runs one forward and one train
step on CPU with shape and finiteness asserts. The FULL configs are
exercised compile-only by the multi-pod dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, REGISTRY, get_config, smoke
from repro.models import Model
from repro.training import AdamWConfig, init_opt_state, make_train_step


def test_registry_covers_assignment():
    assert len(ASSIGNED) == 10
    families = {REGISTRY[a].arch_type for a in ASSIGNED}
    assert families == {"dense", "moe", "ssm", "hybrid", "vlm", "audio"}


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_full_config_exact_dims(arch):
    cfg = get_config(arch)
    expected = {
        "mamba2-130m": (24, 768, 0, 50280),
        "qwen2-vl-72b": (80, 8192, 29568, 152064),
        "dbrx-132b": (40, 6144, 10752, 100352),
        "hymba-1.5b": (32, 1600, 5504, 32001),
        "qwen3-moe-235b-a22b": (94, 4096, 1536, 151936),
        "qwen2-0.5b": (24, 896, 4864, 151936),
        "stablelm-1.6b": (24, 2048, 5632, 100352),
        "musicgen-medium": (48, 1536, 6144, 2048),
        "nemotron-4-15b": (32, 6144, 24576, 256000),
        "gemma-7b": (28, 3072, 24576, 256000),
    }[arch]
    assert (cfg.num_layers, cfg.d_model, cfg.d_ff, cfg.vocab_size) == expected


def test_moe_active_params_match_nameplates():
    dbrx = get_config("dbrx-132b")
    qwen3 = get_config("qwen3-moe-235b-a22b")
    assert 30e9 < dbrx.active_param_count() < 40e9            # "36B active"
    assert 20e9 < qwen3.active_param_count() < 24e9           # "a22b"
    assert 125e9 < dbrx.param_count() < 140e9
    assert 225e9 < qwen3.param_count() < 245e9


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_smoke_variant_forward_and_train_step(arch):
    cfg = smoke(arch)
    assert cfg.num_layers == 2 and cfg.d_model <= 512
    if cfg.uses_moe:
        assert cfg.num_experts <= 4
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    b, s = 2, 64
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)

    # forward
    if cfg.multimodal:
        embeds = jnp.asarray(rng.normal(size=(b, s, cfg.d_model)),
                             jnp.float32)
        logits, aux = model.forward(params, embeds=embeds)
    else:
        logits, aux = model.forward(params, tokens=toks)
    assert logits.shape == (b, s, cfg.vocab_size), arch
    assert jnp.isfinite(logits).all(), f"{arch}: NaN in forward"

    # one train step
    batch = {"labels": toks, "mask": jnp.ones((b, s), jnp.float32)}
    if cfg.multimodal:
        batch["embeds"] = embeds
    else:
        batch["tokens"] = toks
    step = jax.jit(make_train_step(model, AdamWConfig(total_steps=4)))
    p2, _, metrics = step(params, init_opt_state(params), batch)
    assert np.isfinite(float(metrics["loss"])), f"{arch}: non-finite loss"
    # params actually moved
    delta = max(float(jnp.abs(a - b2).max()) for a, b2 in
                zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert delta > 0, f"{arch}: train step was a no-op"


@pytest.mark.parametrize("arch", ["mamba2-130m", "hymba-1.5b",
                                  "qwen2-0.5b", "musicgen-medium"])
def test_smoke_variant_decode_step(arch):
    """Reduced variant runs a serve step (decode against a cache)."""
    cfg = smoke(arch)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    b, s = 2, 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    if cfg.multimodal:
        embeds = jnp.asarray(rng.normal(size=(b, s, cfg.d_model)),
                             jnp.float32)
        _, cache = model.prefill(params, embeds=embeds, max_len=64)
    else:
        _, cache = model.prefill(params, tokens=toks, max_len=64)
    logits, cache, hidden = model.decode_step(
        params, toks[:, -1], cache, jnp.full((b,), s))
    assert logits.shape == (b, cfg.vocab_size)
    assert jnp.isfinite(logits).all(), arch
