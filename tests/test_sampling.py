import jax
import jax.numpy as jnp
import numpy as np
import pytest
from prop import given, settings, st

from repro.serving.sampling import (SamplingParams, apply_top_k, apply_top_p,
                                    sample)


def test_greedy_temperature_zero():
    logits = jnp.array([1.0, 5.0, 2.0])
    tok = sample(jax.random.PRNGKey(0), logits,
                 SamplingParams(temperature=0.0))
    assert int(tok) == 1


def test_top_k_keeps_exactly_k():
    logits = jnp.arange(10.0)
    out = apply_top_k(logits, 3)
    assert int(jnp.sum(out > -1e29)) == 3
    assert (out[-3:] == logits[-3:]).all()


def test_top_p_always_keeps_argmax():
    logits = jnp.array([10.0, 0.0, 0.0])
    out = apply_top_p(logits, 0.01)
    assert out[0] == 10.0
    assert int(jnp.sum(out > -1e29)) == 1


def test_top_p_one_is_identity():
    logits = jax.random.normal(jax.random.PRNGKey(0), (32,))
    np.testing.assert_array_equal(apply_top_p(logits, 1.0), logits)


def test_sample_respects_top_k_support():
    logits = jnp.arange(16.0)
    keys = jax.random.split(jax.random.PRNGKey(0), 200)
    toks = jax.vmap(lambda k: sample(k, logits, SamplingParams(
        temperature=1.0, top_k=4)))(keys)
    assert set(np.asarray(toks).tolist()) <= {12, 13, 14, 15}


def test_sample_distribution_roughly_softmax():
    logits = jnp.array([0.0, jnp.log(3.0)])   # probs 0.25 / 0.75
    keys = jax.random.split(jax.random.PRNGKey(1), 2000)
    toks = jax.vmap(lambda k: sample(k, logits, SamplingParams()))(keys)
    frac1 = float(jnp.mean(toks == 1))
    assert 0.70 < frac1 < 0.80


@settings(max_examples=50, deadline=None)
@given(st.floats(0.1, 0.99), st.integers(2, 64))
def test_top_p_support_nonempty_and_sound(p, v):
    logits = jax.random.normal(jax.random.PRNGKey(42), (v,))
    out = apply_top_p(logits, p)
    kept = np.asarray(out > -1e29)
    assert kept.sum() >= 1
    # kept mass >= p (smallest set property)
    probs = np.asarray(jax.nn.softmax(logits))
    assert probs[kept].sum() >= p - 1e-3


def test_batched_sampling_shape():
    logits = jax.random.normal(jax.random.PRNGKey(0), (8, 32))
    toks = sample(jax.random.PRNGKey(1), logits, SamplingParams())
    assert toks.shape == (8,)
    assert ((toks >= 0) & (toks < 32)).all()
