"""Training substrate: AdamW, LM convergence, PRM head, checkpoints."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import DataConfig, padded_batches, prm_batches
from repro.data import tokenizer as tk
from repro.models import Model
from repro.training import (AdamWConfig, adamw_update, init_opt_state,
                            load_checkpoint, save_checkpoint, train_lm,
                            train_prm_head)
from repro.training.optimizer import schedule

from conftest import tiny_config


def test_adamw_reduces_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100,
                      weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = init_opt_state(params)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, state, gn = adamw_update(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lr0 = float(schedule(cfg, jnp.array(0.0)))
    lr10 = float(schedule(cfg, jnp.array(10.0)))
    lr100 = float(schedule(cfg, jnp.array(100.0)))
    assert lr0 < lr10
    assert lr10 == pytest.approx(1.0, rel=1e-3)
    assert lr100 == pytest.approx(cfg.min_lr_ratio, rel=1e-2)


def test_grad_clip_caps_update_norm():
    cfg = AdamWConfig(lr=1.0, grad_clip=1e-9, warmup_steps=0,
                      weight_decay=0.0)
    params = {"w": jnp.ones((4,))}
    state = init_opt_state(params)
    p2, _, gn = adamw_update(cfg, params, {"w": jnp.full((4,), 1e6)}, state)
    assert float(gn) > 1e5                  # raw norm reported
    # update magnitude bounded by lr since mhat/sqrt(vhat) <= 1/sqrt(1)
    assert float(jnp.abs(p2["w"] - params["w"]).max()) <= 1.1


def test_lm_loss_decreases():
    cfg = tiny_config(vocab_size=tk.VOCAB_SIZE, d_model=96, d_ff=256)
    model = Model(cfg)
    data = padded_batches(DataConfig(batch_size=16, seq_len=96, seed=0))
    params, hist = train_lm(model, data, steps=60,
                            opt_cfg=AdamWConfig(lr=2e-3, warmup_steps=10,
                                                total_steps=60),
                            log_every=59)
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.8, hist


def test_prm_head_loss_decreases():
    """Full-batch GD on one fixed batch must reduce the BCE (per-batch
    stochastic loss is too noisy for an untrained backbone)."""
    from repro.core.prm import init_prm_head, prm_head_loss
    from repro.training.train_loop import hidden_states

    cfg = tiny_config(vocab_size=tk.VOCAB_SIZE)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    toks, labels, mask = next(prm_batches(DataConfig(batch_size=8,
                                                     seq_len=96, seed=0)))
    h = hidden_states(model, params, jnp.asarray(toks))
    labels_j = jnp.asarray(labels)
    mask_j = jnp.asarray(mask)

    def loss(hp):
        from repro.core.prm import reward_logit
        lg = reward_logit(hp, h.astype(jnp.float32))
        bce = (jnp.maximum(lg, 0) - lg * labels_j
               + jnp.log1p(jnp.exp(-jnp.abs(lg))))
        return jnp.sum(bce * mask_j) / jnp.maximum(mask_j.sum(), 1.0)

    head = init_prm_head(jax.random.PRNGKey(1), cfg.d_model)
    l0 = float(loss(head))
    step = jax.jit(lambda hp: jax.tree.map(
        lambda p, g: p - 0.05 * g, hp, jax.grad(loss)(hp)))
    for _ in range(40):
        head = step(head)
    assert float(loss(head)) < l0


def test_checkpoint_roundtrip(tmp_path):
    cfg = tiny_config()
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    path = os.path.join(tmp_path, "ckpt.npz")
    save_checkpoint(path, params)
    restored = load_checkpoint(path, like=params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_structural_load(tmp_path):
    tree = {"a": {"b": jnp.arange(3)}, "c": jnp.ones((2, 2))}
    path = os.path.join(tmp_path, "t.npz")
    save_checkpoint(path, tree)
    r = load_checkpoint(path)
    np.testing.assert_array_equal(np.asarray(r["a"]["b"]), np.arange(3))
    np.testing.assert_array_equal(np.asarray(r["c"]), np.ones((2, 2)))
