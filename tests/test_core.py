"""SART core: order statistics (Lemma 1), two-phase pruning, ensembling."""
import numpy as np
import pytest
from prop import given, settings, st

from repro.core import (OraclePRM, PruningConfig, TwoPhasePruner, best_of_n,
                        empirical_mth_completion, majority_vote,
                        order_statistic_cdf, order_statistic_expectation,
                        weighted_vote)


# ------------------------------------------------------------- Lemma 1


def test_order_statistic_cdf_is_cdf():
    f = np.linspace(0, 1, 101)
    for m, n in [(1, 1), (2, 4), (4, 8), (8, 8)]:
        g = order_statistic_cdf(f, m, n)
        assert g[0] == pytest.approx(0.0)
        assert g[-1] == pytest.approx(1.0)
        assert (np.diff(g) >= -1e-12).all()


def test_lemma1_monotone_in_n():
    """F_{X_(M)}(x; N) increases with N  =>  M-th completion gets faster."""
    f = np.linspace(0.01, 0.99, 99)
    prev = order_statistic_cdf(f, 4, 4)
    for n in (5, 6, 8, 12, 16):
        cur = order_statistic_cdf(f, 4, n)
        assert (cur >= prev - 1e-12).all()
        prev = cur


def test_lemma1_analytic_matches_monte_carlo(rng):
    lengths = rng.lognormal(7.0, 0.8, size=4000)
    m, n = 4, 8
    analytic = order_statistic_expectation(lengths, m, n)
    mc = empirical_mth_completion(lengths, m, n, trials=4000).mean()
    assert abs(analytic - mc) / mc < 0.05


def test_redundant_sampling_speedup_positive(rng):
    lengths = rng.lognormal(7.0, 0.8, size=2000)
    from repro.core import expected_speedup
    s = expected_speedup(lengths, m=4, n=8)
    assert s > 1.2   # heavy-tailed lengths -> real win


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 10), st.integers(0, 8))
def test_order_stat_bounds(m, extra):
    n = m + extra
    f = np.linspace(0, 1, 31)
    g = order_statistic_cdf(f, m, n)
    # m-th of n is stochastically smaller than the max of n
    gmax = order_statistic_cdf(f, n, n)
    assert (g >= gmax - 1e-12).all()


# ------------------------------------------------------- two-phase pruning


def _pruner(alpha=0.5, beta=2):
    return TwoPhasePruner(PruningConfig(alpha=alpha, beta=beta))


def test_phase1_threshold_and_cap():
    pr = _pruner(alpha=0.5, beta=2)
    meta = pr.new_meta(n=8, m=4)
    assert meta.phase == "explore" and meta.threshold == 0.5
    rewards = {i: 0.1 * i for i in range(8)}    # 0.0 .. 0.7
    victims = pr.select_prunes(meta, rewards)
    assert victims == [0, 1]                     # cap β=2, lowest first
    assert meta.num_pruned == 2
    assert pr.select_prunes(meta, rewards) == []  # cap exhausted


def test_phase2_raises_threshold_and_lifts_cap():
    pr = _pruner(alpha=0.5, beta=2)
    meta = pr.new_meta(n=8, m=4)
    pr.on_completion(meta, reward=0.8)
    assert meta.phase == "exploit"
    assert meta.threshold == 0.8
    assert meta.max_num_pruned == 7
    rewards = {i: 0.1 * i for i in range(8)}     # all < 0.8
    victims = pr.select_prunes(meta, rewards)
    assert len(victims) == 7                     # n-1 cap binds
    assert meta.num_pruned == 7


def test_second_completion_keeps_phase2_threshold():
    pr = _pruner()
    meta = pr.new_meta(8, 4)
    pr.on_completion(meta, 0.9)
    pr.on_completion(meta, 0.2)                  # later, worse completion
    assert meta.threshold == 0.9                 # α' fixed by the FIRST
    assert meta.num_completed == 2


def test_terminal_conditions():
    pr = _pruner()
    meta = pr.new_meta(n=4, m=2)
    assert not meta.terminal
    pr.on_completion(meta, 0.5)
    pr.on_completion(meta, 0.5)
    assert meta.terminal                         # early stop at m
    meta2 = pr.new_meta(n=4, m=4)
    meta2.num_completed, meta2.num_pruned = 1, 3
    assert meta2.terminal                        # nothing left running


def test_disabled_pruner_never_prunes():
    pr = TwoPhasePruner(PruningConfig(enabled=False))
    meta = pr.new_meta(8, 4)
    assert pr.select_prunes(meta, {0: -1.0}) == []


@settings(max_examples=100, deadline=None)
@given(st.integers(2, 16), st.integers(1, 8),
       st.lists(st.floats(0, 1), min_size=1, max_size=16),
       st.floats(0, 1))
def test_prune_counts_never_exceed_caps(n, beta, rewards, alpha):
    pr = TwoPhasePruner(PruningConfig(alpha=alpha, beta=beta))
    meta = pr.new_meta(n, max(n // 2, 1))
    rd = {i: r for i, r in enumerate(rewards)}
    v1 = pr.select_prunes(meta, rd)
    assert len(v1) <= min(beta, n - 1)
    pr.on_completion(meta, 0.6)
    v2 = pr.select_prunes(meta, rd)
    assert meta.num_pruned <= n - 1
    assert set(v1).issubset(set(rd)) and set(v2).issubset(set(rd))


# ------------------------------------------------------------- ensembling


def _answers(pairs):
    # encode answer in tokens via a passthrough answer_fn
    return [(ans, r) for ans, r in pairs], (lambda tokens: tokens)


def test_best_of_n_picks_highest_reward():
    completed, fn = _answers([(1, 0.2), (2, 0.9), (3, 0.5)])
    assert best_of_n(completed, fn) == 2


def test_majority_vote_counts():
    completed, fn = _answers([(1, 0.1), (1, 0.2), (2, 0.99)])
    assert majority_vote(completed, fn) == 1


def test_majority_tie_breaks_by_reward():
    completed, fn = _answers([(1, 0.1), (2, 0.9)])
    assert majority_vote(completed, fn) == 2


def test_weighted_vote():
    completed, fn = _answers([(1, 0.3), (1, 0.3), (2, 0.9)])
    assert weighted_vote(completed, fn) == 2


def test_none_answers_skipped():
    completed = [([1], 0.9), ([2], 0.5)]
    fn = lambda tokens: None if tokens == [1] else 42
    assert best_of_n(completed, fn) == 42
    assert majority_vote(completed, fn) == 42
