"""PageAllocator: unit tests + hypothesis property tests of the refcount
invariants under arbitrary fork/append/release interleavings."""
import pytest
from prop import given, settings, st

from repro.kv import BranchBlocks, OutOfPagesError, PageAllocator


def test_alloc_free_roundtrip():
    a = PageAllocator(8, 4)
    pids = [a.alloc() for _ in range(8)]
    assert a.free_pages == 0
    with pytest.raises(OutOfPagesError):
        a.alloc()
    for p in pids:
        a.decref(p)
    assert a.free_pages == 8
    a.check_invariants()


def test_prefix_fork_shares_pages():
    a = PageAllocator(16, 4)
    prefix = a.alloc_prefix(10)          # 3 pages
    assert len(prefix.pages) == 3
    b1 = a.fork(prefix)
    b2 = a.fork(prefix)
    assert b1.pages == prefix.pages == b2.pages
    assert all(a.refcount(p) == 3 for p in prefix.pages)
    assert a.used_pages == 3             # sharing, not copying


def test_cow_on_shared_partial_page():
    a = PageAllocator(16, 4)
    prefix = a.alloc_prefix(10)          # page 2 holds 2 tokens
    b1 = a.fork(prefix)
    assert a.needs_cow(b1)
    cow = a.append_token(b1)
    assert cow is not None
    old, new = cow
    assert old == prefix.pages[-1] and new == b1.pages[-1] != old
    assert a.refcount(old) == 1          # only the prefix holds it now
    assert b1.length == 11


def test_no_cow_on_page_boundary():
    a = PageAllocator(16, 4)
    prefix = a.alloc_prefix(8)           # exactly 2 full pages
    b1 = a.fork(prefix)
    assert not a.needs_cow(b1)
    cow = a.append_token(b1)
    assert cow is None
    assert len(b1.pages) == 3            # fresh page allocated
    assert b1.pages[:2] == prefix.pages


def test_eager_release_returns_shared_last():
    a = PageAllocator(16, 4)
    prefix = a.alloc_prefix(8)
    b1, b2 = a.fork(prefix), a.fork(prefix)
    a.release(b1)
    assert a.used_pages == 2             # still shared with b2 + prefix
    a.release(b2)
    a.release(prefix)
    assert a.used_pages == 0
    a.check_invariants()


@settings(max_examples=200, deadline=None)
@given(st.lists(st.sampled_from(["fork", "append", "release"]),
                min_size=1, max_size=120),
       st.integers(1, 12))
def test_invariants_under_interleaving(ops, prompt_len):
    a = PageAllocator(64, 4)
    prefix = a.alloc_prefix(prompt_len)
    branches = []
    for op in ops:
        try:
            if op == "fork":
                if len(branches) < 8:
                    branches.append(a.fork(prefix))
            elif op == "append" and branches:
                a.append_token(branches[0])
            elif op == "release" and branches:
                a.release(branches.pop())
        except OutOfPagesError:
            pass
        a.check_invariants()
    for b in branches:
        a.release(b)
    a.release(prefix)
    assert a.used_pages == 0
    a.check_invariants()


@settings(max_examples=100, deadline=None)
@given(st.integers(1, 40), st.integers(1, 6), st.integers(0, 30))
def test_fork_append_release_exact_counts(prompt_len, n_forks, n_appends):
    """After releasing everything, zero pages are used — no leaks ever."""
    a = PageAllocator(256, 4)
    prefix = a.alloc_prefix(prompt_len)
    forks = [a.fork(prefix) for _ in range(n_forks)]
    for b in forks:
        for _ in range(n_appends):
            a.append_token(b)
        assert b.length == prompt_len + n_appends
    for b in forks:
        a.release(b)
    a.release(prefix)
    assert a.used_pages == 0
