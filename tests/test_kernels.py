"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_decode_ref
from repro.kernels.ssd_scan.ops import ssd
from repro.kernels.ssd_scan.ref import ssd_scan_ref
from repro.models.mamba2 import ssd_chunked


@pytest.mark.parametrize("b,qh,kvh,hd,ps,pps", [
    (2, 4, 2, 64, 8, 4),
    (3, 8, 8, 128, 16, 3),
    (1, 8, 1, 256, 8, 5),
    (4, 2, 2, 32, 4, 8),
])
def test_paged_attention_shapes(rng, b, qh, kvh, hd, ps, pps):
    npages = b * pps + 2
    q = jnp.asarray(rng.normal(size=(b, qh, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(kvh, npages, ps, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(kvh, npages, ps, hd)), jnp.float32)
    bt = jnp.asarray(rng.integers(0, npages, size=(b, pps)), jnp.int32)
    lens = jnp.asarray(rng.integers(1, pps * ps + 1, size=(b,)), jnp.int32)
    out = paged_attention(q, k, v, bt, lens)
    ref = paged_attention_decode_ref(q, k, v, bt, lens)
    np.testing.assert_allclose(out, ref, atol=2e-4)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 2e-4),
                                        (jnp.bfloat16, 5e-2)])
def test_paged_attention_dtypes(rng, dtype, atol):
    b, qh, kvh, hd, ps, pps = 2, 4, 2, 64, 8, 4
    npages = 16
    q = jnp.asarray(rng.normal(size=(b, qh, hd))).astype(dtype)
    k = jnp.asarray(rng.normal(size=(kvh, npages, ps, hd))).astype(dtype)
    v = jnp.asarray(rng.normal(size=(kvh, npages, ps, hd))).astype(dtype)
    bt = jnp.asarray(rng.integers(0, npages, size=(b, pps)), jnp.int32)
    lens = jnp.asarray(rng.integers(1, pps * ps, size=(b,)), jnp.int32)
    out = paged_attention(q, k, v, bt, lens)
    ref = paged_attention_decode_ref(q, k, v, bt, lens)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=atol)


def test_paged_attention_shared_prefix(rng):
    """Two sequences whose block tables share prefix pages: identical
    prefix + identical query => identical output."""
    kvh, hd, ps, pps = 2, 64, 8, 4
    npages = 12
    k = jnp.asarray(rng.normal(size=(kvh, npages, ps, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(kvh, npages, ps, hd)), jnp.float32)
    qrow = jnp.asarray(rng.normal(size=(4, hd)), jnp.float32)
    q = jnp.stack([qrow, qrow])
    shared = [3, 7]
    bt = jnp.asarray([shared + [1, 2], shared + [5, 6]], jnp.int32)
    lens = jnp.asarray([2 * ps, 2 * ps], jnp.int32)  # only shared pages live
    out = paged_attention(q, k, v, bt, lens)
    np.testing.assert_allclose(out[0], out[1], atol=1e-6)


def test_paged_attention_length_masking(rng):
    """Tokens beyond `lengths` must not affect the result."""
    b, qh, kvh, hd, ps, pps = 1, 2, 1, 32, 4, 3
    npages = 6
    q = jnp.asarray(rng.normal(size=(b, qh, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(kvh, npages, ps, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(kvh, npages, ps, hd)), jnp.float32)
    bt = jnp.asarray([[0, 1, 2]], jnp.int32)
    lens = jnp.asarray([5], jnp.int32)
    base = paged_attention(q, k, v, bt, lens)
    k2 = k.at[:, 1, 3].set(99.0)  # token index 7 > length 5
    v2 = v.at[:, 1, 3].set(99.0)
    pert = paged_attention(q, k2, v2, bt, lens)
    np.testing.assert_allclose(base, pert, atol=1e-6)


@pytest.mark.parametrize("b,s,h,p,n,q", [
    (2, 32, 3, 16, 8, 8),
    (1, 64, 2, 32, 16, 16),
    (2, 40, 4, 8, 4, 16),
    (1, 16, 1, 64, 32, 4),
])
def test_ssd_kernel_shapes(rng, b, s, h, p, n, q):
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(b, s, h)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.5, 2, size=(h,)), jnp.float32)
    bb = jnp.asarray(rng.normal(size=(b, s, h, n)), jnp.float32)
    cc = jnp.asarray(rng.normal(size=(b, s, h, n)), jnp.float32)
    ref = ssd_scan_ref(x, dt, a, bb, cc)
    ker = ssd(x, dt, a, bb, cc, chunk=q)
    chk, _ = ssd_chunked(x, dt, a, bb, cc, chunk=q)
    np.testing.assert_allclose(ker, ref, atol=2e-4)
    np.testing.assert_allclose(chk, ref, atol=2e-4)


def test_ssd_kernel_nondivisible_padding(rng):
    x = jnp.asarray(rng.normal(size=(1, 13, 2, 8)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(1, 13, 2)), jnp.float32)
    a = -jnp.ones((2,), jnp.float32)
    bb = jnp.asarray(rng.normal(size=(1, 13, 2, 4)), jnp.float32)
    cc = jnp.asarray(rng.normal(size=(1, 13, 2, 4)), jnp.float32)
    ref = ssd_scan_ref(x, dt, a, bb, cc)
    ker = ssd(x, dt, a, bb, cc, chunk=8)
    np.testing.assert_allclose(ker, ref, atol=2e-4)


@pytest.mark.parametrize("vl", [1, 7, 11, 16])
def test_ssd_kernel_valid_mask_matches_unpadded_prefix(rng, vl):
    """Masked-dt through the kernel wrapper: ssd(..., valid=mask) over a
    right-padded sequence must reproduce the unpadded scan at every valid
    position — pad positions are identity transitions that contribute
    nothing downstream (same contract the serving chunk lane relies on)."""
    x = jnp.asarray(rng.normal(size=(2, 16, 2, 8)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(2, 16, 2)), jnp.float32)
    a = -jnp.ones((2,), jnp.float32)
    bb = jnp.asarray(rng.normal(size=(2, 16, 2, 4)), jnp.float32)
    cc = jnp.asarray(rng.normal(size=(2, 16, 2, 4)), jnp.float32)
    valid = jnp.arange(16)[None, :] < vl
    for use_kernel in (True, False):
        got = ssd(x, dt, a, bb, cc, chunk=8, use_kernel=use_kernel,
                  valid=valid)
        want = ssd(x[:, :vl], dt[:, :vl], a, bb[:, :vl], cc[:, :vl],
                   chunk=8, use_kernel=use_kernel)
        np.testing.assert_allclose(got[:, :vl], want, atol=2e-4)


def test_ssd_kernel_bf16(rng):
    x = jnp.asarray(rng.normal(size=(1, 16, 2, 8))).astype(jnp.bfloat16)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(1, 16, 2))).astype(jnp.bfloat16)
    a = -jnp.ones((2,), jnp.float32)
    bb = jnp.asarray(rng.normal(size=(1, 16, 2, 4))).astype(jnp.bfloat16)
    cc = jnp.asarray(rng.normal(size=(1, 16, 2, 4))).astype(jnp.bfloat16)
    ker = ssd(x, dt, a, bb, cc, chunk=8)
    ref = ssd_scan_ref(x.astype(jnp.float32), dt.astype(jnp.float32), a,
                       bb.astype(jnp.float32), cc.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(ker, np.float32), ref, atol=0.15)


@pytest.mark.parametrize("b,s,h,hd,bq,bk", [
    (2, 64, 4, 64, 16, 16),
    (1, 128, 2, 128, 32, 64),
    (2, 48, 3, 32, 16, 16),
    (1, 100, 2, 64, 32, 32),   # non-divisible: causal padding path
])
def test_flash_prefill_shapes(rng, b, s, h, hd, bq, bk):
    from repro.kernels.flash_prefill.ops import flash_attention
    from repro.kernels.flash_prefill.ref import flash_prefill_ref
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    out = flash_attention(q, k, v, block_q=bq, block_k=bk)
    ref = flash_prefill_ref(q, k, v)
    np.testing.assert_allclose(out, ref, atol=2e-4)


def test_flash_prefill_bf16(rng):
    from repro.kernels.flash_prefill.ops import flash_attention
    from repro.kernels.flash_prefill.ref import flash_prefill_ref
    q = jnp.asarray(rng.normal(size=(1, 64, 2, 64))).astype(jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 64, 2, 64))).astype(jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 64, 2, 64))).astype(jnp.bfloat16)
    out = flash_attention(q, k, v, block_q=16, block_k=16)
    ref = flash_prefill_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                            v.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(out, np.float32), ref, atol=5e-2)


def test_flash_prefill_noncausal(rng):
    from repro.kernels.flash_prefill.ops import flash_attention
    from repro.kernels.flash_prefill.ref import flash_prefill_ref
    q = jnp.asarray(rng.normal(size=(1, 32, 2, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 32, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 32, 2, 32)), jnp.float32)
    out = flash_attention(q, k, v, causal=False, block_q=16, block_k=16)
    ref = flash_prefill_ref(q, k, v, causal=False)
    np.testing.assert_allclose(out, ref, atol=2e-4)
