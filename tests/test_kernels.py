"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_decode_ref
from repro.kernels.ssd_scan.ops import ssd
from repro.kernels.ssd_scan.ref import ssd_scan_ref
from repro.models.mamba2 import ssd_chunked


@pytest.mark.parametrize("b,qh,kvh,hd,ps,pps", [
    (2, 4, 2, 64, 8, 4),
    (3, 8, 8, 128, 16, 3),
    (1, 8, 1, 256, 8, 5),
    (4, 2, 2, 32, 4, 8),
])
def test_paged_attention_shapes(rng, b, qh, kvh, hd, ps, pps):
    npages = b * pps + 2
    q = jnp.asarray(rng.normal(size=(b, qh, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(kvh, npages, ps, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(kvh, npages, ps, hd)), jnp.float32)
    bt = jnp.asarray(rng.integers(0, npages, size=(b, pps)), jnp.int32)
    lens = jnp.asarray(rng.integers(1, pps * ps + 1, size=(b,)), jnp.int32)
    out = paged_attention(q, k, v, bt, lens)
    ref = paged_attention_decode_ref(q, k, v, bt, lens)
    np.testing.assert_allclose(out, ref, atol=2e-4)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 2e-4),
                                        (jnp.bfloat16, 5e-2)])
def test_paged_attention_dtypes(rng, dtype, atol):
    b, qh, kvh, hd, ps, pps = 2, 4, 2, 64, 8, 4
    npages = 16
    q = jnp.asarray(rng.normal(size=(b, qh, hd))).astype(dtype)
    k = jnp.asarray(rng.normal(size=(kvh, npages, ps, hd))).astype(dtype)
    v = jnp.asarray(rng.normal(size=(kvh, npages, ps, hd))).astype(dtype)
    bt = jnp.asarray(rng.integers(0, npages, size=(b, pps)), jnp.int32)
    lens = jnp.asarray(rng.integers(1, pps * ps, size=(b,)), jnp.int32)
    out = paged_attention(q, k, v, bt, lens)
    ref = paged_attention_decode_ref(q, k, v, bt, lens)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=atol)


def test_paged_attention_shared_prefix(rng):
    """Two sequences whose block tables share prefix pages: identical
    prefix + identical query => identical output."""
    kvh, hd, ps, pps = 2, 64, 8, 4
    npages = 12
    k = jnp.asarray(rng.normal(size=(kvh, npages, ps, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(kvh, npages, ps, hd)), jnp.float32)
    qrow = jnp.asarray(rng.normal(size=(4, hd)), jnp.float32)
    q = jnp.stack([qrow, qrow])
    shared = [3, 7]
    bt = jnp.asarray([shared + [1, 2], shared + [5, 6]], jnp.int32)
    lens = jnp.asarray([2 * ps, 2 * ps], jnp.int32)  # only shared pages live
    out = paged_attention(q, k, v, bt, lens)
    np.testing.assert_allclose(out[0], out[1], atol=1e-6)


def test_paged_attention_length_masking(rng):
    """Tokens beyond `lengths` must not affect the result."""
    b, qh, kvh, hd, ps, pps = 1, 2, 1, 32, 4, 3
    npages = 6
    q = jnp.asarray(rng.normal(size=(b, qh, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(kvh, npages, ps, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(kvh, npages, ps, hd)), jnp.float32)
    bt = jnp.asarray([[0, 1, 2]], jnp.int32)
    lens = jnp.asarray([5], jnp.int32)
    base = paged_attention(q, k, v, bt, lens)
    k2 = k.at[:, 1, 3].set(99.0)  # token index 7 > length 5
    v2 = v.at[:, 1, 3].set(99.0)
    pert = paged_attention(q, k2, v2, bt, lens)
    np.testing.assert_allclose(base, pert, atol=1e-6)


@pytest.mark.parametrize("b,s,h,p,n,q", [
    (2, 32, 3, 16, 8, 8),
    (1, 64, 2, 32, 16, 16),
    (2, 40, 4, 8, 4, 16),
    (1, 16, 1, 64, 32, 4),
])
def test_ssd_kernel_shapes(rng, b, s, h, p, n, q):
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(b, s, h)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.5, 2, size=(h,)), jnp.float32)
    bb = jnp.asarray(rng.normal(size=(b, s, h, n)), jnp.float32)
    cc = jnp.asarray(rng.normal(size=(b, s, h, n)), jnp.float32)
    ref = ssd_scan_ref(x, dt, a, bb, cc)
    ker = ssd(x, dt, a, bb, cc, chunk=q)
    chk, _ = ssd_chunked(x, dt, a, bb, cc, chunk=q)
    np.testing.assert_allclose(ker, ref, atol=2e-4)
    np.testing.assert_allclose(chk, ref, atol=2e-4)


def test_ssd_kernel_nondivisible_padding(rng):
    x = jnp.asarray(rng.normal(size=(1, 13, 2, 8)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(1, 13, 2)), jnp.float32)
    a = -jnp.ones((2,), jnp.float32)
    bb = jnp.asarray(rng.normal(size=(1, 13, 2, 4)), jnp.float32)
    cc = jnp.asarray(rng.normal(size=(1, 13, 2, 4)), jnp.float32)
    ref = ssd_scan_ref(x, dt, a, bb, cc)
    ker = ssd(x, dt, a, bb, cc, chunk=8)
    np.testing.assert_allclose(ker, ref, atol=2e-4)


@pytest.mark.parametrize("vl", [1, 7, 11, 16])
def test_ssd_kernel_valid_mask_matches_unpadded_prefix(rng, vl):
    """Masked-dt through the kernel wrapper: ssd(..., valid=mask) over a
    right-padded sequence must reproduce the unpadded scan at every valid
    position — pad positions are identity transitions that contribute
    nothing downstream (same contract the serving chunk lane relies on)."""
    x = jnp.asarray(rng.normal(size=(2, 16, 2, 8)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(2, 16, 2)), jnp.float32)
    a = -jnp.ones((2,), jnp.float32)
    bb = jnp.asarray(rng.normal(size=(2, 16, 2, 4)), jnp.float32)
    cc = jnp.asarray(rng.normal(size=(2, 16, 2, 4)), jnp.float32)
    valid = jnp.arange(16)[None, :] < vl
    for use_kernel in (True, False):
        got = ssd(x, dt, a, bb, cc, chunk=8, use_kernel=use_kernel,
                  valid=valid)
        want = ssd(x[:, :vl], dt[:, :vl], a, bb[:, :vl], cc[:, :vl],
                   chunk=8, use_kernel=use_kernel)
        np.testing.assert_allclose(got[:, :vl], want, atol=2e-4)


def test_ssd_kernel_bf16(rng):
    x = jnp.asarray(rng.normal(size=(1, 16, 2, 8))).astype(jnp.bfloat16)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(1, 16, 2))).astype(jnp.bfloat16)
    a = -jnp.ones((2,), jnp.float32)
    bb = jnp.asarray(rng.normal(size=(1, 16, 2, 4))).astype(jnp.bfloat16)
    cc = jnp.asarray(rng.normal(size=(1, 16, 2, 4))).astype(jnp.bfloat16)
    ker = ssd(x, dt, a, bb, cc, chunk=8)
    ref = ssd_scan_ref(x.astype(jnp.float32), dt.astype(jnp.float32), a,
                       bb.astype(jnp.float32), cc.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(ker, np.float32), ref, atol=0.15)


@pytest.mark.parametrize("b,s,h,hd,bq,bk", [
    (2, 64, 4, 64, 16, 16),
    (1, 128, 2, 128, 32, 64),
    (2, 48, 3, 32, 16, 16),
    (1, 100, 2, 64, 32, 32),   # non-divisible: causal padding path
])
def test_flash_prefill_shapes(rng, b, s, h, hd, bq, bk):
    from repro.kernels.flash_prefill.ops import flash_attention
    from repro.kernels.flash_prefill.ref import flash_prefill_ref
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    out = flash_attention(q, k, v, block_q=bq, block_k=bk)
    ref = flash_prefill_ref(q, k, v)
    np.testing.assert_allclose(out, ref, atol=2e-4)


def test_flash_prefill_bf16(rng):
    from repro.kernels.flash_prefill.ops import flash_attention
    from repro.kernels.flash_prefill.ref import flash_prefill_ref
    q = jnp.asarray(rng.normal(size=(1, 64, 2, 64))).astype(jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 64, 2, 64))).astype(jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 64, 2, 64))).astype(jnp.bfloat16)
    out = flash_attention(q, k, v, block_q=16, block_k=16)
    ref = flash_prefill_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                            v.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(out, np.float32), ref, atol=5e-2)


def test_flash_prefill_noncausal(rng):
    from repro.kernels.flash_prefill.ops import flash_attention
    from repro.kernels.flash_prefill.ref import flash_prefill_ref
    q = jnp.asarray(rng.normal(size=(1, 32, 2, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 32, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 32, 2, 32)), jnp.float32)
    out = flash_attention(q, k, v, causal=False, block_q=16, block_k=16)
    ref = flash_prefill_ref(q, k, v, causal=False)
    np.testing.assert_allclose(out, ref, atol=2e-4)


def test_flash_prefill_noncausal_nondivisible(rng):
    """Regression: internal padding used to require causal masking (the
    wrapper asserted), so non-causal non-divisible shapes crashed. Now the
    kernel masks padded key columns explicitly for either mode."""
    from repro.kernels.flash_prefill.ops import flash_attention
    from repro.kernels.flash_prefill.ref import flash_prefill_ref
    q = jnp.asarray(rng.normal(size=(1, 37, 2, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 37, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 37, 2, 32)), jnp.float32)
    out = flash_attention(q, k, v, causal=False, block_q=16, block_k=16)
    ref = flash_prefill_ref(q, k, v, causal=False)
    np.testing.assert_allclose(out, ref, atol=2e-4)


@pytest.mark.parametrize("h,hkv", [(4, 2), (8, 1), (6, 3)])
def test_flash_prefill_gqa_native_kv(rng, h, hkv):
    """Regression for Hkv < H: KV stays [B, S, Hkv, hd] and the kernel
    indexes the head group in the BlockSpec — callers never pre-repeat
    (which doubled KV HBM traffic and broke silently when forgotten)."""
    from repro.kernels.flash_prefill.ops import flash_attention
    from repro.kernels.flash_prefill.ref import flash_prefill_ref
    b, s, hd = 2, 48, 32
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, hkv, hd)), jnp.float32)
    out = flash_attention(q, k, v, block_q=16, block_k=16)
    ref = flash_prefill_ref(q, k, v)
    np.testing.assert_allclose(out, ref, atol=2e-4)
    # pre-repeated KV must agree with the native-GQA call
    rep = flash_attention(q, jnp.repeat(k, h // hkv, 2),
                          jnp.repeat(v, h // hkv, 2),
                          block_q=16, block_k=16)
    np.testing.assert_allclose(out, rep, atol=1e-6)


def test_flash_prefill_masked_rows_exact_zeros(rng):
    """Regression: fully-masked (pad) query rows used to emit mis-normalized
    garbage (denominator clamped to 1e-30, or exp(-inf - -inf) = 1 claims).
    With an explicit row-validity mask they are exact zeros."""
    from repro.kernels.flash_prefill.flash_prefill import flash_prefill
    from repro.kernels.flash_prefill.ref import flash_prefill_ref
    q = jnp.asarray(rng.normal(size=(1, 16, 2, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 16, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 16, 2, 32)), jnp.float32)
    for causal in (True, False):
        out = flash_prefill(q, k, v, causal=causal, block_q=8, block_k=8,
                            interpret=True, true_len=13)
        ref = flash_prefill_ref(q[:, :13], k[:, :13], v[:, :13],
                                causal=causal)
        np.testing.assert_allclose(out[:, :13], ref, atol=2e-4)
        assert np.all(np.asarray(out)[:, 13:] == 0.0)


# ---------------------------------------------------------------------------
# fused paged flash-prefill (the mixed-step chunk-row kernel)


def _paged_setup(rng, t, pos0, valid, qh, kvh, hd, ps, dtype=jnp.float32):
    """Random paged KV + a block table shaped like the engine's: real pages
    cover positions 0..pos0+valid-1 (in permuted order), the rest of the
    static-width table is the OOB sentinel (== num_pages)."""
    need = -(-(pos0 + valid) // ps)
    npages = need + 2
    pps = need + 3
    kp = jnp.asarray(rng.normal(size=(kvh, npages, ps, hd))).astype(dtype)
    vp = jnp.asarray(rng.normal(size=(kvh, npages, ps, hd))).astype(dtype)
    bt = np.full((pps,), npages, np.int32)
    bt[:need] = rng.permutation(npages)[:need]
    q = jnp.asarray(rng.normal(size=(t, qh, hd))).astype(dtype)
    return q, kp, vp, jnp.asarray(bt)


@pytest.mark.parametrize("t,pos0,valid,qh,kvh,hd,ps,bq", [
    (8, 0, 8, 4, 2, 32, 4, 8),     # fresh prompt, GQA
    (8, 5, 8, 4, 4, 32, 4, 4),     # chunk straddles a page boundary
    (8, 13, 3, 8, 2, 64, 8, 8),    # bucket-pad rows (valid < t)
    (16, 7, 11, 4, 1, 32, 4, 4),   # MQA, ragged, multiple q blocks
    (8, 16, 8, 2, 2, 16, 8, 8),    # chunk starts exactly at a page edge
    (6, 3, 5, 4, 2, 32, 4, 4),     # t not a block_q multiple: wrapper pads
])
def test_paged_flash_prefill_vs_ref(rng, t, pos0, valid, qh, kvh, hd, ps,
                                    bq):
    from repro.kernels.flash_prefill.ops import paged_flash_prefill
    from repro.kernels.flash_prefill.ref import paged_flash_prefill_ref
    q, kp, vp, bt = _paged_setup(rng, t, pos0, valid, qh, kvh, hd, ps)
    out = paged_flash_prefill(q, kp, vp, bt, jnp.int32(pos0),
                              jnp.int32(valid), block_q=bq)
    ref = paged_flash_prefill_ref(q, kp, vp, bt, jnp.int32(pos0),
                                  jnp.int32(valid))
    np.testing.assert_allclose(out, ref, atol=2e-4)
    # bucket-pad rows are exact zeros, never near-zero residue
    assert np.all(np.asarray(out)[valid:] == 0.0)


@pytest.mark.parametrize("t,pos0,valid,qh,kvh,hd,ps", [
    (8, 5, 8, 4, 2, 32, 4),
    (8, 13, 3, 8, 2, 64, 8),
    (16, 7, 11, 4, 4, 32, 4),
])
def test_paged_flash_prefill_matches_decode_path(rng, t, pos0, valid, qh,
                                                 kvh, hd, ps):
    """The fused kernel and the per-token flash-decode path are the same
    math: row i of the chunk == a decode call with length pos0 + i + 1
    against the same block table."""
    from repro.kernels.flash_prefill.ops import paged_flash_prefill
    from repro.kernels.paged_attention.ref import paged_attention_decode_ref
    q, kp, vp, bt = _paged_setup(rng, t, pos0, valid, qh, kvh, hd, ps)
    out = paged_flash_prefill(q, kp, vp, bt, jnp.int32(pos0),
                              jnp.int32(valid))
    bt_rows = jnp.broadcast_to(bt, (t, bt.shape[0]))
    lens = pos0 + jnp.arange(t) + 1
    dec = paged_attention_decode_ref(q, kp, vp, bt_rows, lens)
    np.testing.assert_allclose(np.asarray(out)[:valid],
                               np.asarray(dec)[:valid], atol=2e-4)


def test_paged_flash_prefill_bf16(rng):
    from repro.kernels.flash_prefill.ops import paged_flash_prefill
    from repro.kernels.flash_prefill.ref import paged_flash_prefill_ref
    q, kp, vp, bt = _paged_setup(rng, 8, 5, 8, 4, 2, 32, 4,
                                 dtype=jnp.bfloat16)
    out = paged_flash_prefill(q, kp, vp, bt, jnp.int32(5), jnp.int32(8),
                              block_q=4)
    ref = paged_flash_prefill_ref(q, kp, vp, bt, jnp.int32(5), jnp.int32(8))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=5e-2)


def test_paged_flash_prefill_ignores_poisoned_future_pages(rng):
    """Keys past a row's causal horizon — slots the chunk has not written
    yet and sentinel-table garbage — must not affect its output."""
    from repro.kernels.flash_prefill.ops import paged_flash_prefill
    t, pos0, valid, qh, kvh, hd, ps = 8, 5, 6, 4, 2, 32, 4
    q, kp, vp, bt = _paged_setup(rng, t, pos0, valid, qh, kvh, hd, ps)
    base = paged_flash_prefill(q, kp, vp, bt, jnp.int32(pos0),
                               jnp.int32(valid), block_q=4)
    # poison every slot at absolute positions >= pos0 + valid
    npages = kp.shape[1]
    flat = np.zeros((npages * ps,), bool)
    for pos in range(pos0 + valid, (npages - 2) * ps):
        flat[int(bt[pos // ps]) * ps + pos % ps] = True
    poison = jnp.asarray(flat).reshape(npages, ps)
    kp2 = jnp.where(poison[None, :, :, None], 1e4, kp)
    vp2 = jnp.where(poison[None, :, :, None], 1e4, vp)
    pert = paged_flash_prefill(q, kp2, vp2, bt, jnp.int32(pos0),
                               jnp.int32(valid), block_q=4)
    np.testing.assert_allclose(base, pert, atol=1e-6)


def test_mixed_step_bytes_fused_strictly_fewer():
    """Acceptance: at chunk >= 256 and context >= 2048 the fused path reads
    strictly fewer K/V bytes than the per-token flash-decode loop (the
    O(chunk · context) -> O(q_blocks · context) collapse)."""
    from repro.kernels.flash_prefill.ops import mixed_step_bytes_read
    for chunk, ctx in [(256, 2048), (256, 4096), (512, 2048)]:
        dec = mixed_step_bytes_read(chunk, ctx, 16, 8, 64, path="decode")
        fus = mixed_step_bytes_read(chunk, ctx, 16, 8, 64, path="fused")
        assert fus < dec, (chunk, ctx, fus, dec)
        # one 128-row q block streams the context once, not 128 times
        assert dec > 50 * fus, (chunk, ctx, fus, dec)


# ---------------------------------------------------------------------------
# tree/cascade decode attention (shared-ancestor pass + per-branch suffix
# pass, merged by online-softmax partials). Differential backbone: the tree
# kernel, the tree jnp ref, the per-branch decode kernel and the per-branch
# decode ref over the SAME reconstructed full tables must all agree. All
# lengths are >= 1: the engine always attends at least the current token,
# and at length 0 the refs' uniform-softmax convention diverges from the
# kernels' exact-zero rows by design.


def _tree_topology(rng, groups, singles, *, qh, kvh, hd, ps):
    """Build a fork topology and every table the four paths consume.

    ``groups``: list of ``(shared_pages, [branch_len_tokens, ...])`` fork
    groups — each branch holds the group's shared ancestor pages plus a
    private suffix covering its remaining tokens. ``singles``: lengths of
    ungrouped rows (full table stays in ``branch_bt``). Page ids are
    distinct across the whole topology; tables are sentinel-padded to a
    common static width with one guaranteed pad column.
    """
    next_page = 0

    def take(n):
        nonlocal next_page
        ids = list(range(next_page, next_page + n))
        next_page += n
        return ids

    full_tables, lengths, group_of, shared_of = [], [], [], []
    for gi, (ns, br_lens) in enumerate(groups):
        sp = take(ns)
        shared_of.append(sp)
        for tokens in br_lens:
            suffix = max(tokens - ns * ps, 0)
            sfx = take(-(-suffix // ps)) if suffix else []
            full_tables.append(sp + sfx)
            lengths.append(tokens)
            group_of.append(gi)
    for tokens in singles:
        full_tables.append(take(-(-tokens // ps)))
        lengths.append(tokens)
        group_of.append(None)

    b = len(full_tables)
    num_pages = next_page + 2            # two never-referenced live pages
    pps = max(len(t) for t in full_tables) + 1   # >= 1 pad column
    full_bt = np.full((b, pps), num_pages, np.int32)
    shared_bt = np.full((b, pps), num_pages, np.int32)
    shared_lens = np.zeros((b,), np.int32)
    branch_bt = np.full((b, pps), num_pages, np.int32)
    row_group = np.full((b,), b, np.int32)
    for i, pages in enumerate(full_tables):
        full_bt[i, :len(pages)] = pages
        gi = group_of[i]
        if gi is None:
            branch_bt[i, :len(pages)] = pages
            continue
        row_group[i] = gi
        sp = shared_of[gi]
        shared_bt[gi, :len(sp)] = sp
        shared_lens[gi] = len(sp) * ps
        sfx = pages[len(sp):]
        branch_bt[i, :len(sfx)] = sfx

    q = jnp.asarray(rng.normal(size=(b, qh, hd)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(kvh, num_pages, ps, hd)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(kvh, num_pages, ps, hd)), jnp.float32)
    return dict(q=q, kp=kp, vp=vp, row_group=jnp.asarray(row_group),
                shared_bt=jnp.asarray(shared_bt),
                shared_lens=jnp.asarray(shared_lens),
                branch_bt=jnp.asarray(branch_bt),
                full_bt=jnp.asarray(full_bt),
                lengths=jnp.asarray(lengths, jnp.int32),
                full_tables=full_tables, group_of=group_of,
                shared_of=shared_of, num_pages=num_pages, ps=ps)


def _tree_all_paths(t):
    """(tree kernel, tree ref, per-branch kernel, per-branch ref)."""
    from repro.kernels.paged_attention.ops import paged_tree_attention
    from repro.kernels.paged_attention.ref import paged_tree_attention_ref
    tree_args = (t["q"], t["kp"], t["vp"], t["row_group"], t["shared_bt"],
                 t["shared_lens"], t["branch_bt"], t["lengths"])
    return (paged_tree_attention(*tree_args),
            paged_tree_attention_ref(*tree_args),
            paged_attention(t["q"], t["kp"], t["vp"], t["full_bt"],
                            t["lengths"]),
            paged_attention_decode_ref(t["q"], t["kp"], t["vp"],
                                       t["full_bt"], t["lengths"]))


def _assert_tree_differential(t, atol=2e-4):
    ker, tref, pb_ker, pb_ref = _tree_all_paths(t)
    # the tree ref reconstructs the exact full tables: bit-identical to
    # the per-branch ref, not merely close
    np.testing.assert_array_equal(np.asarray(tref), np.asarray(pb_ref))
    np.testing.assert_allclose(ker, pb_ref, atol=atol)
    np.testing.assert_allclose(ker, pb_ker, atol=atol)


@pytest.mark.parametrize("qh,kvh", [(4, 2), (4, 1), (4, 4)])  # GQA/MQA/MHA
def test_tree_decode_matches_per_branch(rng, qh, kvh):
    """Mixed topology: a 3-way fork, a 2-way fork and a singleton, ragged
    suffix lengths, under every head regime."""
    t = _tree_topology(
        rng,
        groups=[(2, [2 * 4 + 5, 2 * 4 + 1, 2 * 4 + 9]),
                (1, [4 + 3, 4 + 4])],
        singles=[7],
        qh=qh, kvh=kvh, hd=32, ps=4)
    _assert_tree_differential(t)


def test_tree_decode_ragged_depths(rng):
    """Shared depths 1..3 pages across groups; one branch's context ends
    INSIDE its group's shared span (its suffix pass has zero pages and
    the shared pass must mask tokens past its own length)."""
    t = _tree_topology(
        rng,
        groups=[(3, [3 * 4 + 2, 2 * 4 + 1]),   # second row ends mid-span
                (2, [2 * 4 + 4, 2 * 4 + 7]),
                (1, [4 + 1, 4 + 2, 4 + 3])],
        singles=[],
        qh=4, kvh=2, hd=32, ps=4)
    _assert_tree_differential(t)


def test_tree_decode_fork_alignment(rng):
    """Boundary fork vs mid-page fork. A fork at a page boundary keeps
    the full prefix shared; a mid-page fork copies the straddling page
    into each branch (CoW), so only the floor-to-page prefix is shared
    and the straddled page rides in each suffix table."""
    # boundary: 2 shared pages, suffixes start exactly at token 8
    t = _tree_topology(rng, groups=[(2, [8 + 1, 8 + 2])], singles=[],
                       qh=4, kvh=2, hd=32, ps=4)
    _assert_tree_differential(t)
    # mid-page: fork at token 6 -> 1 shared page, the half-filled page is
    # private to each branch (distinct page ids, same logical prefix)
    t = _tree_topology(rng, groups=[(1, [4 + 6, 4 + 8])], singles=[],
                       qh=4, kvh=2, hd=32, ps=4)
    _assert_tree_differential(t)


def test_tree_decode_single_branch_degenerate(rng):
    """A 1-member fork group and a fully ungrouped batch must both
    reproduce the plain decode kernel bit-for-bit — the tree machinery
    degenerates to per-branch streaming."""
    t = _tree_topology(rng, groups=[(2, [2 * 4 + 3])], singles=[9, 5],
                       qh=4, kvh=2, hd=32, ps=4)
    ker, _tref, pb_ker, _pb_ref = _tree_all_paths(t)
    np.testing.assert_allclose(ker, pb_ker, atol=1e-6)
    # all-ungrouped: sentinel row_group, zero shared spans
    t2 = _tree_topology(rng, groups=[], singles=[13, 6, 2],
                        qh=4, kvh=2, hd=32, ps=4)
    ker2, _t2ref, pb_ker2, _ = _tree_all_paths(t2)
    np.testing.assert_array_equal(np.asarray(ker2), np.asarray(pb_ker2))


def test_tree_decode_poisoned_unshared_page_invariance(rng):
    """Pages a row does not own — other branches' suffixes and
    never-referenced pages — must not leak into its output through the
    shared pass's parked iterations or sentinel clamps. Poisoning branch
    B's suffix pages leaves every OTHER row bitwise unchanged."""
    t = _tree_topology(
        rng,
        groups=[(2, [2 * 4 + 5, 2 * 4 + 6, 2 * 4 + 2])],
        singles=[7],
        qh=4, kvh=2, hd=32, ps=4)
    from repro.kernels.paged_attention.ops import paged_tree_attention
    args = (t["row_group"], t["shared_bt"], t["shared_lens"],
            t["branch_bt"], t["lengths"])
    base = np.asarray(paged_tree_attention(t["q"], t["kp"], t["vp"], *args))
    victim = 1                           # poison this branch's suffix
    own = set(t["full_tables"][victim]) - set(t["shared_of"][0])
    # plus the two never-referenced live pages and the sentinel clamp
    # target (num_pages - 1 is never-referenced here by construction)
    poison = own | {t["num_pages"] - 2, t["num_pages"] - 1}
    assert not any(p in poison
                   for i, pages in enumerate(t["full_tables"])
                   if i != victim for p in pages)
    mask = np.zeros((t["num_pages"],), bool)
    mask[sorted(poison)] = True
    sel = jnp.asarray(mask)[None, :, None, None]
    kp2 = jnp.where(sel, 1e4, t["kp"])
    vp2 = jnp.where(sel, 1e4, t["vp"])
    pert = np.asarray(paged_tree_attention(t["q"], kp2, vp2, *args))
    rows = [i for i in range(base.shape[0]) if i != victim]
    np.testing.assert_array_equal(base[rows], pert[rows])


def test_tree_decode_bf16_pages(rng):
    """bf16 K/V pages through both passes (the engine's serving dtype)."""
    t = _tree_topology(rng, groups=[(2, [2 * 4 + 3, 2 * 4 + 6])],
                       singles=[5], qh=4, kvh=2, hd=32, ps=4)
    from repro.kernels.paged_attention.ops import paged_tree_attention
    kp = t["kp"].astype(jnp.bfloat16)
    vp = t["vp"].astype(jnp.bfloat16)
    out = paged_tree_attention(t["q"].astype(jnp.bfloat16), kp, vp,
                               t["row_group"], t["shared_bt"],
                               t["shared_lens"], t["branch_bt"],
                               t["lengths"])
    ref = paged_attention_decode_ref(t["q"], t["kp"], t["vp"],
                                     t["full_bt"], t["lengths"])
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref), atol=5e-2)


def test_tree_decode_grid_lattice_bounds():
    """STEP007-style containment proof over the tree grids' full grid ×
    scalar-case lattice, plus the negative control: stripping the
    sentinel clamp from the shared pass's KV map must be caught on the
    all-sentinel case."""
    import dataclasses
    import sys
    from pathlib import Path
    repo = Path(__file__).resolve().parents[1]
    if str(repo) not in sys.path:
        sys.path.insert(0, str(repo))
    from tools.stepcheck import bounds
    from tools.stepcheck.bounds import verify_kernel_grid
    from repro.kernels import paged_tree_branch_grid, paged_tree_shared_grid

    num_pages, ps, pps = 16, 4, 6
    for kvh in (1, 2, 4):
        kg = paged_tree_shared_grid(3, 4, 8, kvh, num_pages, ps, 3, pps)
        cases = bounds.tree_shared_cases(num_pages, ps, pps, 3)
        assert verify_kernel_grid(kg, cases) == []
        bg = paged_tree_branch_grid(3, 4, 8, kvh, num_pages, ps, pps)
        assert verify_kernel_grid(
            bg, bounds.tree_branch_cases(num_pages, ps, pps, 3)) == []

    kg = paged_tree_shared_grid(3, 4, 8, 2, num_pages, ps, 3, pps)
    broken = dataclasses.replace(kg, in_mappings=tuple(
        dataclasses.replace(
            m, index_map=lambda h, g, ki, sbt, sl: (h, sbt[g, ki], 0, 0))
        if m.name in ("k_pages", "v_pages") else m
        for m in kg.in_mappings))
    caught = verify_kernel_grid(
        broken, bounds.tree_shared_cases(num_pages, ps, pps, 3))
    assert {f.symbol for f in caught} == {"k_pages", "v_pages"}
    # the sentinel chase specifically: only the num_pages-1 clamp keeps
    # an all-sentinel (no fork groups) step in bounds
    sentinel = [c for c in bounds.tree_shared_cases(num_pages, ps, pps, 3)
                if c.name == "all-sentinel"]
    caught = verify_kernel_grid(broken, sentinel)
    assert any(f.rule == "STEP007" and "all-sentinel" in f.message
               for f in caught)
