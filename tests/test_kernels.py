"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_decode_ref
from repro.kernels.ssd_scan.ops import ssd
from repro.kernels.ssd_scan.ref import ssd_scan_ref
from repro.models.mamba2 import ssd_chunked


@pytest.mark.parametrize("b,qh,kvh,hd,ps,pps", [
    (2, 4, 2, 64, 8, 4),
    (3, 8, 8, 128, 16, 3),
    (1, 8, 1, 256, 8, 5),
    (4, 2, 2, 32, 4, 8),
])
def test_paged_attention_shapes(rng, b, qh, kvh, hd, ps, pps):
    npages = b * pps + 2
    q = jnp.asarray(rng.normal(size=(b, qh, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(kvh, npages, ps, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(kvh, npages, ps, hd)), jnp.float32)
    bt = jnp.asarray(rng.integers(0, npages, size=(b, pps)), jnp.int32)
    lens = jnp.asarray(rng.integers(1, pps * ps + 1, size=(b,)), jnp.int32)
    out = paged_attention(q, k, v, bt, lens)
    ref = paged_attention_decode_ref(q, k, v, bt, lens)
    np.testing.assert_allclose(out, ref, atol=2e-4)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 2e-4),
                                        (jnp.bfloat16, 5e-2)])
def test_paged_attention_dtypes(rng, dtype, atol):
    b, qh, kvh, hd, ps, pps = 2, 4, 2, 64, 8, 4
    npages = 16
    q = jnp.asarray(rng.normal(size=(b, qh, hd))).astype(dtype)
    k = jnp.asarray(rng.normal(size=(kvh, npages, ps, hd))).astype(dtype)
    v = jnp.asarray(rng.normal(size=(kvh, npages, ps, hd))).astype(dtype)
    bt = jnp.asarray(rng.integers(0, npages, size=(b, pps)), jnp.int32)
    lens = jnp.asarray(rng.integers(1, pps * ps, size=(b,)), jnp.int32)
    out = paged_attention(q, k, v, bt, lens)
    ref = paged_attention_decode_ref(q, k, v, bt, lens)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=atol)


def test_paged_attention_shared_prefix(rng):
    """Two sequences whose block tables share prefix pages: identical
    prefix + identical query => identical output."""
    kvh, hd, ps, pps = 2, 64, 8, 4
    npages = 12
    k = jnp.asarray(rng.normal(size=(kvh, npages, ps, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(kvh, npages, ps, hd)), jnp.float32)
    qrow = jnp.asarray(rng.normal(size=(4, hd)), jnp.float32)
    q = jnp.stack([qrow, qrow])
    shared = [3, 7]
    bt = jnp.asarray([shared + [1, 2], shared + [5, 6]], jnp.int32)
    lens = jnp.asarray([2 * ps, 2 * ps], jnp.int32)  # only shared pages live
    out = paged_attention(q, k, v, bt, lens)
    np.testing.assert_allclose(out[0], out[1], atol=1e-6)


def test_paged_attention_length_masking(rng):
    """Tokens beyond `lengths` must not affect the result."""
    b, qh, kvh, hd, ps, pps = 1, 2, 1, 32, 4, 3
    npages = 6
    q = jnp.asarray(rng.normal(size=(b, qh, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(kvh, npages, ps, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(kvh, npages, ps, hd)), jnp.float32)
    bt = jnp.asarray([[0, 1, 2]], jnp.int32)
    lens = jnp.asarray([5], jnp.int32)
    base = paged_attention(q, k, v, bt, lens)
    k2 = k.at[:, 1, 3].set(99.0)  # token index 7 > length 5
    v2 = v.at[:, 1, 3].set(99.0)
    pert = paged_attention(q, k2, v2, bt, lens)
    np.testing.assert_allclose(base, pert, atol=1e-6)


@pytest.mark.parametrize("b,s,h,p,n,q", [
    (2, 32, 3, 16, 8, 8),
    (1, 64, 2, 32, 16, 16),
    (2, 40, 4, 8, 4, 16),
    (1, 16, 1, 64, 32, 4),
])
def test_ssd_kernel_shapes(rng, b, s, h, p, n, q):
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(b, s, h)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.5, 2, size=(h,)), jnp.float32)
    bb = jnp.asarray(rng.normal(size=(b, s, h, n)), jnp.float32)
    cc = jnp.asarray(rng.normal(size=(b, s, h, n)), jnp.float32)
    ref = ssd_scan_ref(x, dt, a, bb, cc)
    ker = ssd(x, dt, a, bb, cc, chunk=q)
    chk, _ = ssd_chunked(x, dt, a, bb, cc, chunk=q)
    np.testing.assert_allclose(ker, ref, atol=2e-4)
    np.testing.assert_allclose(chk, ref, atol=2e-4)


def test_ssd_kernel_nondivisible_padding(rng):
    x = jnp.asarray(rng.normal(size=(1, 13, 2, 8)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(1, 13, 2)), jnp.float32)
    a = -jnp.ones((2,), jnp.float32)
    bb = jnp.asarray(rng.normal(size=(1, 13, 2, 4)), jnp.float32)
    cc = jnp.asarray(rng.normal(size=(1, 13, 2, 4)), jnp.float32)
    ref = ssd_scan_ref(x, dt, a, bb, cc)
    ker = ssd(x, dt, a, bb, cc, chunk=8)
    np.testing.assert_allclose(ker, ref, atol=2e-4)


@pytest.mark.parametrize("vl", [1, 7, 11, 16])
def test_ssd_kernel_valid_mask_matches_unpadded_prefix(rng, vl):
    """Masked-dt through the kernel wrapper: ssd(..., valid=mask) over a
    right-padded sequence must reproduce the unpadded scan at every valid
    position — pad positions are identity transitions that contribute
    nothing downstream (same contract the serving chunk lane relies on)."""
    x = jnp.asarray(rng.normal(size=(2, 16, 2, 8)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(2, 16, 2)), jnp.float32)
    a = -jnp.ones((2,), jnp.float32)
    bb = jnp.asarray(rng.normal(size=(2, 16, 2, 4)), jnp.float32)
    cc = jnp.asarray(rng.normal(size=(2, 16, 2, 4)), jnp.float32)
    valid = jnp.arange(16)[None, :] < vl
    for use_kernel in (True, False):
        got = ssd(x, dt, a, bb, cc, chunk=8, use_kernel=use_kernel,
                  valid=valid)
        want = ssd(x[:, :vl], dt[:, :vl], a, bb[:, :vl], cc[:, :vl],
                   chunk=8, use_kernel=use_kernel)
        np.testing.assert_allclose(got[:, :vl], want, atol=2e-4)


def test_ssd_kernel_bf16(rng):
    x = jnp.asarray(rng.normal(size=(1, 16, 2, 8))).astype(jnp.bfloat16)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(1, 16, 2))).astype(jnp.bfloat16)
    a = -jnp.ones((2,), jnp.float32)
    bb = jnp.asarray(rng.normal(size=(1, 16, 2, 4))).astype(jnp.bfloat16)
    cc = jnp.asarray(rng.normal(size=(1, 16, 2, 4))).astype(jnp.bfloat16)
    ker = ssd(x, dt, a, bb, cc, chunk=8)
    ref = ssd_scan_ref(x.astype(jnp.float32), dt.astype(jnp.float32), a,
                       bb.astype(jnp.float32), cc.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(ker, np.float32), ref, atol=0.15)


@pytest.mark.parametrize("b,s,h,hd,bq,bk", [
    (2, 64, 4, 64, 16, 16),
    (1, 128, 2, 128, 32, 64),
    (2, 48, 3, 32, 16, 16),
    (1, 100, 2, 64, 32, 32),   # non-divisible: causal padding path
])
def test_flash_prefill_shapes(rng, b, s, h, hd, bq, bk):
    from repro.kernels.flash_prefill.ops import flash_attention
    from repro.kernels.flash_prefill.ref import flash_prefill_ref
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    out = flash_attention(q, k, v, block_q=bq, block_k=bk)
    ref = flash_prefill_ref(q, k, v)
    np.testing.assert_allclose(out, ref, atol=2e-4)


def test_flash_prefill_bf16(rng):
    from repro.kernels.flash_prefill.ops import flash_attention
    from repro.kernels.flash_prefill.ref import flash_prefill_ref
    q = jnp.asarray(rng.normal(size=(1, 64, 2, 64))).astype(jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 64, 2, 64))).astype(jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 64, 2, 64))).astype(jnp.bfloat16)
    out = flash_attention(q, k, v, block_q=16, block_k=16)
    ref = flash_prefill_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                            v.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(out, np.float32), ref, atol=5e-2)


def test_flash_prefill_noncausal(rng):
    from repro.kernels.flash_prefill.ops import flash_attention
    from repro.kernels.flash_prefill.ref import flash_prefill_ref
    q = jnp.asarray(rng.normal(size=(1, 32, 2, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 32, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 32, 2, 32)), jnp.float32)
    out = flash_attention(q, k, v, causal=False, block_q=16, block_k=16)
    ref = flash_prefill_ref(q, k, v, causal=False)
    np.testing.assert_allclose(out, ref, atol=2e-4)


def test_flash_prefill_noncausal_nondivisible(rng):
    """Regression: internal padding used to require causal masking (the
    wrapper asserted), so non-causal non-divisible shapes crashed. Now the
    kernel masks padded key columns explicitly for either mode."""
    from repro.kernels.flash_prefill.ops import flash_attention
    from repro.kernels.flash_prefill.ref import flash_prefill_ref
    q = jnp.asarray(rng.normal(size=(1, 37, 2, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 37, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 37, 2, 32)), jnp.float32)
    out = flash_attention(q, k, v, causal=False, block_q=16, block_k=16)
    ref = flash_prefill_ref(q, k, v, causal=False)
    np.testing.assert_allclose(out, ref, atol=2e-4)


@pytest.mark.parametrize("h,hkv", [(4, 2), (8, 1), (6, 3)])
def test_flash_prefill_gqa_native_kv(rng, h, hkv):
    """Regression for Hkv < H: KV stays [B, S, Hkv, hd] and the kernel
    indexes the head group in the BlockSpec — callers never pre-repeat
    (which doubled KV HBM traffic and broke silently when forgotten)."""
    from repro.kernels.flash_prefill.ops import flash_attention
    from repro.kernels.flash_prefill.ref import flash_prefill_ref
    b, s, hd = 2, 48, 32
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, hkv, hd)), jnp.float32)
    out = flash_attention(q, k, v, block_q=16, block_k=16)
    ref = flash_prefill_ref(q, k, v)
    np.testing.assert_allclose(out, ref, atol=2e-4)
    # pre-repeated KV must agree with the native-GQA call
    rep = flash_attention(q, jnp.repeat(k, h // hkv, 2),
                          jnp.repeat(v, h // hkv, 2),
                          block_q=16, block_k=16)
    np.testing.assert_allclose(out, rep, atol=1e-6)


def test_flash_prefill_masked_rows_exact_zeros(rng):
    """Regression: fully-masked (pad) query rows used to emit mis-normalized
    garbage (denominator clamped to 1e-30, or exp(-inf - -inf) = 1 claims).
    With an explicit row-validity mask they are exact zeros."""
    from repro.kernels.flash_prefill.flash_prefill import flash_prefill
    from repro.kernels.flash_prefill.ref import flash_prefill_ref
    q = jnp.asarray(rng.normal(size=(1, 16, 2, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 16, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 16, 2, 32)), jnp.float32)
    for causal in (True, False):
        out = flash_prefill(q, k, v, causal=causal, block_q=8, block_k=8,
                            interpret=True, true_len=13)
        ref = flash_prefill_ref(q[:, :13], k[:, :13], v[:, :13],
                                causal=causal)
        np.testing.assert_allclose(out[:, :13], ref, atol=2e-4)
        assert np.all(np.asarray(out)[:, 13:] == 0.0)


# ---------------------------------------------------------------------------
# fused paged flash-prefill (the mixed-step chunk-row kernel)


def _paged_setup(rng, t, pos0, valid, qh, kvh, hd, ps, dtype=jnp.float32):
    """Random paged KV + a block table shaped like the engine's: real pages
    cover positions 0..pos0+valid-1 (in permuted order), the rest of the
    static-width table is the OOB sentinel (== num_pages)."""
    need = -(-(pos0 + valid) // ps)
    npages = need + 2
    pps = need + 3
    kp = jnp.asarray(rng.normal(size=(kvh, npages, ps, hd))).astype(dtype)
    vp = jnp.asarray(rng.normal(size=(kvh, npages, ps, hd))).astype(dtype)
    bt = np.full((pps,), npages, np.int32)
    bt[:need] = rng.permutation(npages)[:need]
    q = jnp.asarray(rng.normal(size=(t, qh, hd))).astype(dtype)
    return q, kp, vp, jnp.asarray(bt)


@pytest.mark.parametrize("t,pos0,valid,qh,kvh,hd,ps,bq", [
    (8, 0, 8, 4, 2, 32, 4, 8),     # fresh prompt, GQA
    (8, 5, 8, 4, 4, 32, 4, 4),     # chunk straddles a page boundary
    (8, 13, 3, 8, 2, 64, 8, 8),    # bucket-pad rows (valid < t)
    (16, 7, 11, 4, 1, 32, 4, 4),   # MQA, ragged, multiple q blocks
    (8, 16, 8, 2, 2, 16, 8, 8),    # chunk starts exactly at a page edge
    (6, 3, 5, 4, 2, 32, 4, 4),     # t not a block_q multiple: wrapper pads
])
def test_paged_flash_prefill_vs_ref(rng, t, pos0, valid, qh, kvh, hd, ps,
                                    bq):
    from repro.kernels.flash_prefill.ops import paged_flash_prefill
    from repro.kernels.flash_prefill.ref import paged_flash_prefill_ref
    q, kp, vp, bt = _paged_setup(rng, t, pos0, valid, qh, kvh, hd, ps)
    out = paged_flash_prefill(q, kp, vp, bt, jnp.int32(pos0),
                              jnp.int32(valid), block_q=bq)
    ref = paged_flash_prefill_ref(q, kp, vp, bt, jnp.int32(pos0),
                                  jnp.int32(valid))
    np.testing.assert_allclose(out, ref, atol=2e-4)
    # bucket-pad rows are exact zeros, never near-zero residue
    assert np.all(np.asarray(out)[valid:] == 0.0)


@pytest.mark.parametrize("t,pos0,valid,qh,kvh,hd,ps", [
    (8, 5, 8, 4, 2, 32, 4),
    (8, 13, 3, 8, 2, 64, 8),
    (16, 7, 11, 4, 4, 32, 4),
])
def test_paged_flash_prefill_matches_decode_path(rng, t, pos0, valid, qh,
                                                 kvh, hd, ps):
    """The fused kernel and the per-token flash-decode path are the same
    math: row i of the chunk == a decode call with length pos0 + i + 1
    against the same block table."""
    from repro.kernels.flash_prefill.ops import paged_flash_prefill
    from repro.kernels.paged_attention.ref import paged_attention_decode_ref
    q, kp, vp, bt = _paged_setup(rng, t, pos0, valid, qh, kvh, hd, ps)
    out = paged_flash_prefill(q, kp, vp, bt, jnp.int32(pos0),
                              jnp.int32(valid))
    bt_rows = jnp.broadcast_to(bt, (t, bt.shape[0]))
    lens = pos0 + jnp.arange(t) + 1
    dec = paged_attention_decode_ref(q, kp, vp, bt_rows, lens)
    np.testing.assert_allclose(np.asarray(out)[:valid],
                               np.asarray(dec)[:valid], atol=2e-4)


def test_paged_flash_prefill_bf16(rng):
    from repro.kernels.flash_prefill.ops import paged_flash_prefill
    from repro.kernels.flash_prefill.ref import paged_flash_prefill_ref
    q, kp, vp, bt = _paged_setup(rng, 8, 5, 8, 4, 2, 32, 4,
                                 dtype=jnp.bfloat16)
    out = paged_flash_prefill(q, kp, vp, bt, jnp.int32(5), jnp.int32(8),
                              block_q=4)
    ref = paged_flash_prefill_ref(q, kp, vp, bt, jnp.int32(5), jnp.int32(8))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=5e-2)


def test_paged_flash_prefill_ignores_poisoned_future_pages(rng):
    """Keys past a row's causal horizon — slots the chunk has not written
    yet and sentinel-table garbage — must not affect its output."""
    from repro.kernels.flash_prefill.ops import paged_flash_prefill
    t, pos0, valid, qh, kvh, hd, ps = 8, 5, 6, 4, 2, 32, 4
    q, kp, vp, bt = _paged_setup(rng, t, pos0, valid, qh, kvh, hd, ps)
    base = paged_flash_prefill(q, kp, vp, bt, jnp.int32(pos0),
                               jnp.int32(valid), block_q=4)
    # poison every slot at absolute positions >= pos0 + valid
    npages = kp.shape[1]
    flat = np.zeros((npages * ps,), bool)
    for pos in range(pos0 + valid, (npages - 2) * ps):
        flat[int(bt[pos // ps]) * ps + pos % ps] = True
    poison = jnp.asarray(flat).reshape(npages, ps)
    kp2 = jnp.where(poison[None, :, :, None], 1e4, kp)
    vp2 = jnp.where(poison[None, :, :, None], 1e4, vp)
    pert = paged_flash_prefill(q, kp2, vp2, bt, jnp.int32(pos0),
                               jnp.int32(valid), block_q=4)
    np.testing.assert_allclose(base, pert, atol=1e-6)


def test_mixed_step_bytes_fused_strictly_fewer():
    """Acceptance: at chunk >= 256 and context >= 2048 the fused path reads
    strictly fewer K/V bytes than the per-token flash-decode loop (the
    O(chunk · context) -> O(q_blocks · context) collapse)."""
    from repro.kernels.flash_prefill.ops import mixed_step_bytes_read
    for chunk, ctx in [(256, 2048), (256, 4096), (512, 2048)]:
        dec = mixed_step_bytes_read(chunk, ctx, 16, 8, 64, path="decode")
        fus = mixed_step_bytes_read(chunk, ctx, 16, 8, 64, path="fused")
        assert fus < dec, (chunk, ctx, fus, dec)
        # one 128-row q block streams the context once, not 128 times
        assert dec > 50 * fus, (chunk, ctx, fus, dec)
