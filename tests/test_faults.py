"""Chaos/fault-injection tests (docs/robustness.md): failure-domain
isolation, quarantine/backoff, engine restart, snapshot/restore, eviction
stall escalation, and fault-free bit-exactness of the injector.

The hypothesis-backed property tests fuzz random fault interleavings
(OutOfPages storms, step exceptions, corrupted logits, slow steps, hard
crash/restart) against both ``SimEngine`` and the live ``Engine``, and
assert the failure-domain contract: allocator refcount conservation and
the live/free/LRU partition hold at exit, and every submitted request is
terminally accounted — completed or quarantined, never dropped.
"""
import json

import jax
import numpy as np
import pytest

from repro.core import (OraclePRM, Scheduler, SchedulerConfig,
                        SchedulerFaultError)
from repro.data import tasks
from repro.data import tokenizer as tk
from repro.data.tasks import extract_answer
from repro.models import Model
from repro.serving import Engine, EngineConfig, SamplingParams
from repro.serving.faults import (EngineCrashFault, FaultInjector, FaultPlan,
                                  InjectedStepFault, PoisonedRequestFault)
from repro.serving.simulator import (SimEngine, SimEngineConfig, SimPRM,
                                     SimTask, SimWorkload,
                                     run_sim_experiment)

from conftest import tiny_config
from prop import given, settings, st

POISON = tk.STEP       # never appears in a normal prompt


def _digest(m, acc=None):
    """Trajectory fingerprint for bit-exactness comparisons."""
    recs = tuple(
        (r["request_id"], r["arrival"], r["first_service"], r["ttfb"],
         r["finish"], r["e2e"], r["num_completed"], r["num_pruned"],
         r["answer"], tuple(r["response_lengths"]))
        for r in m["requests"])
    return (m["clock"], m["decode_steps"],
            None if acc is None else round(acc, 6), recs)


def _sim_setup(num_requests=8, seed=0, plan=None, poison_idx=None,
               engine_kw=None, sched_kw=None, mean_len=80):
    """SimEngine + Scheduler (optionally fault-injected) with submitted
    requests; returns (inner_engine, scheduler)."""
    w = SimWorkload(mean_len=mean_len, sigma_len=0.5, prompt_len=64,
                    prm_drift=6.0, prm_noise=0.05)
    ec = SimEngineConfig(**{**dict(max_slots=32, page_size=8,
                                   num_pages=8192, prefill_chunk=32),
                            **(engine_kw or {})})
    eng = SimEngine(ec, w, seed=seed)
    driven = FaultInjector(eng, plan) if plan is not None else eng
    cfg = SchedulerConfig(policy="sart", n=4, window=20,
                          **(sched_kw or {}))
    sch = Scheduler(driven, SimPRM(eng), cfg, answer_fn=extract_answer)
    rng = np.random.default_rng(seed + 1)
    for i in range(num_requests):
        task = SimTask(answer=int(rng.integers(0, 10)))
        prompt = [tk.BOS] + [tk.digit(i % 10)] * 62 + [tk.EQUALS]
        if i == poison_idx:
            prompt[1] = POISON
        req = sch.submit(prompt, payload=task, arrival=i * 5)
        eng.tasks[req.request_id] = task
    return eng, sch


# ----------------------------------------------------------------- FaultPlan
def test_faultplan_parse_roundtrip():
    plan = FaultPlan.parse(
        "seed=3,step_rate=0.1,oop_rate=0.05,crash_at=50+120,"
        "poison_token=5,slow_rate=0.2,slow_penalty=4,admit_fail_rate=0.3")
    assert plan == FaultPlan(seed=3, step_rate=0.1, oop_rate=0.05,
                             crash_at=(50, 120), poison_token=5,
                             slow_rate=0.2, slow_penalty=4,
                             admit_fail_rate=0.3)
    assert plan.enabled
    assert not FaultPlan().enabled
    with pytest.raises(ValueError):
        FaultPlan.parse("no_such_field=1")


def test_injector_is_deterministic_and_delegates():
    """Same plan + same call sequence => same injected faults; all
    non-intercepted attributes resolve on the wrapped engine."""
    w = SimWorkload(mean_len=50, prompt_len=16)
    plan = FaultPlan(seed=5, step_rate=0.3)
    outcomes = []
    for _ in range(2):
        eng = SimEngine(SimEngineConfig(max_slots=4, page_size=8,
                                        num_pages=512, prefill_chunk=8),
                        w, seed=0)
        inj = FaultInjector(eng, plan)
        assert inj.cfg is eng.cfg and inj.allocator is eng.allocator
        st_ = inj.begin_prefill([tk.BOS] * 8)
        while not st_.done:
            inj.decode_step()
        blocks, lg, ssm = inj.finish_prefill(st_)
        inj.spawn_branch(0, blocks, lg, ssm, 8)
        run = []
        for _ in range(30):
            try:
                inj.decode_step()
                run.append("ok")
            except InjectedStepFault:
                run.append("fault")
        outcomes.append(tuple(run))
        assert "fault" in run and "ok" in run
    assert outcomes[0] == outcomes[1]


def test_injector_crash_then_restart():
    w = SimWorkload(mean_len=50, prompt_len=16)
    eng = SimEngine(SimEngineConfig(max_slots=4, page_size=8, num_pages=512,
                                    prefill_chunk=8), w, seed=0)
    inj = FaultInjector(eng, FaultPlan(crash_at=(1,)))
    st_ = inj.begin_prefill([tk.BOS] * 8)
    inj.decode_step()                      # step 0: chunk advances
    assert st_.done
    with pytest.raises(EngineCrashFault):
        inj.decode_step()                  # step 1: planned crash
    with pytest.raises(EngineCrashFault):
        inj.decode_step()                  # still down
    inj.restart()
    inj.decode_step()                      # back up
    assert inj.fault_stats()["crash"] == 1
    assert inj.fault_stats()["restarts"] == 1


# ----------------------------------------------------- fault-free bit-exact
def test_chaos_disabled_injector_is_bit_exact_sim():
    """Acceptance: with the injector disabled (empty plan), tokens and
    metrics are bit-exact with a no-injector run."""
    runs = []
    for plan in (None, FaultPlan()):
        m, acc = run_sim_experiment(
            "sart", 4, num_requests=10, workload=SimWorkload(
                mean_len=120, sigma_len=0.5, prompt_len=128, prompt_tail=16),
            engine_cfg=SimEngineConfig(max_slots=32, num_pages=65536,
                                       prefill_chunk=64,
                                       step_token_budget=128,
                                       prefix_cache=True),
            window=50, seed=0, arrival_times=[0, 0, 0, 20, 20, 40, 40,
                                              40, 60, 60],
            fault_plan=plan)
        runs.append(_digest(m, acc))
    assert runs[0] == runs[1]
    assert runs[0][0] > 0


# --------------------------------------------------- admission quarantining
def test_chaos_poisoned_admission_quarantines_not_drops():
    """Satellite regression: the seed popped the request in ``_admit_one``
    and let the exception crash ``run()`` — a poisoned prompt must end
    terminally quarantined with bounded retries, while every other
    request completes untouched."""
    plan = FaultPlan(seed=1, poison_token=POISON)
    eng, sch = _sim_setup(num_requests=6, plan=plan, poison_idx=2)
    m = sch.run()
    bad = m["requests"][2]
    assert bad["quarantined"] and bad["finish"] is None
    assert bad["retries"] == sch.cfg.retry_budget + 1
    assert sch.requests[2].quarantine_reason is not None
    assert "PoisonedRequestFault" in sch.requests[2].quarantine_reason
    for r in m["requests"]:
        if r["request_id"] != 2:
            assert not r["quarantined"] and r["finish"] is not None
    f = m["faults"]
    assert f["quarantined"] == 1 and f["quarantined_requests"] == 1
    assert f["retries"] == sch.cfg.retry_budget
    eng.allocator.check_invariants()
    assert eng.allocator.used_pages == 0


def test_chaos_transient_admission_fault_retries_with_backoff():
    """A transient begin_prefill failure retries with exponential backoff
    and eventually admits — the request recovers instead of quarantining."""
    plan = FaultPlan(seed=4, admit_fail_rate=0.5)
    eng, sch = _sim_setup(num_requests=6, plan=plan,
                          sched_kw=dict(retry_budget=10))
    m = sch.run()
    assert m["unfinished_requests"] == 0
    f = m["faults"]
    assert f["retries"] > 0 and f["quarantined"] == 0
    assert f["recovered"] >= 1          # a retried request finished
    retried = [r for r in m["requests"] if r["retries"] > 0]
    assert retried and all(r["finish"] is not None for r in retried)
    eng.allocator.check_invariants()


def test_chaos_backoff_is_exponential():
    """not_before grows as retry_backoff * 2**(retries-1) from the clock
    of each failure."""
    eng, sch = _sim_setup(num_requests=1)
    req = sch.requests[0]
    sch.clock = 100
    sch._quarantine_or_requeue(req, RuntimeError("x"))
    assert req.retries == 1
    assert req.not_before == 100 + sch.cfg.retry_backoff
    sch.clock = 200
    sch._quarantine_or_requeue(req, RuntimeError("x"))
    assert req.not_before == 200 + 2 * sch.cfg.retry_backoff
    sch.clock = 300
    sch._quarantine_or_requeue(req, RuntimeError("x"))
    assert req.not_before == 300 + 4 * sch.cfg.retry_backoff
    assert not req.quarantined
    sch._quarantine_or_requeue(req, RuntimeError("x"))  # budget exhausted
    assert req.quarantined


# ------------------------------------------------------- storms and restarts
def test_chaos_step_fault_storm_completes_all_nonpoisoned():
    """Acceptance: seeded plan with step-exception rate >= 10% plus a
    mid-run hard crash — every non-poisoned request completes (zero
    drops), allocator invariants hold at exit, and metrics carries the
    quarantine/retry/restart/recovered counters."""
    plan = FaultPlan(seed=3, step_rate=0.15, crash_at=(60,),
                     poison_token=POISON)
    eng, sch = _sim_setup(num_requests=8, plan=plan, poison_idx=5)
    m = sch.run()
    assert len(m["requests"]) == 8      # terminally accounted, no drops
    for r in m["requests"]:
        if r["request_id"] == 5:
            assert r["quarantined"]
        else:
            assert r["finish"] is not None
    f = m["faults"]
    for key in ("quarantined", "retries", "engine_restarts", "recovered",
                "step_faults", "requeued"):
        assert key in f
    assert f["engine_restarts"] >= 1    # the crash forced a restart
    assert f["recovered"] >= 1
    assert f["injected"]["crash"] == 1
    eng.allocator.check_invariants()
    assert eng.allocator.used_pages == 0


def test_chaos_crash_restart_preserves_completed_branches():
    """Branches completed before the crash keep their tokens/rewards;
    lost in-flight work resamples (completed count still reaches m)."""
    plan = FaultPlan(seed=2, crash_at=(100,))
    eng, sch = _sim_setup(num_requests=6, plan=plan, mean_len=150)
    m = sch.run()
    assert m["unfinished_requests"] == 0
    assert m["faults"]["engine_restarts"] >= 1
    for r in m["requests"]:
        assert r["num_completed"] >= 1
    eng.allocator.check_invariants()


def test_chaos_slow_steps_charge_clock():
    """Slow-step injection advances the scheduler clock by the penalty,
    so deadline pressure is real: the same workload finishes later."""
    clocks = {}
    for tag, plan in (("clean", None),
                      ("slow", FaultPlan(seed=6, slow_rate=0.5,
                                         slow_penalty=8))):
        m, _ = run_sim_experiment(
            "sart", 4, num_requests=6,
            workload=SimWorkload(mean_len=80, sigma_len=0.4, prompt_len=64),
            engine_cfg=SimEngineConfig(max_slots=32, num_pages=8192,
                                       page_size=8, prefill_chunk=32),
            window=20, seed=0, fault_plan=plan)
        clocks[tag] = m["clock"]
        assert m["unfinished_requests"] == 0
    assert clocks["slow"] > clocks["clean"]


def test_chaos_restart_budget_exhaustion_raises_diagnosable():
    """A fault that persists across max_engine_restarts propagates as
    SchedulerFaultError (with the cause chained) instead of restarting
    forever."""
    plan = FaultPlan(seed=0, step_rate=1.0)    # every step faults
    eng, sch = _sim_setup(num_requests=2, plan=plan,
                          sched_kw=dict(max_engine_restarts=2))
    with pytest.raises(SchedulerFaultError) as ei:
        sch.run()
    assert isinstance(ei.value.__cause__, InjectedStepFault)
    assert sch.fault_counters["engine_restarts"] == 2


# ------------------------------------------------------- eviction escalation
def test_evict_longest_escalates_past_shared_victim():
    """Satellite regression: when force-completing the longest branch
    frees zero pages (all its pages shared), eviction must escalate to
    the next victim instead of letting _decode_window spin on
    OutOfPagesError without progress."""
    w = SimWorkload(mean_len=10_000, sigma_len=0.1, prompt_len=16)
    eng = SimEngine(SimEngineConfig(max_slots=4, page_size=8, num_pages=3,
                                    prefill_chunk=16), w, seed=0)
    sch = Scheduler(eng, SimPRM(eng), SchedulerConfig(
        policy="sart", n=2, m=2, window=4, max_tokens=1 << 20),
        answer_fn=extract_answer)
    req = sch.submit([tk.BOS] * 16, payload=SimTask())
    eng.tasks[0] = SimTask()
    blocks, lg, ssm = eng.prefill(req.prompt)       # 2 of 3 pages
    parent = eng.spawn_branch(0, blocks, lg, ssm, 16)
    # decode the parent alone up to its page boundary: its third page is
    # private (refcount 1) until the fork below shares it
    for _ in range(8):
        eng.decode_step()
    assert parent.blocks.length == 24 and len(parent.blocks.pages) == 3
    child = eng.fork_branch(parent)                 # shares ALL 3 pages
    req.live = {parent.branch_id: parent, child.branch_id: child}
    req.prefix_blocks = blocks
    req.meta = sch.pruner.new_meta(4, 4)            # don't finalize at 2
    req.pending = 2
    assert eng.allocator.free_pages == 0
    # both branches sit at a page boundary: the next step needs 2 pages
    from repro.kv import OutOfPagesError
    with pytest.raises(OutOfPagesError):
        eng.decode_step()
    # pre-fix behavior completed ONE victim (the parent): every parent
    # page is still shared with the child, so zero pages free and the
    # window would retry OutOfPages forever. The fix escalates to the
    # child, whose release drops the generated page's last reference.
    assert sch._evict_longest() is True
    assert req.meta.num_completed == 2              # both victims evicted
    assert req.meta.num_truncated == 2
    assert eng.allocator.free_pages > 0
    eng.release_prefix(blocks)
    eng.allocator.check_invariants()


def test_evict_longest_reports_stall_when_nothing_freeable():
    """When no victim frees pages at all (every page shared with the
    request's own prefix), _evict_longest returns False so the caller
    can route the stall to the bounded engine-fault path — a diagnosable
    error instead of the pre-fix infinite spin."""
    w = SimWorkload(mean_len=10_000, sigma_len=0.1, prompt_len=16)
    eng = SimEngine(SimEngineConfig(max_slots=4, page_size=8, num_pages=2,
                                    prefill_chunk=16), w, seed=0)
    sch = Scheduler(eng, SimPRM(eng), SchedulerConfig(
        policy="sart", n=2, m=2, window=4, max_tokens=1 << 20),
        answer_fn=extract_answer)
    req = sch.submit([tk.BOS] * 16, payload=SimTask())
    eng.tasks[0] = SimTask()
    blocks, lg, ssm = eng.prefill(req.prompt)       # all pages used
    b1 = eng.spawn_branch(0, blocks, lg, ssm, 16)   # shares both pages
    b2 = eng.spawn_branch(0, blocks, lg, ssm, 16)
    req.live = {b1.branch_id: b1, b2.branch_id: b2}
    req.prefix_blocks = blocks
    req.meta = sch.pruner.new_meta(4, 4)
    req.pending = 2
    # every victim's pages stay referenced by the prefix: nothing frees
    assert sch._evict_longest() is False
    assert req.meta.num_truncated == 2
    # with no live branches left, eviction reports the stall immediately
    assert sch._evict_longest() is False
    eng.release_prefix(blocks)
    eng.allocator.check_invariants()


# ----------------------------------------------------------- truncated drain
def test_chaos_truncated_run_drains_prefilling():
    """Satellite regression: a run stopped at max_steps mid-prefill must
    abort the pending ChunkedPrefillStates (allocator invariants hold
    after every run) and requeue the requests, never drop them."""
    w = SimWorkload(mean_len=400, sigma_len=0.4, prompt_len=256)
    ec = SimEngineConfig(max_slots=8, page_size=8, num_pages=65536,
                         prefill_chunk=16)   # 16 chunk-steps per prompt
    eng = SimEngine(ec, w, seed=0)
    sch = Scheduler(eng, SimPRM(eng), SchedulerConfig(
        policy="sart", n=4, window=10, max_tokens=1 << 20),
        answer_fn=extract_answer)
    for i in range(4):
        t = SimTask()
        r = sch.submit([tk.BOS] + [tk.digit(i)] * 254 + [tk.EQUALS],
                       payload=t, arrival=i * 4)
        eng.tasks[r.request_id] = t
    m = sch.run(max_steps=8)                 # cap hits mid-prefill
    assert m["unfinished_requests"] > 0
    assert not sch.prefilling
    assert not eng.has_pending_prefill
    eng.allocator.check_invariants()
    # requeued, not dropped: every unfinished request is back in queue
    queued = {r.request_id for r in sch.request_queue}
    for r in m["requests"]:
        if r["finish"] is None:
            assert r["request_id"] in queued


# ---------------------------------------------------------- snapshot/restore
def test_chaos_snapshot_restore_roundtrip_completes():
    """Checkpoint/restore rescheduling: snapshot a half-done run, rebuild
    against a FRESH engine (KV pages gone), and drive to completion —
    completed branches, rewards, truncated flags and pruner meta survive;
    in-flight work resamples; nothing is dropped."""
    w = SimWorkload(mean_len=120, sigma_len=0.5, prompt_len=64,
                    prm_drift=6.0, prm_noise=0.05)
    ec = SimEngineConfig(max_slots=16, page_size=8, num_pages=8192,
                         prefill_chunk=32)
    eng = SimEngine(ec, w, seed=0)
    cfg = SchedulerConfig(policy="sart", n=4, window=20)
    sch = Scheduler(eng, SimPRM(eng), cfg, answer_fn=extract_answer)
    rng = np.random.default_rng(1)
    task_by_id = {}
    for i in range(6):
        t = SimTask(answer=int(rng.integers(0, 10)))
        r = sch.submit([tk.BOS] + [tk.digit(i)] * 62 + [tk.EQUALS],
                       payload=t, arrival=i * 5)
        eng.tasks[r.request_id] = t
        task_by_id[r.request_id] = t
    sch.run(max_steps=80)                    # half-done "crash point"
    snap = json.loads(json.dumps(sch.snapshot()))   # wire round-trip
    assert snap["version"] == 1 and snap["clock"] >= 80
    pre_completed = {r["request_id"]: [tuple(c[0]) for c in r["completed"]]
                     for r in snap["requests"]}

    eng2 = SimEngine(ec, w, seed=7)          # fresh engine: KV pages gone
    for rid, t in task_by_id.items():
        eng2.tasks[rid] = t                  # payloads re-attached by hand
    sch2 = Scheduler.restore(snap, eng2, SimPRM(eng2), cfg, extract_answer)
    assert sch2.clock == snap["clock"]
    m = sch2.run()
    assert m["unfinished_requests"] == 0
    assert len(m["requests"]) == 6
    eng2.allocator.check_invariants()
    assert eng2.allocator.used_pages == 0
    # pre-crash completed branches retained verbatim in the final record
    for req in sch2.requests.values():
        kept = [tuple(t_) for t_, _, _ in req.completed]
        for tokens in pre_completed[req.request_id]:
            assert tokens in kept
    # submit() keeps numbering from the snapshot
    assert sch2._next_request_id == snap["next_request_id"]


def test_chaos_snapshot_rejects_unknown_version():
    eng, sch = _sim_setup(num_requests=1)
    snap = sch.snapshot()
    snap["version"] = 99
    with pytest.raises(ValueError):
        Scheduler.restore(snap, eng, SimPRM(eng), sch.cfg, extract_answer)


# -------------------------------------------------------- property: sim chaos
@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000),
       st.floats(0.0, 0.25),
       st.floats(0.0, 0.2),
       st.booleans(),
       st.booleans())
def test_chaos_property_sim_interleavings(seed, step_rate, oop_rate,
                                          crash, cached):
    """Random fault interleavings against SimEngine: allocator refcount
    conservation + live/free/LRU partition hold at exit, and every
    submitted request is terminally accounted (completed or quarantined,
    never dropped)."""
    plan = FaultPlan(seed=seed, step_rate=step_rate, oop_rate=oop_rate,
                     nan_rate=step_rate / 2, slow_rate=oop_rate,
                     crash_at=(40 + seed % 60,) if crash else (),
                     poison_token=POISON)
    eng, sch = _sim_setup(
        num_requests=6, seed=seed % 7, plan=plan,
        poison_idx=seed % 6 if seed % 3 == 0 else None,
        engine_kw=dict(prefix_cache=cached, num_pages=4096))
    try:
        m = sch.run(max_steps=100_000)
    except SchedulerFaultError:
        # persistent-fault escape hatch: allowed, but never a hang — and
        # the allocator must still satisfy its invariants
        eng.allocator.check_invariants()
        return
    assert len(m["requests"]) == 6
    for r in m["requests"]:
        assert r["finish"] is not None or r["quarantined"], \
            f"request {r['request_id']} dropped"
    eng.allocator.check_invariants()
    assert eng.allocator.used_pages == 0
    f = m["faults"]
    assert f["quarantined_requests"] == sum(
        1 for r in m["requests"] if r["quarantined"])
    if not plan.enabled:
        assert f["step_faults"] == 0 and f["engine_restarts"] == 0


# ------------------------------------------------------- property: live chaos
def _live_sched(plan, seed=0, prefix_cache=False):
    cfg = tiny_config(vocab_size=tk.VOCAB_SIZE)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = Engine(model, params, EngineConfig(
        page_size=8, num_pages=128, max_slots=4, max_pages_per_branch=8,
        eos_id=tk.EOS, sampling=SamplingParams(temperature=1.0), seed=1,
        prefill_chunk=8, prefix_cache=prefix_cache))
    driven = FaultInjector(eng, plan) if plan is not None else eng
    prm = OraclePRM(tasks.oracle_grader, noise=0.05, seed=2)
    sch = Scheduler(driven, prm, SchedulerConfig(
        policy="sart", n=2, m=1, window=8, max_tokens=24),
        answer_fn=extract_answer)
    rng = np.random.default_rng(seed + 3)
    for i in range(3):
        p = tasks.gen_problem(rng)
        sch.submit(p.prompt_tokens(), payload=p, arrival=i * 2)
    return eng, sch


def test_chaos_disabled_injector_is_bit_exact_live_engine():
    """Fault-free bit-exactness on the live Engine: the empty-plan
    injector run matches the bare-engine run token-for-token."""
    runs = []
    for plan in (None, FaultPlan()):
        eng, sch = _live_sched(plan)
        m = sch.run(max_steps=10_000)
        runs.append(_digest(m))
        assert eng.allocator.used_pages == 0
    assert runs[0] == runs[1]


@pytest.mark.parametrize("seed,crash", [(0, True), (1, False), (2, True)])
def test_chaos_property_live_engine_interleavings(seed, crash):
    """Injected fault interleavings against the live Engine: the restart
    path tears down real KV state through the normal release paths, the
    prefix cache survives for warm re-admission, and every request is
    terminally accounted."""
    plan = FaultPlan(seed=seed, step_rate=0.1, oop_rate=0.05,
                     crash_at=(30,) if crash else ())
    eng, sch = _live_sched(plan, seed=seed, prefix_cache=True)
    m = sch.run(max_steps=50_000)
    assert len(m["requests"]) == 3
    for r in m["requests"]:
        assert r["finish"] is not None or r["quarantined"]
    eng.allocator.check_invariants()
    assert all(s is None for s in eng.slots)
    if crash:
        assert m["faults"]["engine_restarts"] >= 1 \
            or m["faults"]["injected"]["crash"] == 0
