"""Cross-family model consistency: decode-vs-forward, prefill continuation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import Model
from repro.models.layers import embed_tokens

from conftest import FAMILY_CONFIGS, tiny_config


def _build(family):
    cfg = tiny_config(**FAMILY_CONFIGS[family])
    if cfg.uses_moe:
        cfg = cfg.replace(moe_capacity_factor=4.0)  # no drops in tiny tests
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.mark.parametrize("family", sorted(FAMILY_CONFIGS))
def test_decode_matches_forward(family):
    cfg, model, params = _build(family)
    B, S = 2, 20
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    if cfg.multimodal:
        emb = embed_tokens(cfg, params["embed"], toks)
        logits, _ = model.forward(params, embeds=emb)
        lg, cache = model.prefill(params, embeds=emb[:, :S - 1], max_len=64)
    else:
        logits, _ = model.forward(params, tokens=toks)
        lg, cache = model.prefill(params, tokens=toks[:, :S - 1], max_len=64)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert jnp.isfinite(logits).all(), f"{family}: NaN in forward"
    # prefill last-token logits == forward at S-2
    np.testing.assert_allclose(lg, logits[:, S - 2], atol=2e-4,
                               err_msg=f"{family}: prefill mismatch")
    l2, cache, hidden = model.decode_step(params, toks[:, S - 1], cache,
                                          jnp.full((B,), S - 1))
    np.testing.assert_allclose(l2, logits[:, S - 1], atol=5e-4,
                               err_msg=f"{family}: decode mismatch")
    assert hidden.shape == (B, cfg.d_model)


@pytest.mark.parametrize("family", ["dense", "ssm", "hybrid", "moe"])
def test_multi_step_decode(family):
    cfg, model, params = _build(family)
    B, S, extra = 1, 12, 6
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + extra), 0,
                              cfg.vocab_size)
    logits, _ = model.forward(params, tokens=toks)
    lg, cache = model.prefill(params, tokens=toks[:, :S], max_len=64)
    for t in range(S, S + extra):
        l2, cache, _ = model.decode_step(params, toks[:, t], cache,
                                         jnp.full((B,), t))
        np.testing.assert_allclose(l2, logits[:, t], atol=1e-3,
                                   err_msg=f"{family}: step {t}")


def test_gradients_flow_all_families():
    for family in ["dense", "moe", "ssm", "hybrid"]:
        cfg, model, params = _build(family)
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                  cfg.vocab_size)

        def loss(p):
            lg, aux = model.forward(p, tokens=toks)
            return jnp.mean(lg ** 2) + aux

        g = jax.grad(loss)(params)
        norms = [float(jnp.linalg.norm(x)) for x in jax.tree.leaves(g)]
        assert all(np.isfinite(n) for n in norms), family
        assert any(n > 0 for n in norms), family


def test_forward_positions_override():
    cfg, model, params = _build("dense")
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                              cfg.vocab_size)
    base, _ = model.forward(params, tokens=toks)
    shifted, _ = model.forward(params, tokens=toks,
                               positions=jnp.arange(8)[None] + 100)
    assert not np.allclose(base, shifted)
