"""REP007 positive fixture: broad handlers that swallow the failure."""


def step():
    raise RuntimeError("boom")


def swallow_and_log(log):
    try:
        step()
    except Exception:           # finding: neither re-raise nor recovery
        log.append("oops")


def swallow_bare():
    try:
        step()
    except:                     # noqa: E722  finding: bare except, swallowed
        pass


def swallow_tuple(log):
    try:
        step()
    except (ValueError, Exception) as exc:   # finding: Exception in tuple
        log.append(str(exc))
