"""REP007 negative fixture: accounted failures stay silent."""


def step():
    raise RuntimeError("boom")


def reraises():
    try:
        step()
    except Exception:
        raise                       # re-raise: accounted


def wraps_and_raises():
    try:
        step()
    except Exception as exc:
        raise RuntimeError("context") from exc


class Sched:
    def _quarantine_or_requeue(self, req, exc):
        pass

    def _on_engine_fault(self, exc):
        pass

    def routed_to_quarantine(self, req):
        try:
            step()
        except Exception as exc:
            self._quarantine_or_requeue(req, exc)   # recovery route

    def routed_to_fault_domain(self):
        try:
            step()
        except Exception as exc:
            self._on_engine_fault(exc)              # recovery route


def narrow_handler():
    try:
        step()
    except ValueError:
        pass                        # narrow except: out of scope
