"""REP001 positive fixture: Python lists crossing jit boundaries.

Four findings, all in ``drive``: two on ``step`` (decorated ``@jax.jit``,
no static args), one on ``chunk_step`` (partial-jit; the list for the
*non*-static param fires, the static kwarg does not), one on ``step_jit``
(assignment-wrapped; positional args resolve against ``_fn``'s params).
"""
import functools

import jax
import jax.numpy as jnp


@jax.jit
def step(tokens, lengths):
    return tokens


@functools.partial(jax.jit, static_argnames=("buckets",))
def chunk_step(tokens, buckets):
    return tokens


def _fn(tokens, lengths):
    return tokens


step_jit = jax.jit(_fn, static_argnames=("lengths",))


def drive(xs):
    a = step([1, 2, 3], jnp.zeros((3,)))            # REP001: tokens
    b = step(jnp.zeros((3,)), [x for x in xs])      # REP001: lengths
    c = chunk_step([0], buckets=(1,))               # REP001: tokens
    d = step_jit([1], jnp.ones((1,)))               # REP001: tokens
    e = step_jit(jnp.ones((1,)), lengths=[1, 2])    # static: silent
    return a, b, c, d, e
