"""REP003 negative fixture: clamped index map, masked pad store, and a
kernel with no pad path at all."""
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kv_index(b, i, bt):
    return (jnp.minimum(bt[b, i], 1023), 0, 0)    # clamped: fine


def build_spec():
    return pl.BlockSpec((None, 64, 128), _kv_index)


def masked_kernel(q_ref, valid_ref, out_ref):
    acc = q_ref[...] * 2.0
    num_valid = valid_ref[0]
    row = 1
    out_ref[...] = jnp.where(row < num_valid, acc, 0.0)   # gated: fine


def no_pad_kernel(q_ref, out_ref):
    out_ref[...] = q_ref[...]            # no validity name: not a pad path
