"""REP003 positive fixture: unclamped index map + unmasked pad store.

The ``kernels`` path component activates the rule. Two findings: the
raw ``bt[b, i]`` in ``_kv_index``'s return tuple, and ``pad_kernel``'s
output store (the kernel mentions a validity name but the write has no
``jnp.where`` gate).
"""
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kv_index(b, i, bt):
    return (bt[b, i], 0, 0)                       # REP003: no clamp


def build_spec():
    return pl.BlockSpec((None, 64, 128), _kv_index)


def pad_kernel(q_ref, valid_ref, out_ref):
    acc = q_ref[...] * 2.0
    num_valid = valid_ref[0]
    out_ref[...] = acc + num_valid * 0            # REP003: unmasked store
