"""Suppression fixture: the REP005 violation is real but carries an
inline justification, so the run reports nothing."""
import numpy as np


class MiniEngine:
    def decode_loop(self):
        next_tokens = self._step_jit(0)
        # the one mandated sync: tokens drive host bookkeeping
        toks = np.asarray(next_tokens)  # reprolint: disable=REP005
        return toks
