"""REP001(b) positive fixture: per-iteration jnp.asarray(list) churn.

The ``serving/`` path component is what activates pattern 2. Two
findings, both in ``hot_loop``.
"""
import jax.numpy as jnp


def hot_loop(items):
    out = []
    for it in items:
        vec = jnp.asarray([it, it + 1])       # REP001: fresh list per step
        out.append(vec)
    while items:
        items = items[:-1]
        out.append(jnp.array([len(items)]))   # REP001: fresh list per step
    return out
