"""REP005 positive fixture: host syncs on jit-step results in serving/.

Three findings in ``decode_loop``: the np.asarray sink, the float() of a
subscript (taint propagates through indexing), and the .item() method
sink on the unpacked second result.
"""
import numpy as np


class MiniEngine:
    def decode_loop(self):
        next_tokens, hidden = self._step_jit(0)
        toks = np.asarray(next_tokens)            # REP005
        first = float(next_tokens[0])             # REP005
        score = hidden.item()                     # REP005
        return toks, first, score
