"""REP005 negative fixture: device values stay on device; host-side
numpy on untainted values is fine."""
import numpy as np


class MiniEngine:
    def decode_loop(self, batch):
        next_tokens = self._step_jit(0)
        usable = next_tokens + 1                  # stays on device
        staged = np.asarray(batch)                # not a jit-step result
        return usable, staged
