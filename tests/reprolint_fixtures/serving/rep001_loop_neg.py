"""REP001(b) negative fixture: conversions hoisted or of stable names."""
import jax.numpy as jnp
import numpy as np


def cool_loop(items):
    staged = np.zeros((len(items),), np.int32)   # batched host staging
    for j, it in enumerate(items):
        staged[j] = it
    vec = jnp.asarray(staged)                    # one transfer, outside
    once = jnp.asarray([0, 1, 2])                # list, but not in a loop
    for it in items:
        vec = vec + jnp.asarray(it)              # name, not a fresh list
    return vec, once
