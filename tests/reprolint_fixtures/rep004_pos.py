"""REP004 positive fixture: value-equality dataclass used on queues by
membership/removal. Two findings — one per function touching the
container (``cancel`` dedupes its ``in`` + ``.remove`` pair)."""
import dataclasses
from typing import List


@dataclasses.dataclass
class Job:                         # generated __eq__: value equality
    job_id: int
    prompt: List[int] = dataclasses.field(default_factory=list)


class Queue:
    def __init__(self):
        self.waiting: List[Job] = []

    def cancel(self, job: Job) -> None:
        if job in self.waiting:            # REP004 (one per function)
            self.waiting.remove(job)

    def drop_first(self, job: Job) -> None:
        self.waiting.remove(job)           # REP004
