"""REP004 negative fixture: identity-eq dataclass queues and plain-value
containers are both fine."""
import dataclasses
from typing import List


@dataclasses.dataclass(eq=False)
class IdentityJob:                 # identity equality: queue-safe
    job_id: int


@dataclasses.dataclass
class HandEqJob:                   # hand-written __eq__ wins over the
    job_id: int                    # generated one: also exempt

    def __eq__(self, other):
        return self is other

    def __hash__(self):
        return id(self)


class Queue:
    def __init__(self):
        self.waiting: List[IdentityJob] = []
        self.review: List[HandEqJob] = []
        self.names: List[str] = []

    def cancel(self, job: IdentityJob) -> None:
        if job in self.waiting:
            self.waiting.remove(job)

    def unreview(self, job: HandEqJob) -> None:
        self.review.remove(job)

    def forget(self, name: str) -> None:
        if name in self.names:             # str is not a dataclass
            self.names.remove(name)
