"""REP002 negative fixture: the PrefixCache.admit rollback shape, and a
single acquisition (nothing to roll back if the only call raises)."""


class MiniCache:
    def __init__(self, allocator):
        self.allocator = allocator

    def admit(self, pages):
        taken = []
        try:
            for pid in pages:
                self.allocator.incref(pid)      # guarded: handler decrefs
                taken.append(pid)
        except RuntimeError:
            for pid in reversed(taken):
                self.allocator.decref(pid)
            raise
        return taken


def single(allocator):
    return allocator.alloc()                    # one call: exempt
