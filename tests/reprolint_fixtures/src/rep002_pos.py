"""REP002 positive fixture: unguarded multi-page acquisition.

The ``src/`` path component activates the rule. Two findings: one in
``grow`` (acquisition in a comprehension = "many"), one in ``share``
(the second of two single acquisitions is unguarded; the first is exempt
because nothing is held yet when it raises).
"""


def grow(allocator, n):
    return [allocator.alloc() for _ in range(n)]     # REP002


def share(allocator, b):
    first = allocator.alloc()
    second = allocator.alloc()                       # REP002
    return first, second
