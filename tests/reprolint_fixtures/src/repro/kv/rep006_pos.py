"""REP006 positive fixture: the ``src/repro/kv`` path component
activates the rule. Two findings: ``MiniStore.put`` and ``lookup`` lack
docstrings (``_internal`` is private and exempt)."""


class MiniStore:
    """Keyed store."""

    def put(self, key, value):                    # REP006
        self.data[key] = value

    def _internal(self):
        pass


def lookup(store, key):                           # REP006
    return store.data.get(key)
