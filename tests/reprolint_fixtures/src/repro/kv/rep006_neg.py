"""REP006 negative fixture: documented public surface; private and
nested helpers exempt."""


class MiniStore:
    """Keyed store."""

    def put(self, key, value):
        """Store ``value`` under ``key``, replacing any prior value."""
        self.data[key] = value

    def _internal(self):
        pass


def lookup(store, key):
    """Return the stored value for ``key``, or None."""
    def inner():                     # nested helper: exempt
        return store.data
    return inner().get(key)
