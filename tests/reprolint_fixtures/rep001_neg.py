"""REP001 negative fixture: the clean twins of rep001_pos."""
import jax
import jax.numpy as jnp


@jax.jit
def step(tokens, lengths):
    return tokens


def plain(xs):
    return xs


def drive(xs):
    arr = jnp.asarray(xs)              # conversion of a name, not a list
    a = step(arr, jnp.zeros((3,)))     # arrays across the boundary: fine
    b = plain([1, 2, 3])               # not a jit target: fine
    return a, b
