"""Sharded-execution equivalence: run the pjit'd steps on 8 host devices
(subprocess, so the placeholder-device XLA flag cannot leak into other
tests) and compare numerics against the unsharded single-device model.

This is the strongest distribution test available without TPUs: it
validates that the sharding rules + logical constraints + collectives
compute the SAME function, not merely that they compile.
"""
import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke
from repro.distributed.logical import activation_rules, standard_rules
from repro.distributed.sharding import param_pspecs, sanitize_pspecs, \
    shardings
from repro.launch.mesh import make_mesh_compat
from repro.models import Model, cross_entropy_loss

arch = sys_arch = %(arch)r
cfg = smoke(arch).replace(vocab_size=512)
model = Model(cfg)
params = model.init_params(jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
b, s = 4, 32
toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
embeds = (jnp.asarray(rng.normal(size=(b, s, cfg.d_model)), jnp.float32)
          if cfg.multimodal else None)

# --- single-device reference
ref_logits, _ = model.forward(params, tokens=None if cfg.multimodal
                              else toks, embeds=embeds)

# --- sharded execution on a (2 data x 4 model) mesh
# (make_mesh_compat: jax 0.4.x has no AxisType/axis_types kwarg)
mesh = make_mesh_compat((2, 4), ("data", "model"))
pspecs = sanitize_pspecs(param_pspecs(params), params, mesh)
sharded_params = jax.device_put(params, shardings(mesh, pspecs))
rules = standard_rules(("data",))

def fwd(p, toks, embeds):
    with activation_rules(mesh, rules):
        logits, aux = model.forward(p, tokens=None if cfg.multimodal
                                    else toks, embeds=embeds)
        return logits

out = jax.jit(fwd)(sharded_params, toks, embeds)
err = float(jnp.max(jnp.abs(out - ref_logits)))
scale = float(jnp.max(jnp.abs(ref_logits)))
print(json.dumps({"err": err, "scale": scale,
                  "devices": len(jax.devices())}))
"""


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "mamba2-130m",
                                  "dbrx-132b", "hymba-1.5b",
                                  "musicgen-medium"])
def test_sharded_forward_matches_single_device(arch):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run(
        [sys.executable, "-c", _SCRIPT % {"arch": arch}],
        capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["devices"] == 8
    # sharded collectives reorder float math; tolerance scaled to logits
    assert out["err"] <= max(2e-3 * max(out["scale"], 1.0), 2e-3), out
