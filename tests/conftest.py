import jax
import numpy as np
import pytest

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# single real CPU device; only launch/dryrun.py requests 512 placeholders.

jax.config.update("jax_enable_x64", False)


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def tiny_config(**kw):
    from repro.models import ModelConfig
    base = dict(name="tiny", arch_type="dense", num_layers=2, d_model=64,
                vocab_size=97, num_heads=4, num_kv_heads=2, d_ff=128)
    base.update(kw)
    return ModelConfig(**base)


@pytest.fixture
def dense_cfg():
    return tiny_config()


FAMILY_CONFIGS = {
    "dense": dict(),
    "dense_bias": dict(qkv_bias=True),
    "swa": dict(sliding_window=8, num_kv_heads=4),
    "moe": dict(arch_type="moe", d_ff=96, num_experts=4,
                num_experts_per_tok=2),
    "ssm": dict(arch_type="ssm", d_ff=0, ssm_state=16, ssm_head_dim=32,
                ssm_chunk=8),
    "hybrid": dict(arch_type="hybrid", ssm_state=16, ssm_head_dim=32,
                   ssm_chunk=8),
    "vlm": dict(arch_type="vlm", pos_embedding="mrope"),
    "audio": dict(arch_type="audio", pos_embedding="sinusoidal",
                  norm_type="layernorm", mlp_gated=False,
                  mlp_activation="gelu", num_kv_heads=4),
    "gemma_like": dict(mlp_activation="gelu", embedding_scale=True,
                       tie_embeddings=True, head_dim=16),
    "nemotron_like": dict(mlp_activation="relu2", mlp_gated=False,
                          norm_type="layernorm", rope_pct=0.5),
    "stablelm_like": dict(num_kv_heads=4, rope_pct=0.25,
                          norm_type="layernorm"),
}
