"""Property-test backbone: hypothesis when installed, else a pure-random
fallback generator.

Test modules import ``given, settings, st`` from here instead of from
``hypothesis`` directly (the tier-1 seed failed to collect when hypothesis
was missing from the container). With hypothesis installed
(``pip install -r requirements-dev.txt``) the real shrinking engine runs;
without it, ``given`` degrades to drawing ``max_examples`` pseudo-random
samples from a fixed-seed PRNG — no shrinking, but the invariants still get
fuzzed on every CI lane. ``HAVE_HYPOTHESIS`` lets a test
``pytest.importorskip``-style gate anything that genuinely needs the real
library (e.g. ``assume``/stateful testing).
"""
from __future__ import annotations

import functools
import random

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                      # pragma: no cover
    HAVE_HYPOTHESIS = False

    _DEFAULT_EXAMPLES = 25

    class _Strategy:
        def example(self, rng: random.Random):
            raise NotImplementedError

    class _Integers(_Strategy):
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

        def example(self, rng):
            return rng.randint(self.lo, self.hi)

    class _Floats(_Strategy):
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

        def example(self, rng):
            # hit the boundaries occasionally, like hypothesis does
            r = rng.random()
            if r < 0.05:
                return self.lo
            if r < 0.10:
                return self.hi
            return rng.uniform(self.lo, self.hi)

    class _Booleans(_Strategy):
        def example(self, rng):
            return rng.random() < 0.5

    class _SampledFrom(_Strategy):
        def __init__(self, elements):
            self.elements = list(elements)

        def example(self, rng):
            return rng.choice(self.elements)

    class _Lists(_Strategy):
        def __init__(self, elements, min_size=0, max_size=10):
            self.elements = elements
            self.min_size, self.max_size = min_size, max_size

        def example(self, rng):
            n = rng.randint(self.min_size, self.max_size)
            return [self.elements.example(rng) for _ in range(n)]

    class _St:
        @staticmethod
        def integers(min_value, max_value):
            return _Integers(min_value, max_value)

        @staticmethod
        def floats(min_value, max_value):
            return _Floats(min_value, max_value)

        @staticmethod
        def booleans():
            return _Booleans()

        @staticmethod
        def sampled_from(elements):
            return _SampledFrom(elements)

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            return _Lists(elements, min_size=min_size, max_size=max_size)

    st = _St()

    def settings(max_examples=_DEFAULT_EXAMPLES, deadline=None, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            def wrapper(*args, **kwargs):
                rng = random.Random(f"prop:{fn.__module__}.{fn.__name__}")
                n = getattr(wrapper, "_max_examples",
                            getattr(fn, "_max_examples", _DEFAULT_EXAMPLES))
                for _ in range(n):
                    drawn = [s.example(rng) for s in strategies]
                    fn(*args, *drawn, **kwargs)
            # NOT functools.wraps: pytest must see the zero-arg signature,
            # not the inner function's drawn parameters (they'd look like
            # fixtures)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            wrapper._max_examples = getattr(fn, "_max_examples",
                                            _DEFAULT_EXAMPLES)
            return wrapper
        return deco
