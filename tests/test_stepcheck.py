"""stepcheck: trace-level verifier tests — negative controls (seeded
violations MUST be caught), grid exhaustiveness, manifest ratchet
semantics, the engine-enumeration drift gate, the PRM dtype-equivalence
regression pinned by the STEP005 triage, and CLI exit codes.

The bounds verifier doubles as a test harness here: tests hand it
deliberately broken ``KernelGrid``s (an un-clamped index map) and assert
the exact failure is reported — proof the checker checks, not just that
it runs.
"""
import dataclasses
import json
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

REPO = Path(__file__).resolve().parents[1]
if str(REPO) not in sys.path:          # tools/ lives at the repo root
    sys.path.insert(0, str(REPO))

from tools.stepcheck import RULES                                # noqa: E402
from tools.stepcheck import bounds, manifest                     # noqa: E402
from tools.stepcheck.bounds import (ScalarCase,                  # noqa: E402
                                    grid_exhaustive_points,
                                    verify_kernel_grid)

from conftest import tiny_config                                 # noqa: E402


# ------------------------------------------------------------ rule catalog
def test_rule_catalog_complete():
    assert sorted(RULES) == [f"STEP00{i}" for i in range(1, 8)]
    for code, (name, summary) in RULES.items():
        assert name and summary


# ----------------------------------------------- STEP007 negative controls
def _unclamp(kg, names, index_map):
    """Replace the index map of the named mappings — seed a violation."""
    return dataclasses.replace(kg, in_mappings=tuple(
        dataclasses.replace(m, index_map=index_map)
        if m.name in names else m for m in kg.in_mappings))


def test_unclamped_decode_kv_map_is_caught():
    """REMOVE flash-decode's sentinel clamp: the ragged-lengths case must
    produce STEP007 out-of-bounds findings on the exact KV mappings —
    and the shipped (clamped) map must stay silent on the same cases."""
    from repro.kernels import paged_attention_grid
    num_pages, page_size, pps = 16, 4, 5
    kg = paged_attention_grid(3, 4, 8, 2, num_pages, page_size, pps)
    cases = bounds.paged_attention_cases(num_pages, page_size, pps, 3)
    assert verify_kernel_grid(kg, cases) == []

    broken = _unclamp(kg, ("k_pages", "v_pages"),
                      lambda b, h, i, bt, ln: (h, bt[b, i], 0, 0))
    caught = verify_kernel_grid(broken, cases)
    assert {f.rule for f in caught} == {"STEP007"}
    assert {f.symbol for f in caught} == {"k_pages", "v_pages"}
    assert all(f.path == "paged_attention" for f in caught)


def test_unclamped_prefill_sentinel_chase_is_caught():
    """The fused prefill kernel's KV map chases ``bt[ki]`` — without the
    horizon + num_pages-1 clamps the all-sentinel table addresses page
    ``num_pages`` (one past the end)."""
    from repro.kernels import paged_prefill_grid
    num_pages, page_size, pps, t = 16, 4, 6, 8
    kg = paged_prefill_grid(t, 4, 8, 2, num_pages, page_size, pps,
                            block_q=4)
    cases = bounds.paged_prefill_cases(num_pages, page_size, pps, t)
    assert verify_kernel_grid(kg, cases) == []

    broken = _unclamp(kg, ("k_pages", "v_pages"),
                      lambda h, qi, ki, bt, info: (h, bt[ki], 0, 0))
    caught = verify_kernel_grid(broken, cases)
    assert {f.symbol for f in caught if f.rule == "STEP007"} == \
        {"k_pages", "v_pages"}
    # the sentinel chase specifically: only the num_pages-1 clamp keeps
    # an all-sentinel table in bounds
    sentinel = [c for c in cases if c.name == "all-sentinel"]
    caught = verify_kernel_grid(broken, sentinel)
    assert any(f.rule == "STEP007" and "all-sentinel" in f.message
               for f in caught)


def test_block_shape_overrun_is_caught():
    """A block that simply overhangs the array (no scalar refs at all)
    is the plain half of the containment proof."""
    from repro.kernels.introspect import BlockMapping, KernelGrid
    kg = KernelGrid(kernel="toy", grid=(3,), in_mappings=(
        BlockMapping(name="x", array_shape=(10,), block_shape=(4,),
                     index_map=lambda i: (i,)),), out_mappings=())
    caught = verify_kernel_grid(kg)
    assert len(caught) == 1 and "grid point (2,)" in caught[0].message


def test_findings_capped_per_mapping():
    from repro.kernels.introspect import BlockMapping, KernelGrid
    kg = KernelGrid(kernel="toy", grid=(100,), in_mappings=(
        BlockMapping(name="x", array_shape=(1,), block_shape=(1,),
                     index_map=lambda i: (i + 1,)),), out_mappings=())
    assert len(verify_kernel_grid(kg, max_findings_per_mapping=3)) == 3


# ------------------------------------------------------ grid exhaustiveness
def test_lattice_grids_are_exhaustive_and_pinned():
    """Pin the grid shapes the lattice sweeps so it cannot silently stop
    covering grid points (e.g. a refactor collapsing a grid axis)."""
    from repro.kernels import (flash_prefill_grid, paged_attention_grid,
                               paged_prefill_grid, ssd_scan_grid)
    kg = paged_attention_grid(3, 4, 8, 2, 16, 4, 6)
    assert kg.grid == (3, 2, 6) and grid_exhaustive_points(kg) == 36
    kg = paged_prefill_grid(8, 4, 8, 2, 16, 4, 6, block_q=4)
    assert kg.grid == (2, 2, 6) and grid_exhaustive_points(kg) == 24
    kg = flash_prefill_grid(2, 12, 4, 8, 2, block_q=8, block_k=8)
    assert kg.grid == (2, 4, 2, 2)      # s=12 pads to 16: 2 q/k blocks
    kg = ssd_scan_grid(2, 16, 2, 8, 4, 8)
    assert kg.grid == (2, 2, 2)


def test_lattice_covers_all_kernels_and_head_regimes():
    pairs = bounds.engine_lattice()
    assert sorted({kg.kernel for kg, _ in pairs}) == [
        "flash_prefill", "paged_attention", "paged_flash_prefill",
        "paged_tree_branch", "paged_tree_shared", "ssd_scan"]
    # MQA / GQA / MHA over 4 query heads for the attention kernels
    for kernel in ("paged_attention", "paged_tree_branch"):
        kv_counts = {kg.in_mappings[1].array_shape[0]
                     for kg, _ in pairs if kg.kernel == kernel}
        assert kv_counts == {1, 2, 4}, kernel
    assert len(pairs) == 22
    for kg, cases in pairs:
        assert grid_exhaustive_points(kg) > 0 and cases


def test_repo_kernels_prove_in_bounds():
    assert bounds.run_bounds_lattice() == []


# ------------------------------------------------------- manifest semantics
def _sigs(**kw):
    return {name: {"sig": sig, "out": []} for name, sig in kw.items()}


def test_check_manifest_missing_file_is_a_finding():
    fs = manifest.check_manifest({"engine[t]": _sigs(decode="aa")}, {})
    assert [(f.rule, f.symbol) for f in fs] == [("STEP002", "<missing>")]


def test_check_manifest_ratchets_both_directions():
    traced = {"engine[t]": _sigs(decode="aa", **{"mixed:b8xl1": "bb"})}
    committed = {"targets": {"engine[t]": _sigs(
        decode="XX", **{"mixed:b8xl2": "cc"})}}
    fs = manifest.check_manifest(traced, committed)
    got = {(f.rule, f.symbol) for f in fs}
    assert got == {("STEP002", "decode"),        # signature changed
                   ("STEP002", "mixed:b8xl1"),   # traced, not committed
                   ("STEP002", "mixed:b8xl2")}   # committed, not traced


def test_check_manifest_clean_when_identical():
    traced = {"engine[t]": _sigs(decode="aa")}
    assert manifest.check_manifest(
        traced, {"targets": traced}) == []


def test_cache_invariance_flags_signature_drift():
    off = _sigs(decode="aa", **{"mixed:b8xl1": "bb"})
    on = _sigs(decode="aa", **{"mixed:b8xl1": "ZZ"})
    fs = manifest.check_cache_invariance(off, on, "engine[dense+cache]")
    assert [(f.rule, f.symbol) for f in fs] == [("STEP001", "mixed:b8xl1")]
    assert manifest.check_cache_invariance(off, dict(off),
                                           "engine[dense+cache]") == []


def test_sim_projection_flags_extra_shapes():
    fs = manifest.check_sim_projection(["decode", "mixed:b8xl1"],
                                       ["decode", "mixed:b8xl9"])
    assert [(f.rule, f.path) for f in fs] == [("STEP001", "simulator")]
    assert manifest.check_sim_projection(
        ["decode", "mixed:b8xl1"], ["decode"]) == []


def test_committed_manifest_matches_bound():
    """The committed file itself must respect the O(buckets × lanes)
    bound it exists to enforce."""
    committed = manifest.load_manifest()
    assert committed, "tools/stepcheck/manifest.json must be committed"
    for tname, variants in committed["targets"].items():
        mixed = [v for v in variants if v.startswith("mixed:")]
        assert len(variants) == committed["variants_per_target"]
        assert len(variants) == 1 + len(mixed) and "decode" in variants


# ----------------------------------------------- enumeration + drift gate
def _real_engine(**eng_kw):
    import jax
    from repro.models import Model
    from repro.serving import Engine, EngineConfig, SamplingParams
    cfg = tiny_config()
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    base = dict(page_size=4, num_pages=128, max_slots=4,
                max_pages_per_branch=24, eos_id=1,
                sampling=SamplingParams(temperature=0.0), seed=0,
                prefill_chunk=8)
    base.update(eng_kw)
    return Engine(model, params, EngineConfig(**base))


def test_step_variants_enumeration_matches_bound():
    eng = _real_engine(step_token_budget=16)
    names = [v.name for v in eng.step_variants()]
    expected = {"decode"} | {f"mixed:b{b}xl{n}"
                             for b in eng._buckets
                             for n in eng._lane_configs}
    assert len(names) == len(set(names)) == \
        1 + len(eng._buckets) * len(eng._lane_configs)
    assert set(names) == expected


def test_decode_traces_stay_within_declared_variants():
    """Drift gate: every shape the engine ACTUALLY traces while serving
    ragged mixed traffic must be declared by ``step_variants()`` —
    enumeration drift is exactly the silent-retrace bug class."""
    eng = _real_engine(step_token_budget=16)
    declared = {v.name for v in eng.step_variants()}
    rng = np.random.default_rng(3)
    sts = [eng.begin_prefill(
        [int(t) for t in rng.integers(2, 97, size=s)])
        for s in (13, 9, 17)]
    while any(not st.done for st in sts):
        eng.decode_step()
    assert eng._buckets_used, "mixed traffic never traced a chunk shape"
    traced = {f"mixed:b{b}xl{n}" for (b, n) in eng._buckets_used}
    assert traced <= declared, f"undeclared shapes: {traced - declared}"
    assert eng.prefill_compile_count <= len(declared) - 1


# ------------------------------------------- STEP005 triage regression (#5)
def test_prm_reward_dtype_equivalence():
    """The eager ``hidden.astype(jnp.float32)`` removed from
    ``Engine._step_fn`` was redundant: the fp32 PRM head promotes a bf16
    hidden state at the matmul, bit-identically. This pins that
    equivalence so the upcast can never be 'needed back' silently."""
    import jax
    import jax.numpy as jnp
    from repro.core.prm import init_prm_head, reward_logit

    params = init_prm_head(jax.random.PRNGKey(0), d_model=64)
    assert params["w1"].dtype == jnp.float32
    hidden = jax.random.normal(jax.random.PRNGKey(1), (4, 64),
                               dtype=jnp.bfloat16)
    narrow = reward_logit(params, hidden)
    wide = reward_logit(params, hidden.astype(jnp.float32))
    assert narrow.dtype == wide.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(narrow), np.asarray(wide))


def test_engine_last_hidden_stays_model_dtype():
    """The step returns hidden state in the model dtype — the fp32
    boundary lives inside the PRM head, not on the dispatch."""
    eng = _real_engine()
    assert eng._last_hidden.dtype == eng.model.dtype


# ------------------------------------------------------------ CLI contract
def _cli(*args, timeout=300):
    return subprocess.run(
        [sys.executable, "-m", "tools.stepcheck", *args],
        cwd=REPO, capture_output=True, text=True, timeout=timeout)


def test_cli_list_rules():
    res = _cli("--list-rules")
    assert res.returncode == 0
    for code in RULES:
        assert code in res.stdout


def test_cli_self_test_catches_seeded_violations():
    res = _cli("--self-test")
    assert res.returncode == 0, res.stdout + res.stderr
    assert "self-test OK" in res.stdout


def test_cli_repo_clean_with_committed_manifest_and_baseline():
    """The acceptance gate: the committed manifest + justified baseline
    make the full run exit 0; every finding is marked baselined."""
    res = _cli()
    assert res.returncode == 0, res.stdout + res.stderr
    assert "0 new" in res.stdout


def test_cli_json_output_shape():
    res = _cli("--json")
    assert res.returncode == 0, res.stdout + res.stderr
    data = json.loads(res.stdout)
    assert data["new"] == 0 and data["total"] == len(data["findings"])
    assert all(not f["new"] for f in data["findings"])


def test_cli_tampered_manifest_fails_the_build(tmp_path):
    committed = manifest.load_manifest()
    tampered = json.loads(json.dumps(committed))
    target = next(iter(tampered["targets"]))
    tampered["targets"][target]["decode"]["sig"] = "0" * 16
    bad = tmp_path / "manifest.json"
    bad.write_text(json.dumps(tampered), encoding="utf-8")
    res = _cli("--manifest", str(bad))
    assert res.returncode == 1
    assert "STEP002" in res.stdout and "decode" in res.stdout
