"""Property tests for the paged KV allocator: random interleavings of
alloc_prefix / extend / fork / append_token / release never leak pages or
double-free, and refcounts always equal the number of block tables holding
each page (refcount conservation). Runs under hypothesis when installed,
else under prop.py's pure-random fallback generator."""
import pytest

from prop import given, settings, st
from repro.kv import OutOfPagesError, PageAllocator


def _refcount_conservation(alloc: PageAllocator, live_blocks):
    """Every page's refcount must equal the number of live BranchBlocks that
    list it (a block lists a page at most once)."""
    held = {}
    for b in live_blocks:
        for pid in b.pages:
            held[pid] = held.get(pid, 0) + 1
    for pid, n in held.items():
        assert alloc.refcount(pid) == n, f"page {pid}: refs != holders"
    assert alloc.used_pages == len(held)


# each op is (action_selector, operand); the operand picks a target branch
# and sizes new allocations, so a fixed op list replays deterministically
@settings(max_examples=60, deadline=None)
@given(st.integers(1, 8),                       # page_size
       st.integers(4, 64),                      # num_pages
       st.lists(st.integers(0, 10_000), min_size=1, max_size=100))
def test_random_interleavings_conserve_refcounts(page_size, num_pages, ops):
    alloc = PageAllocator(num_pages, page_size)
    live = []
    for op in ops:
        action = op % 5
        pick = (op // 5) % max(len(live), 1)
        size = op % (3 * page_size) + 1
        try:
            if action == 0:                     # admit a new prompt
                live.append(alloc.alloc_prefix(size))
            elif action == 1 and live:          # fork (prefix sharing)
                live.append(alloc.fork(live[pick]))
            elif action == 2 and live:          # decode one token
                alloc.append_token(live[pick])
            elif action == 3 and live:          # chunked-prefill growth
                b = live[pick]
                alloc.extend(b, b.length + size)
            elif action == 4 and live:          # branch terminates
                alloc.release(live.pop(pick))
        except OutOfPagesError:
            pass                                # pool pressure is legal
        alloc.check_invariants()
        _refcount_conservation(alloc, live)
    for b in live:
        alloc.release(b)
    alloc.check_invariants()
    assert alloc.used_pages == 0, "page leak after releasing every branch"


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 6), st.integers(1, 30), st.integers(0, 40))
def test_extend_matches_incremental_appends(page_size, start_tokens, extra):
    """extend(b, L) must land on exactly the same page count as appending
    token-by-token, and must be all-or-nothing under pool exhaustion."""
    a1 = PageAllocator(1024, page_size)
    a2 = PageAllocator(1024, page_size)
    b1 = a1.alloc_prefix(start_tokens)
    b2 = a2.alloc_prefix(start_tokens)
    a1.extend(b1, start_tokens + extra)
    for _ in range(extra):
        a2.append_token(b2)
    assert len(b1.pages) == len(b2.pages)
    assert b1.length == b2.length == start_tokens + extra

    tight = PageAllocator(a1.pages_for(max(start_tokens, 1)), page_size)
    tb = tight.alloc_prefix(start_tokens)
    before = (list(tb.pages), tb.length, tight.free_pages)
    huge = start_tokens + tight.num_pages * page_size + 1
    with pytest.raises(OutOfPagesError):
        tight.extend(tb, huge)
    assert (list(tb.pages), tb.length, tight.free_pages) == before
    tight.check_invariants()


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 6), st.integers(1, 20), st.integers(1, 6))
def test_fork_release_any_order_frees_everything(page_size, tokens, forks):
    """Whatever order siblings (and the parent prefix) release in, the pool
    drains to zero — eager per-branch release with shared-prefix refcounts."""
    alloc = PageAllocator(256, page_size)
    prefix = alloc.alloc_prefix(tokens)
    branches = [alloc.fork(prefix) for _ in range(forks)]
    for i, b in enumerate(branches):
        for _ in range(i):                      # ragged private tails
            alloc.append_token(b)
    order = branches[1::2] + [prefix] + branches[0::2]
    for b in order:
        alloc.release(b)
        alloc.check_invariants()
    assert alloc.used_pages == 0
