"""Property tests for the paged KV allocator: random interleavings of
alloc_prefix / extend / fork / append_token / release never leak pages or
double-free, and refcounts always equal the number of block tables holding
each page (refcount conservation). With the radix prefix cache attached,
the same interleavings plus acquire/insert/evict (under degraded,
collision-heavy hash functions) must conserve the live + free + LRU
partition: every cached page has refcount >= 1 or sits on the LRU
free-list, and releasing shared prefix pages parks them there instead of
recycling them through the free list. Runs under hypothesis when
installed, else under prop.py's pure-random fallback generator."""
import pytest

from prop import given, settings, st
from repro.kv import (OutOfPagesError, PageAllocator, PrefixCache,
                      default_page_hash)


def _refcount_conservation(alloc: PageAllocator, live_blocks):
    """Every page's refcount must equal the number of live BranchBlocks that
    list it (a block lists a page at most once)."""
    held = {}
    for b in live_blocks:
        for pid in b.pages:
            held[pid] = held.get(pid, 0) + 1
    for pid, n in held.items():
        assert alloc.refcount(pid) == n, f"page {pid}: refs != holders"
    assert alloc.used_pages == len(held)


# each op is (action_selector, operand); the operand picks a target branch
# and sizes new allocations, so a fixed op list replays deterministically
@settings(max_examples=60, deadline=None)
@given(st.integers(1, 8),                       # page_size
       st.integers(4, 64),                      # num_pages
       st.lists(st.integers(0, 10_000), min_size=1, max_size=100))
def test_random_interleavings_conserve_refcounts(page_size, num_pages, ops):
    alloc = PageAllocator(num_pages, page_size)
    live = []
    for op in ops:
        action = op % 5
        pick = (op // 5) % max(len(live), 1)
        size = op % (3 * page_size) + 1
        try:
            if action == 0:                     # admit a new prompt
                live.append(alloc.alloc_prefix(size))
            elif action == 1 and live:          # fork (prefix sharing)
                live.append(alloc.fork(live[pick]))
            elif action == 2 and live:          # decode one token
                alloc.append_token(live[pick])
            elif action == 3 and live:          # chunked-prefill growth
                b = live[pick]
                alloc.extend(b, b.length + size)
            elif action == 4 and live:          # branch terminates
                alloc.release(live.pop(pick))
        except OutOfPagesError:
            pass                                # pool pressure is legal
        alloc.check_invariants()
        _refcount_conservation(alloc, live)
    for b in live:
        alloc.release(b)
    alloc.check_invariants()
    assert alloc.used_pages == 0, "page leak after releasing every branch"


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 6), st.integers(1, 30), st.integers(0, 40))
def test_extend_matches_incremental_appends(page_size, start_tokens, extra):
    """extend(b, L) must land on exactly the same page count as appending
    token-by-token, and must be all-or-nothing under pool exhaustion."""
    a1 = PageAllocator(1024, page_size)
    a2 = PageAllocator(1024, page_size)
    b1 = a1.alloc_prefix(start_tokens)
    b2 = a2.alloc_prefix(start_tokens)
    a1.extend(b1, start_tokens + extra)
    for _ in range(extra):
        a2.append_token(b2)
    assert len(b1.pages) == len(b2.pages)
    assert b1.length == b2.length == start_tokens + extra

    tight = PageAllocator(a1.pages_for(max(start_tokens, 1)), page_size)
    tb = tight.alloc_prefix(start_tokens)
    before = (list(tb.pages), tb.length, tight.free_pages)
    huge = start_tokens + tight.num_pages * page_size + 1
    with pytest.raises(OutOfPagesError):
        tight.extend(tb, huge)
    assert (list(tb.pages), tb.length, tight.free_pages) == before
    tight.check_invariants()


# degraded hash functions inject collisions: the cache must verify tokens
# + parent identity, so collisions degrade to misses, never wrong pages
_HASH_FNS = (default_page_hash,
             lambda p, t: default_page_hash(p, t) % 13,
             lambda p, t: 7)


def _admit_through_cache(alloc, cache, prompt):
    """The engines' admission dance (PrefixCache.admit: acquire the
    cached prefix, reserve the tail all-or-nothing with rollback), then
    insert the full pages as a completed prefill would."""
    b, _ = cache.admit(prompt)
    cache.insert(prompt, b.pages)
    return b


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 4),                       # page_size
       st.integers(6, 48),                      # num_pages
       st.integers(0, 2),                       # hash degradation level
       st.lists(st.integers(0, 100_000), min_size=1, max_size=80))
def test_prefix_cache_interleavings_conserve_pages(page_size, num_pages,
                                                   degrade, ops):
    """Random admit(acquire+extend+insert) / fork / append / release /
    evict sequences — including under colliding hashes — keep the
    live + free + LRU partition and refcount conservation intact, and
    draining every branch plus the LRU returns the pool to empty."""
    alloc = PageAllocator(num_pages, page_size)
    cache = PrefixCache(alloc, hash_fn=_HASH_FNS[degrade])
    live = []
    for op in ops:
        action = op % 6
        pick = (op // 6) % max(len(live), 1)
        size = op % (4 * page_size) + 1
        # tiny token alphabet + constant-prefix prompts force prefix
        # sharing (and, degraded, hash collisions) across admissions
        prompt = [(op // 24) % 3] * size
        try:
            if action == 0:                     # admit via the cache
                live.append(_admit_through_cache(alloc, cache, prompt))
            elif action == 1 and live:          # branch fork
                live.append(alloc.fork(live[pick]))
            elif action == 2 and live:          # decode one token
                alloc.append_token(live[pick])
            elif action == 3 and live:          # branch terminates
                alloc.release(live.pop(pick))
            elif action == 4 and cache.evictable:   # memory pressure
                cache.evict_one()
            elif action == 5:                   # bare lookup + drop: the
                pages, _ = cache.acquire(prompt)  # resurrect/re-idle path
                for pid in reversed(pages):
                    alloc.decref(pid)
        except OutOfPagesError:
            pass                                # pool pressure is legal
        alloc.check_invariants()                # includes cache invariants
        _refcount_conservation(alloc, live)
    for b in live:
        alloc.release(b)
    alloc.check_invariants()
    assert alloc.used_pages == 0, "pages still live after releasing all"
    cache.drop()                                # evict the whole LRU
    alloc.check_invariants()
    assert cache.evictable == 0 and len(alloc._free) == num_pages


def test_release_shared_prefix_decrefs_to_lru_not_free():
    """Regression (decref-to-LRU vs decref-to-free): releasing a
    BranchBlocks holding cache-tracked prefix pages must park them on the
    cache's LRU free-list — NOT the allocator free list, where the next
    allocation would recycle them and let the engine overwrite K/V the
    cache still maps. Untracked pages (the partial tail) free normally."""
    alloc = PageAllocator(8, 2)
    cache = PrefixCache(alloc)
    prompt = [1, 2, 3, 4, 5]                    # 2 full pages + 1-token tail
    b = _admit_through_cache(alloc, cache, prompt)
    tracked = list(b.pages[:2])
    free_before = len(alloc._free)
    alloc.release(b)
    # decref-to-LRU: the 2 tracked pages idle on the cache's list ...
    assert cache.evictable == 2
    assert sorted(cache.lru_pages) == sorted(tracked)
    # ... decref-to-free: only the untracked tail page hits the free list
    assert len(alloc._free) == free_before + 1
    assert not set(tracked) & set(alloc._free)
    alloc.check_invariants()
    # allocation never hands out an LRU page while true-free pages remain
    held = [alloc.alloc() for _ in range(len(alloc._free))]
    assert not set(held) & set(tracked)
    assert cache.evictable == 2
    # a hash hit resurrects the parked pages with their refcount restored
    pages, _ = cache.acquire(prompt)
    assert pages == tracked
    assert all(alloc.refcount(p) == 1 for p in pages)
    assert cache.evictable == 0
    alloc.check_invariants()
    # exhausting the pool now evicts nothing that is still referenced
    with pytest.raises(OutOfPagesError):
        for _ in range(alloc.num_pages):
            alloc.alloc()


def test_prefix_cache_eviction_is_lru_and_pressure_only():
    """Idle cached pages are reclaimed oldest-idled-first, and only when
    the free list runs dry — a warm pool never evicts."""
    alloc = PageAllocator(6, 2)
    cache = PrefixCache(alloc)
    b1 = _admit_through_cache(alloc, cache, [1, 1, 1, 1])   # pages 0..1
    b2 = _admit_through_cache(alloc, cache, [2, 2])         # page 2
    alloc.release(b1)                           # idles first (older)
    alloc.release(b2)
    assert cache.evictable == 3 and alloc.free_pages == 6
    # 3 true-free pages serve without evicting
    blocks = alloc.alloc_prefix(3 * 2)
    assert cache.evictable == 3 and cache.stats()["evictions"] == 0
    # the 4th page forces one eviction — the oldest-idled (b1's leaf-first
    # release order means its deepest page idled first)
    alloc.extend(blocks, 4 * 2)
    assert cache.evictable == 2 and cache.stats()["evictions"] == 1
    alloc.check_invariants()
    # evicted chains are misses now; survivors still hit
    pages, _ = cache.acquire([2, 2, 9])
    assert len(pages) == 1
    alloc.release(blocks)
    for pid in reversed(pages):
        alloc.decref(pid)
    alloc.check_invariants()
    assert alloc.used_pages == 0


def test_prefix_cache_collisions_never_alias():
    """A constant hash function maps every page to one bucket; lookups
    must still return only true token matches (verification by tokens +
    parent identity)."""
    alloc = PageAllocator(16, 2)
    cache = PrefixCache(alloc, hash_fn=lambda p, t: 7)
    b1 = _admit_through_cache(alloc, cache, [1, 2, 3, 4])
    b2 = _admit_through_cache(alloc, cache, [5, 6, 7, 8])
    pages, _ = cache.acquire([5, 6, 9, 9, 9])
    assert pages == [b2.pages[0]] and pages != [b1.pages[0]]
    for pid in pages:
        alloc.decref(pid)
    pages, _ = cache.acquire([9, 9, 9, 9, 9])
    assert pages == []                          # collision != match
    alloc.release(b1)
    alloc.release(b2)
    alloc.check_invariants()
    assert alloc.used_pages == 0


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 6), st.integers(1, 20), st.integers(1, 6))
def test_fork_release_any_order_frees_everything(page_size, tokens, forks):
    """Whatever order siblings (and the parent prefix) release in, the pool
    drains to zero — eager per-branch release with shared-prefix refcounts."""
    alloc = PageAllocator(256, page_size)
    prefix = alloc.alloc_prefix(tokens)
    branches = [alloc.fork(prefix) for _ in range(forks)]
    for i, b in enumerate(branches):
        for _ in range(i):                      # ragged private tails
            alloc.append_token(b)
    order = branches[1::2] + [prefix] + branches[0::2]
    for b in order:
        alloc.release(b)
        alloc.check_invariants()
    assert alloc.used_pages == 0


# --------------------------------------------------- error-path rollback
# (reprolint REP002's fix shape: acquisition sequences must be
# all-or-nothing even when a primitive fails mid-way)

def test_extend_rolls_back_when_alloc_fails_mid_loop(monkeypatch):
    """A mid-loop alloc failure inside extend must return the pages taken
    so far — conservation can't depend on the free_pages pre-check
    staying in sync with alloc's actual supply."""
    alloc = PageAllocator(16, 2)
    b = alloc.alloc_prefix(4)          # 2 pages held
    real_alloc = PageAllocator.alloc
    calls = {"n": 0}

    def flaky_alloc(self):
        calls["n"] += 1
        if calls["n"] == 3:            # fail on the 3rd new page
            raise OutOfPagesError("injected mid-loop failure")
        return real_alloc(self)

    monkeypatch.setattr(PageAllocator, "alloc", flaky_alloc)
    with pytest.raises(OutOfPagesError):
        alloc.extend(b, 12)            # needs 4 new pages; dies on #3
    monkeypatch.undo()
    # the 2 pages allocated before the failure were rolled back
    assert alloc.used_pages == 2
    assert b.length == 4 and len(b.pages) == 2
    alloc.check_invariants()
    _refcount_conservation(alloc, [b])
    # and the branch is still usable: the retry succeeds cleanly
    alloc.extend(b, 12)
    assert len(b.pages) == 6
    alloc.check_invariants()


def test_prefix_cache_acquire_rolls_back_on_mid_loop_failure(monkeypatch):
    """If taking references on the matched prefix fails part-way,
    acquire must give back what it took (re-idling resurrected pages
    onto the LRU), leaving the live/free/LRU partition intact."""
    alloc = PageAllocator(32, 2)
    cache = PrefixCache(alloc)
    prompt = list(range(10))
    b, _ = cache.admit(prompt)
    cache.insert(prompt, b.pages)
    alloc.release(b)                   # cached pages idle onto the LRU
    assert cache.evictable == 5
    real_incref = PageAllocator.incref
    real_resurrect = PageAllocator.resurrect
    calls = {"n": 0}

    def count(self):
        calls["n"] += 1
        if calls["n"] == 3:
            raise RuntimeError("injected mid-acquire failure")

    def flaky_incref(self, pid):
        count(self)
        real_incref(self, pid)

    def flaky_resurrect(self, pid):
        count(self)
        real_resurrect(self, pid)

    monkeypatch.setattr(PageAllocator, "incref", flaky_incref)
    monkeypatch.setattr(PageAllocator, "resurrect", flaky_resurrect)
    with pytest.raises(RuntimeError, match="mid-acquire"):
        cache.acquire(prompt)          # matches 4 pages; dies on the 3rd
    monkeypatch.undo()
    # the 2 references taken before the failure were rolled back: every
    # cached page is refcount-0 and back on the LRU
    assert alloc.used_pages == 0
    assert cache.evictable == 5
    alloc.check_invariants()
    # the cache still serves the prefix afterwards
    pages, _ = cache.acquire(prompt)
    assert len(pages) == 4
    for pid in pages:
        assert alloc.refcount(pid) == 1
    alloc.check_invariants()


# ---------------------------------------------------- generated-prefix tree
# (PR: decode-side insertion — branches insert prompt + generated tokens on
# completion and page boundaries, forks share parked ancestors via revive)


def test_fork_parked_prefix_tail_page_revives():
    """Regression: forking off a held BranchBlocks copy whose pages were
    released to the cache's LRU (refcount 0, K/V resident) used to
    KeyError inside ``incref`` — ``fork`` now asks the cache to revive
    parked pages so the child holds the single new reference."""
    alloc = PageAllocator(8, 2)
    cache = PrefixCache(alloc)
    prompt = [1, 2, 3, 4]                       # 2 full pages
    b = _admit_through_cache(alloc, cache, prompt)
    held = b.copy()                             # e.g. a queued request's
    alloc.release(b)                            # prefix_blocks snapshot
    assert cache.evictable == 2
    assert all(alloc.refcount(p) == 0 for p in held.pages)
    child = alloc.fork(held)                    # pre-fix: KeyError
    assert child.pages == held.pages
    assert all(alloc.refcount(p) == 1 for p in child.pages)
    assert cache.evictable == 0                 # revived off the LRU
    alloc.check_invariants()
    alloc.release(child)
    assert cache.evictable == 2                 # parked again, not freed
    alloc.check_invariants()
    cache.drop()
    assert alloc.used_pages == 0


def test_fork_mixed_live_and_parked_prefix_pages():
    """A fork whose parent holds both live (still-referenced) and parked
    (refcount-0 LRU) pages takes exactly one new reference per page
    through the matching path — incref for live, revive for parked."""
    alloc = PageAllocator(16, 2)
    cache = PrefixCache(alloc)
    prompt = [1, 2, 3, 4, 5, 6]
    b = _admit_through_cache(alloc, cache, prompt)
    sibling = alloc.fork(b)                     # keeps every page live
    held = b.copy()
    alloc.release(b)                            # refcounts drop to 1
    assert all(alloc.refcount(p) == 1 for p in held.pages)
    assert cache.evictable == 0                 # nothing parked yet
    child = alloc.fork(held)                    # plain incref path
    assert all(alloc.refcount(p) == 2 for p in child.pages)
    alloc.release(sibling)
    alloc.release(child)
    assert cache.evictable == 3
    # now every tracked page is parked: fork revives all of them
    child2 = alloc.fork(held)
    assert all(alloc.refcount(p) == 1 for p in child2.pages)
    assert cache.evictable == 0
    alloc.check_invariants()
    alloc.release(child2)
    cache.drop()
    assert alloc.used_pages == 0


def test_generated_prefix_collisions_degrade_to_misses():
    """Two branches share a prompt but generate different tokens under a
    constant (always-colliding) hash: acquiring one branch's full
    prompt+generated key must never return the other's generated pages —
    collisions degrade to shorter matches, never aliased K/V."""
    alloc = PageAllocator(32, 2)
    cache = PrefixCache(alloc, hash_fn=lambda p, t: 7)
    prompt = [1, 2]                             # one full page
    a = _admit_through_cache(alloc, cache, prompt)
    bb = alloc.fork(a)
    gen_a, gen_b = [5, 6, 7, 8], [5, 9, 9, 9]
    for blocks, gen in ((a, gen_a), (bb, gen_b)):
        for _t in gen:
            alloc.append_token(blocks)
    # completion-time insertion of prompt + generated (full pages only)
    cache.insert(prompt + gen_a, a.pages)
    cache.insert(prompt + gen_b, bb.pages)
    pages_a, _ = cache.acquire(prompt + gen_a + [0])
    assert pages_a == a.pages[:3] and pages_a[1:] != bb.pages[1:3]
    for pid in reversed(pages_a):
        alloc.decref(pid)
    # a colliding-but-different generated suffix stops at the prompt page
    pages_x, _ = cache.acquire(prompt + [5, 4, 4, 4, 0])
    assert pages_x == a.pages[:1]
    for pid in reversed(pages_x):
        alloc.decref(pid)
    alloc.release(a)
    alloc.release(bb)
    alloc.check_invariants()
    cache.drop()
    assert alloc.used_pages == 0


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 4),                       # page_size
       st.integers(8, 48),                      # num_pages
       st.integers(0, 2),                       # hash degradation level
       st.lists(st.integers(0, 100_000), min_size=1, max_size=80))
def test_generated_prefix_tree_interleavings(page_size, num_pages, degrade,
                                             ops):
    """The full decode-side lifecycle the tree-decoding engine runs:
    admit / fork / decode-with-boundary-insert / complete-with-insert /
    evict / bare-acquire-resurrect, interleaved at random and under
    colliding hashes. Each branch's token list mirrors its block length
    (prompt + generated), so insertions register generated pages exactly
    as ``Engine._insert_generated`` does. The live + free + LRU partition
    and refcount conservation must hold at every step, and draining
    branches plus the LRU returns the pool to empty."""
    alloc = PageAllocator(num_pages, page_size)
    cache = PrefixCache(alloc, hash_fn=_HASH_FNS[degrade])
    live = []                                   # (blocks, tokens) pairs
    for op in ops:
        action = op % 7
        pick = (op // 7) % max(len(live), 1)
        size = op % (4 * page_size) + 1
        prompt = [(op // 24) % 3] * size
        try:
            if action == 0:                     # admit via the cache
                b, _ = cache.admit(prompt)
                live.append((b, list(prompt)))
            elif action == 1 and live:          # branch fork
                b, tokens = live[pick]
                live.append((alloc.fork(b), list(tokens)))
            elif action == 2 and live:          # decode one token ...
                b, tokens = live[pick]
                alloc.append_token(b)
                tokens.append(op % 5)
                if b.length % page_size == 0:   # ... boundary insert
                    cache.insert(tokens, b.pages)
            elif action == 3 and live:          # complete: insert + free
                b, tokens = live.pop(pick)
                cache.insert(tokens, b.pages)
                alloc.release(b)
            elif action == 4 and cache.evictable:   # memory pressure
                cache.evict_one()
            elif action == 5 and live:          # generated-prefix lookup
                _b, tokens = live[pick]         # + drop (resurrect path)
                pages, _ = cache.acquire(tokens + [9])
                for pid in reversed(pages):
                    alloc.decref(pid)
            elif action == 6 and live:          # chunked growth
                b, tokens = live[pick]
                alloc.extend(b, b.length + size)
                tokens.extend([op % 5] * size)
        except OutOfPagesError:
            pass                                # pool pressure is legal
        for b, tokens in live:
            assert len(tokens) == b.length      # model stays in lockstep
        alloc.check_invariants()                # includes cache invariants
        _refcount_conservation(alloc, [b for b, _t in live])
    for b, _tokens in live:
        alloc.release(b)
    alloc.check_invariants()
    assert alloc.used_pages == 0, "pages still live after releasing all"
    cache.drop()
    alloc.check_invariants()
    assert cache.evictable == 0 and len(alloc._free) == num_pages


def test_tree_decode_map_from_fork_topology():
    """Unit coverage of ``tree_decode_map``: forked siblings sharing
    leading page ids form a group with the longest-common-page-prefix as
    its shared span; singletons, empty slots and page-less rows stay
    ungrouped with their full table in ``branch_bt``."""
    import numpy as np
    from repro.kv import BranchBlocks, tree_decode_map
    ps, num_pages, ppb = 4, 32, 6
    sib_a = BranchBlocks(pages=[3, 7, 10], num_shared=2, length=2 * ps + 1)
    sib_b = BranchBlocks(pages=[3, 7, 11], num_shared=2, length=2 * ps + 2)
    sib_c = BranchBlocks(pages=[3, 7, 11, 12], num_shared=2,
                         length=3 * ps + 1)
    single = BranchBlocks(pages=[20, 21], num_shared=0, length=ps + 2)
    blocks = [sib_a, None, sib_b, single, sib_c]
    row_group, shared_bt, shared_lens, branch_bt = tree_decode_map(
        blocks, pages_per_branch=ppb, num_pages=num_pages, page_size=ps)
    b = len(blocks)
    gid = row_group[0]
    assert gid < b and row_group[2] == gid and row_group[4] == gid
    assert row_group[1] == b and row_group[3] == b      # ungrouped
    # lcp of [3,7,10] / [3,7,11] / [3,7,11,12] is [3,7] -> span 2 pages
    assert shared_lens[gid] == 2 * ps
    assert list(shared_bt[gid][:2]) == [3, 7]
    assert all(shared_bt[gid][2:] == num_pages)
    assert list(branch_bt[0][:1]) == [10]
    assert list(branch_bt[2][:1]) == [11]
    assert list(branch_bt[4][:2]) == [11, 12]
    assert list(branch_bt[3][:2]) == [20, 21]           # full table
    assert all(branch_bt[1] == num_pages)               # empty slot
    assert shared_lens[row_group[3]] == 0 if row_group[3] < b else True
    # sibling pair 2/4 share THREE leading pages ([3,7,11]) but the
    # group's span is the lcp over all members — never a partial subset
    np.testing.assert_array_equal(row_group[[0, 2, 4]], gid)
