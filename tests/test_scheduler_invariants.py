"""Scheduler/engine invariants from Algorithm 1: early stop at exactly M,
phase-1 pruning capped at beta per round, suspend/resume round-tripping
SSM state bit-exactly, and the token-budget chunk-lane packer (budget
never exceeded, bounded starvation, O(buckets x lane-configs) compiles —
see docs/scheduling.md)."""
import jax
import numpy as np
import pytest

from repro.core import OraclePRM, Scheduler, SchedulerConfig
from repro.core.policies import make_policy, select_next
from repro.core.pruning import PruningConfig, TwoPhasePruner
from repro.core.scheduler import Request, percentile_latency
from repro.data import tokenizer as tk
from repro.data.tasks import extract_answer
from repro.models import Model
from repro.serving import Engine, EngineConfig, SamplingParams
from repro.serving.engine import (ChunkedPrefillState, derive_lane_configs,
                                  pack_chunk_lanes)
from repro.serving.simulator import (SimEngine, SimEngineConfig, SimPRM,
                                     SimTask, SimWorkload,
                                     adversarial_shared_header_mix,
                                     mixed_deadline_workload,
                                     poisson_burst_arrivals,
                                     run_sim_experiment)

from conftest import tiny_config


def _sim_sched(policy="sart", n=8, m=4, beta=2, num_requests=12, seed=0,
               window=10, prm_drift=6.0):
    workload = SimWorkload(mean_len=80, sigma_len=0.4, overthink_p=0.1,
                           prompt_len=16, prm_drift=prm_drift, prm_noise=0.05)
    engine = SimEngine(SimEngineConfig(max_slots=32, page_size=8,
                                       num_pages=8192, prefill_chunk=8),
                       workload, seed=seed)
    cfg = SchedulerConfig(policy=policy, n=n, m=m, beta=beta, window=window,
                          max_tokens=1 << 20)
    sch = Scheduler(engine, SimPRM(engine), cfg, answer_fn=extract_answer)
    rng = np.random.default_rng(seed + 1)
    for i in range(num_requests):
        task = SimTask(answer=int(rng.integers(0, 10)))
        prompt = [tk.BOS] + [tk.digit(0)] * 14 + [tk.EQUALS]
        req = sch.submit(prompt, payload=task, arrival=i * 5)
        engine.tasks[req.request_id] = task
    return engine, sch


def test_sart_stops_at_exactly_m_completions():
    """Early stop fires at the M-th completion: no request ever records more
    than M, and requests that aren't starved by pruning record exactly M."""
    n, m = 8, 4
    engine, sch = _sim_sched(n=n, m=m)
    metrics = sch.run(max_steps=500_000)
    assert len(metrics["requests"]) == 12
    for r in metrics["requests"]:
        assert r["num_completed"] <= m, "ran past the early-stop point"
        if r["num_completed"] + r["num_pruned"] < n:
            # branches were still live when the request finalized, so the
            # only way to finish is hitting M exactly
            assert r["num_completed"] == m
    assert any(r["num_completed"] == m for r in metrics["requests"])
    assert engine.allocator.used_pages == 0


class _RecordingPruner(TwoPhasePruner):
    def __init__(self, inner: TwoPhasePruner):
        super().__init__(inner.cfg)
        self.rounds = []            # (phase_at_call, num_pruned_this_round)

    def select_prunes(self, meta, rewards):
        phase = meta.phase
        victims = super().select_prunes(meta, rewards)
        self.rounds.append((phase, len(victims)))
        return victims


def test_phase1_never_prunes_more_than_beta_per_round():
    beta = 2
    engine, sch = _sim_sched(n=8, m=4, beta=beta, prm_drift=0.5)
    sch.pruner = _RecordingPruner(sch.pruner)
    sch.run(max_steps=500_000)
    explore_rounds = [k for p, k in sch.pruner.rounds if p == "explore"]
    assert explore_rounds, "no explore-phase pruning round ever ran"
    assert all(k <= beta for k in explore_rounds), \
        "phase-1 round exceeded the beta cap"
    assert engine.allocator.used_pages == 0


def test_branch_at_block_table_capacity_is_evicted_not_crashed():
    """A branch whose prompt + generation outgrows the static block table
    must be force-completed via the memory-pressure path (latent in the
    seed: the table-refresh assert crashed the engine instead)."""
    from repro.data import tasks

    cfg = tiny_config(vocab_size=tk.VOCAB_SIZE)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    # capacity 12 pages * 4 = 48 tokens < prompt (~15) + max_tokens (64)
    eng = Engine(model, params, EngineConfig(
        page_size=4, num_pages=64, max_slots=2, max_pages_per_branch=12,
        eos_id=tk.EOS, sampling=SamplingParams(temperature=1.0), seed=1))
    prm = OraclePRM(tasks.oracle_grader, noise=0.05, seed=2)
    sch = Scheduler(eng, prm, SchedulerConfig(policy="vanilla", n=1,
                                              window=8, max_tokens=64),
                    answer_fn=extract_answer)
    rng = np.random.default_rng(3)
    for i in range(2):
        p = tasks.gen_problem(rng)
        sch.submit(p.prompt_tokens(), payload=p, arrival=i)
    m = sch.run(max_steps=10000)
    assert len(m["requests"]) == 2
    assert eng.allocator.used_pages == 0
    assert all(s is None for s in eng.slots)


@pytest.mark.parametrize("family_kw", [
    dict(arch_type="ssm", d_ff=0, ssm_state=16, ssm_head_dim=32, ssm_chunk=8),
    dict(arch_type="hybrid", ssm_state=16, ssm_head_dim=32, ssm_chunk=8),
])
def test_ssm_requests_admit_async_through_scheduler(family_kw):
    """Uniform admission (Algorithm 1, all families): ssm/hybrid requests
    go through the asynchronous chunked path — parked on ``prefilling``,
    chunks riding decode steps — and complete without leaks, with the
    bucketed compile bound holding end-to-end."""
    from repro.data import tasks

    cfg = tiny_config(vocab_size=tk.VOCAB_SIZE, **family_kw)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = Engine(model, params, EngineConfig(
        page_size=8, num_pages=256, max_slots=4, max_pages_per_branch=16,
        eos_id=tk.EOS, sampling=SamplingParams(temperature=1.0), seed=1,
        prefill_chunk=8))
    prm = OraclePRM(tasks.oracle_grader, noise=0.05, seed=2)
    sch = Scheduler(eng, prm, SchedulerConfig(policy="sart", n=2, m=1,
                                              window=8, max_tokens=24),
                    answer_fn=extract_answer)
    rng = np.random.default_rng(3)
    for i in range(3):
        p = tasks.gen_problem(rng)
        sch.submit(p.prompt_tokens(), payload=p, arrival=i * 2)

    saw_async = []
    orig = sch._admit

    def spy(req):
        orig(req)
        # sync admission harvests inline and clears prefill_state
        saw_async.append(req.prefill_state is not None
                         and not req.prefill_state.done)
    sch._admit = spy

    m = sch.run(max_steps=10000)
    assert len(m["requests"]) == 3
    assert saw_async and all(saw_async), \
        "ssm admission fell back to the synchronous path"
    assert all(r["ttfb"] is not None and r["ttfb"] >= 0
               for r in m["requests"])
    assert eng.prefill_compile_count <= 2
    assert len(eng._prefill_cache) == 0          # exact path never used
    assert eng.allocator.used_pages == 0
    assert all(s is None for s in eng.slots)


# --------------------------------------------------- token-budget chunk lanes


def _pending(*remainings):
    """ChunkedPrefillStates with given remaining token counts (packer only
    reads ``remaining`` and ``passed_over``)."""
    return [ChunkedPrefillState(prompt=[0] * r, blocks=None)
            for r in remainings]


def _bucket_for(buckets):
    def f(st):
        n = min(8, st.remaining)            # prefill_chunk = 8
        for b in buckets:
            if b >= n:
                return b
        raise AssertionError(n)
    return f


def test_lane_packer_budget_never_exceeded():
    """Randomized packer invariants: padded chunk rows never exceed the
    budget, lane counts come from the allowed configs, selection is an
    oldest-first subsequence of the queue."""
    rng = np.random.default_rng(0)
    buckets = (4, 8)
    for _ in range(300):
        budget = int(rng.choice([8, 12, 16, 24, 32, 64]))
        configs = derive_lane_configs((), budget, buckets[-1])
        pending = _pending(*(int(r) for r in
                             rng.integers(1, 30, size=rng.integers(1, 9))))
        for st in pending:                  # arbitrary starvation history
            st.passed_over = int(rng.integers(0, 6))
        selected, bucket = pack_chunk_lanes(
            pending, budget=budget, chunk_bucket=_bucket_for(buckets),
            lane_configs=configs, starvation_bound=4)
        assert selected, "budget >= max bucket always fits the oldest"
        assert bucket * len(selected) <= budget
        assert len(selected) in configs
        assert bucket == max(_bucket_for(buckets)(st) for st in selected)
        idx = [pending.index(st) for st in selected]
        assert idx == sorted(idx), "selection must keep queue order"
        assert all(st.passed_over == 0 for st in selected)


def test_lane_packer_starvation_bound_honored():
    """A request's chunk that doesn't fit the remaining budget may be
    overtaken by smaller chunks behind it — but only ``starvation_bound``
    times; then nothing behind it packs until it is served."""
    buckets, bound = (4, 8), 3
    # budget 8: A (bucket 4) + C (bucket 4) pack together; B's bucket-8
    # chunk never fits beside A, so C keeps overtaking B — until B starves
    pending = _pending(4, 8, 4)
    a, b, c = pending
    for i in range(bound):
        selected, bucket = pack_chunk_lanes(
            pending, budget=8, chunk_bucket=_bucket_for(buckets),
            lane_configs=(1, 2), starvation_bound=bound)
        assert selected == [a, c] and bucket == 4   # C overtakes B
        assert b.passed_over == i + 1
    # B is starved now: the packer refuses to pack past it, reserving the
    # next step's budget — C no longer overtakes
    selected, bucket = pack_chunk_lanes(
        pending, budget=8, chunk_bucket=_bucket_for(buckets),
        lane_configs=(1, 2), starvation_bound=bound)
    assert selected == [a] and c not in selected
    # once A drains, the starved B is served immediately
    pending.remove(a)
    selected, bucket = pack_chunk_lanes(
        pending, budget=8, chunk_bucket=_bucket_for(buckets),
        lane_configs=(1, 2), starvation_bound=bound)
    assert selected == [b] and bucket == 8


def test_lane_packer_compile_count_stays_bucketed():
    """Engine-level acceptance: ragged prompts admitted through multi-lane
    packing trace at most len(buckets) x len(lane_configs) mixed-step
    shapes, each within the token budget."""
    cfg = tiny_config()
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = Engine(model, params, EngineConfig(
        page_size=4, num_pages=512, max_slots=2, max_pages_per_branch=24,
        eos_id=1, prefill_chunk=8, step_token_budget=16))
    rng = np.random.default_rng(0)
    sts = [eng.begin_prefill([int(t) for t in
                              rng.integers(2, cfg.vocab_size, size=s)])
           for s in range(3, 19)]          # 16 distinct ragged lengths
    while any(not st.done for st in sts):
        eng.decode_step()
    bound = len(eng._buckets) * len(eng._lane_configs)
    assert eng.prefill_compile_count <= bound
    for bucket, lanes in eng._buckets_used:
        assert bucket * lanes <= 16, "a traced shape exceeded the budget"
        assert lanes in eng._lane_configs
    for st in sts:
        eng.release_prefix(st.blocks)
    assert eng.allocator.used_pages == 0


def test_lane_budget_never_exceeded_through_sim_engine():
    """SimEngine mirror: per decode step, at most budget // chunk pending
    prefills advance."""
    ec = SimEngineConfig(max_slots=8, page_size=8, num_pages=4096,
                         prefill_chunk=8, step_token_budget=24)
    eng = SimEngine(ec, SimWorkload(prompt_len=40), seed=0)
    assert eng.admission_capacity == 3
    sts = [eng.begin_prefill([0] * 40) for _ in range(6)]
    while any(not st.done for st in sts):
        before = eng.prefill_chunk_steps
        eng.decode_step()
        assert eng.prefill_chunk_steps - before <= 24 // 8
    for st in sts:
        eng.release_prefix(st.blocks)
    assert eng.allocator.used_pages == 0


def test_lane_budget_one_chunk_is_bit_exact_with_legacy_sim():
    """Acceptance: step_token_budget = one chunk reproduces the legacy
    single-lane FIFO run metric-for-metric (same seeds, bursty
    arrivals)."""
    w = SimWorkload(mean_len=100, sigma_len=0.5, prompt_len=128)
    times = [0, 0, 0, 20, 20, 40, 40, 40, 40, 60]
    runs = []
    for budget in (0, 64):
        ec = SimEngineConfig(max_slots=32, page_size=16, num_pages=65536,
                             prefill_chunk=64, step_token_budget=budget)
        m, acc = run_sim_experiment("sart", 4, num_requests=10, workload=w,
                                    engine_cfg=ec, window=50, seed=3,
                                    arrival_times=times)
        runs.append((m, acc))
    (m0, a0), (m1, a1) = runs
    assert a0 == a1 and m0["clock"] == m1["clock"]
    for r0, r1 in zip(m0["requests"], m1["requests"]):
        assert r0 == r1, "budget=one-chunk diverged from legacy FIFO"


def test_lane_multi_beats_single_ttfb_under_bursts():
    """The tentpole claim at sim scale: under Poisson-burst arrivals,
    multi-lane token-budget packing strictly improves median
    time-to-first-branch over the single FIFO lane."""
    from repro.core.scheduler import percentile_latency
    from repro.serving.simulator import poisson_burst_arrivals
    w = SimWorkload(mean_len=400, sigma_len=0.6, prompt_len=512)
    times = poisson_burst_arrivals(24, burst_gap=30, burst_mean=5)
    ttfb = {}
    for name, budget in (("single", 64), ("multi", 256)):
        ec = SimEngineConfig(max_slots=128, num_pages=500000,
                             prefill_chunk=64, step_token_budget=budget)
        m, _ = run_sim_experiment("sart", 4, num_requests=24, workload=w,
                                  engine_cfg=ec, window=100, seed=0,
                                  arrival_times=times)
        ttfb[name] = percentile_latency(m, 50, "ttfb")
    assert ttfb["multi"] < ttfb["single"]


def test_prefix_cache_improves_shared_header_burst_ttfb():
    """Prefix caching at sim scale: on a shared-few-shot-header Poisson
    burst, warm admissions skip the cached header's chunk steps, so
    single-lane median time-to-first-branch strictly improves and the
    metrics dict reports the hit rate."""
    from repro.core.scheduler import percentile_latency
    from repro.serving.simulator import poisson_burst_arrivals
    w = SimWorkload(mean_len=200, sigma_len=0.6, prompt_len=512,
                    prompt_tail=64)
    times = poisson_burst_arrivals(12, burst_gap=30, burst_mean=5)
    ttfb, hit_rate = {}, {}
    for cached in (False, True):
        ec = SimEngineConfig(max_slots=128, num_pages=500000,
                             prefill_chunk=64, step_token_budget=64,
                             prefix_cache=cached)
        m, _ = run_sim_experiment("sart", 4, num_requests=12, workload=w,
                                  engine_cfg=ec, window=100, seed=0,
                                  arrival_times=times)
        ttfb[cached] = percentile_latency(m, 50, "ttfb")
        pc = m.get("prefix_cache")
        hit_rate[cached] = pc["hit_rate"] if pc else None
    assert hit_rate[False] is None and hit_rate[True] > 0.5
    assert ttfb[True] < ttfb[False]


def test_prefix_cache_sim_conserves_pages_end_to_end():
    """A full scheduler run over a caching SimEngine drains to zero live
    pages with the allocator + cache invariants intact (release parks
    shared prefixes on the cache's LRU free-list instead of freeing
    them — they are warm capacity, not leaks)."""
    workload = SimWorkload(mean_len=60, sigma_len=0.4, prompt_len=64,
                           prm_drift=6.0, prm_noise=0.05)
    engine = SimEngine(SimEngineConfig(max_slots=16, page_size=8,
                                       num_pages=4096, prefill_chunk=16,
                                       step_token_budget=32,
                                       prefix_cache=True),
                       workload, seed=1)
    cfg = SchedulerConfig(policy="sart", n=4, m=2, window=10,
                          max_tokens=1 << 20)
    sch = Scheduler(engine, SimPRM(engine), cfg, answer_fn=extract_answer)
    rng = np.random.default_rng(2)
    for i in range(8):
        task = SimTask(answer=int(rng.integers(0, 10)))
        prompt = [tk.BOS] + [tk.digit(0)] * 56 \
            + [tk.digit(i % 3)] * 6 + [tk.EQUALS]
        req = sch.submit(prompt, payload=task, arrival=i * 5)
        engine.tasks[req.request_id] = task
    m = sch.run(max_steps=500_000)
    assert len(m["requests"]) == 8
    assert m["prefix_cache"]["hit_tokens"] > 0
    engine.allocator.check_invariants()
    assert engine.allocator.used_pages == 0, \
        "live pages leaked (cached-idle LRU pages must not count as used)"
    assert engine.prefix_cache.evictable == \
        engine.prefix_cache.tracked_pages


# ------------------------------------------- admission policies + accounting


def test_policy_parse_and_compose():
    assert make_policy("fifo").name == "fifo"
    assert make_policy("lpm").name == "lpm"
    # every separator spelling builds the same composition
    for spec in ("priority+lpm", "priority-then-lpm", "priority,lpm"):
        p = make_policy(spec)
        assert p.name == "priority+lpm"
    with pytest.raises(ValueError):
        make_policy("sjf")
    with pytest.raises(ValueError):
        make_policy("")


def test_policy_select_next_starvation_bound():
    """A request may be passed over by policy-preferred younger requests
    only ``starvation_bound`` times; then it preempts the ordering."""
    bound = 3
    policy = make_policy("priority")
    old = Request(0, [tk.BOS], arrival=0, priority=0)
    for i in range(bound):
        urgent = Request(1 + i, [tk.BOS], arrival=0, priority=5)
        chosen = select_next(policy, [old, urgent], None, bound)
        assert chosen is urgent
        assert old.passed_over == i + 1
    # old is starved now: it wins despite the lower priority tier
    urgent = Request(99, [tk.BOS], arrival=0, priority=5)
    chosen = select_next(policy, [old, urgent], None, bound)
    assert chosen is old and old.passed_over == 0
    # under fifo the oldest request always wins and nothing accrues
    fifo = make_policy("fifo")
    a, b = Request(3, [tk.BOS], arrival=0), Request(7, [tk.BOS], arrival=0)
    assert select_next(fifo, [b, a], None, bound) is a
    assert b.passed_over == 0


def test_policy_out_of_order_arrival_not_head_blocked():
    """Seed bug: ``_arrived`` peeked only the queue head, so an arrived
    request submitted behind a future-arrival head waited for the head's
    arrival clock. Admission must select over the whole arrived set."""
    w = SimWorkload(mean_len=40, sigma_len=0.4, prompt_len=16,
                    prm_drift=6.0, prm_noise=0.05)
    ec = SimEngineConfig(max_slots=16, page_size=8, num_pages=4096,
                         prefill_chunk=8)
    m, _ = run_sim_experiment("sart", 4, num_requests=2, workload=w,
                              engine_cfg=ec, window=20, seed=0,
                              arrival_times=[500, 0])
    late, early = m["requests"][0], m["requests"][1]
    assert early["first_service"] is not None and early["first_service"] < 500
    assert late["first_service"] >= 500
    assert m["unfinished_requests"] == 0


def _burst_digest(m, acc):
    recs = tuple(
        (r["request_id"], r["arrival"], r["first_service"], r["ttfb"],
         r["finish"], r["e2e"], r["num_completed"], r["num_pruned"],
         tuple(r["response_lengths"]))
        for r in m["requests"])
    pc = m.get("prefix_cache")
    return (m["clock"], m["decode_steps"], round(acc, 6),
            pc["hit_tokens"] if pc else None, recs)


# Captured from the pre-PR scheduler (before the admission-policy layer)
# on the fig5 burst workloads — policy="fifo" must stay bit-exact.
_GOLDEN_FIFO = {
    "single": (500, 500, 0.916667, None, (
        (0, 0, 8, 8, 200, 200, 1, 3, (96,)),
        (1, 0, 16, 16, 200, 200, 1, 3, (131,)),
        (2, 0, 24, 24, 200, 200, 1, 3, (112,)),
        (3, 0, 32, 32, 500, 500, 1, 3, (451,)),
        (4, 0, 40, 40, 138, 138, 2, 1, (95, 99)),
        (5, 0, 48, 48, 300, 300, 1, 3, (156,)),
        (6, 0, 56, 56, 500, 500, 1, 3, (368,)),
        (7, 30, 64, 34, 200, 170, 1, 3, (130,)),
        (8, 30, 72, 42, 214, 184, 2, 2, (107, 143)),
        (9, 30, 80, 50, 334, 304, 2, 2, (129, 255)),
        (10, 30, 88, 58, 421, 391, 2, 2, (217, 334)),
        (11, 30, 96, 66, 300, 270, 1, 3, (110,)))),
    "multi_cached": (499, 499, 1.0, 3584, (
        (0, 0, 8, 8, 225, 225, 2, 2, (96, 218)),
        (1, 0, 8, 8, 200, 200, 1, 3, (131,)),
        (2, 0, 8, 8, 286, 286, 2, 2, (112, 279)),
        (3, 0, 8, 8, 499, 499, 2, 2, (451, 492)),
        (4, 0, 10, 10, 108, 108, 2, 1, (95, 99)),
        (5, 0, 10, 10, 165, 165, 2, 1, (56, 156)),
        (6, 0, 12, 12, 400, 400, 1, 3, (368,)),
        (7, 30, 32, 2, 200, 170, 1, 3, (143,)),
        (8, 30, 32, 2, 200, 170, 1, 3, (129,)),
        (9, 30, 32, 2, 300, 270, 1, 3, (187,)),
        (10, 30, 31, 1, 200, 170, 1, 3, (130,)),
        (11, 30, 32, 2, 100, 70, 1, 3, (51,)))),
}


def test_policy_fifo_bit_exact_with_pre_policy_scheduler():
    """Acceptance: admission_policy="fifo" reproduces the pre-policy-layer
    scheduler metric-for-metric on the fig5 burst workloads (single-lane
    uncached and multi-lane cached), pinned by golden digests."""
    w = SimWorkload(mean_len=200, sigma_len=0.6, overthink_p=0.12,
                    correct_p=0.55, prompt_len=512, prompt_tail=64)
    times = poisson_burst_arrivals(12, burst_gap=30, burst_mean=5)
    for tag, budget, cached in (("single", 64, False),
                                ("multi_cached", 256, True)):
        ec = SimEngineConfig(max_slots=128, num_pages=500000,
                             prefill_chunk=64, step_token_budget=budget,
                             prefix_cache=cached)
        m, acc = run_sim_experiment("sart", 4, num_requests=12, workload=w,
                                    engine_cfg=ec, window=100, seed=0,
                                    arrival_times=times,
                                    admission_policy="fifo")
        assert _burst_digest(m, acc) == _GOLDEN_FIFO[tag], tag


def test_policy_lpm_without_cache_degrades_to_fifo():
    """LPM's probe returns 0 for every request on a cache-less engine, so
    the request_id tiebreak makes it bit-exact with fifo."""
    w = SimWorkload(mean_len=100, sigma_len=0.5, prompt_len=128)
    runs = []
    for pol in ("fifo", "lpm"):
        ec = SimEngineConfig(max_slots=32, page_size=16, num_pages=65536,
                             prefill_chunk=64)
        m, acc = run_sim_experiment("sart", 4, num_requests=10, workload=w,
                                    engine_cfg=ec, window=50, seed=3,
                                    arrival_times=[0, 0, 0, 20, 20, 40, 40,
                                                   40, 40, 60],
                                    admission_policy=pol)
        runs.append(_burst_digest(m, acc))
    assert runs[0] == runs[1]


def test_policy_lpm_beats_fifo_warm_hit_rate():
    """Tentpole acceptance at sim scale: on the adversarial shared-header
    burst under page pressure (cold prompts submitted ahead of warm ones,
    num_pages tight enough that cold admissions evict the idle header),
    LPM ordering strictly improves the warm-hit token rate — it admits
    cached-prefix matches first, pinning the header pages."""
    prompts, times = adversarial_shared_header_mix()
    w = SimWorkload(mean_len=80, sigma_len=0.5, overthink_p=0.1,
                    correct_p=0.55, prompt_len=512)
    ec = SimEngineConfig(max_slots=128, num_pages=280, prefill_chunk=64,
                         step_token_budget=256, prefix_cache=True)
    rate = {}
    for pol in ("fifo", "lpm"):
        m, _ = run_sim_experiment(
            "sart", 4, num_requests=len(prompts), workload=w, engine_cfg=ec,
            window=100, seed=0, arrival_times=times, prompts=prompts,
            admission_policy=pol)
        recs = m["requests"]
        assert m["unfinished_requests"] == 0
        rate[pol] = (sum(r["cached_tokens"] for r in recs)
                     / sum(r["prompt_tokens"] for r in recs))
    assert rate["lpm"] > rate["fifo"]


def test_policy_edf_beats_fifo_deadline_attainment():
    """Tentpole acceptance at sim scale: on the mixed-deadline workload
    over a serialized single chunk lane, EDF strictly improves SLO
    attainment — fifo drains the loose-deadline backlog first and the
    late-arriving tight requests miss."""
    times, deadlines = mixed_deadline_workload()
    w = SimWorkload(mean_len=40, sigma_len=0.5, overthink_p=0.1,
                    correct_p=0.55, prompt_len=512)
    ec = SimEngineConfig(max_slots=64, num_pages=500000, prefill_chunk=64,
                         step_token_budget=64)
    att = {}
    for pol in ("fifo", "edf"):
        m, _ = run_sim_experiment(
            "sart", 4, num_requests=len(times), workload=w, engine_cfg=ec,
            window=100, seed=0, arrival_times=times, admission_policy=pol,
            deadlines=deadlines)
        slo = m["slo"]
        assert slo["with_deadline"] == len(times)
        assert slo["deadline_met"] + slo["deadline_missed"] == len(times)
        att[pol] = slo["attainment"]
    assert att["edf"] > att["fifo"]


def test_prefix_cache_probe_is_non_mutating():
    """``match_tokens`` (the LPM probe, run over every queued request each
    admission opportunity) must be observationally free: no references
    taken, no LRU reorder, no hit/lookup counter movement."""
    eng = SimEngine(SimEngineConfig(max_slots=4, page_size=8, num_pages=64,
                                    prefill_chunk=8, prefix_cache=True),
                    SimWorkload(prompt_len=32), seed=0)
    prompt = [tk.BOS] + [tk.digit(0)] * 30 + [tk.EQUALS]
    st = eng.begin_prefill(prompt)
    while not st.done:
        eng.decode_step()
    blocks, _, _ = eng.finish_prefill(st)
    eng.release_prefix(blocks)          # park the pages on the cache's LRU
    cache = eng.prefix_cache
    before = cache.stats()
    lru_before = list(cache.lru_pages)
    refs_before = [eng.allocator.refcount(p) for p in lru_before]
    # warm probe: matches the cached pages, capped so the last prompt
    # token is always recomputed ((32 - 1) // 8 = 3 pages)
    assert eng.match_cached_tokens(prompt) == 24
    # cold probe: no match
    assert eng.match_cached_tokens([tk.digit(3)] * 32) == 0
    assert cache.stats() == before
    assert list(cache.lru_pages) == lru_before
    assert [eng.allocator.refcount(p) for p in lru_before] == refs_before


def test_truncated_completion_keeps_pruning_threshold():
    """Satellite bugfix: a truncated completion (force-eviction or
    max-token cap) counts toward early stop but must not flip the pruner
    to phase 2 or seed the α′ threshold with a phantom reward."""
    pruner = TwoPhasePruner(PruningConfig(alpha=0.5))
    meta = pruner.new_meta(8, 4)
    pruner.on_completion(meta, 0.95, truncated=True)
    assert meta.phase == "explore"
    assert meta.threshold == 0.5            # still α, not the phantom 0.95
    assert meta.num_completed == 1 and meta.num_truncated == 1
    # a genuine completion then flips the phase with ITS reward as α′
    pruner.on_completion(meta, 0.7)
    assert meta.phase == "exploit" and meta.threshold == 0.7
    assert meta.max_num_pruned == meta.n - 1
    assert meta.num_completed == 2 and meta.num_truncated == 1


def test_truncated_completions_surface_in_metrics():
    """max-token-capped branches count as truncated in the per-request
    record; capped runs finish instead of spinning."""
    w = SimWorkload(mean_len=120, sigma_len=0.4, prompt_len=16,
                    prm_drift=6.0, prm_noise=0.05)
    ec = SimEngineConfig(max_slots=16, page_size=8, num_pages=4096,
                         prefill_chunk=8)
    m, _ = run_sim_experiment("sart", 4, num_requests=4, workload=w,
                              engine_cfg=ec, window=20, seed=0,
                              max_tokens=20)
    assert m["unfinished_requests"] == 0
    assert sum(r["num_truncated"] for r in m["requests"]) > 0
    for r in m["requests"]:
        assert r["num_truncated"] <= r["num_completed"]


class _FixedPRM:
    """PRM stub with per-branch canned rewards (records nothing else)."""

    def __init__(self, rewards):
        self.rewards = rewards

    def score(self, request, handles):
        return [self.rewards[h.branch_id] for h in handles]


def test_preemption_scores_unscored_victims():
    """Satellite bugfix: victim selection must not default an unscored
    branch's reward to 0.0 — a strong branch that simply hasn't hit a
    scoring window yet would always be the victim."""
    engine = SimEngine(SimEngineConfig(max_slots=2, page_size=8,
                                       num_pages=1024, prefill_chunk=8),
                       SimWorkload(mean_len=500, prompt_len=8), seed=0)
    cfg = SchedulerConfig(policy="sart", n=2, m=2, preempt=True, window=4,
                          max_tokens=1 << 20)
    prm = _FixedPRM({0: 0.2, 1: 0.9})
    sch = Scheduler(engine, prm, cfg, answer_fn=extract_answer)
    req0 = sch.submit([tk.BOS] * 8)
    req1 = sch.submit([tk.BOS] * 8)
    blocks, lg, ssm = engine.prefill(req0.prompt)
    weak = engine.spawn_branch(req0.request_id, blocks, lg, ssm, 8)
    strong = engine.spawn_branch(req0.request_id, blocks, lg, ssm, 8)
    req0.live = {weak.branch_id: weak, strong.branch_id: strong}
    req0.prefix_blocks = blocks
    # the weak branch was scored at a pruning window; the strong one never
    weak.last_reward = 0.2
    weak.scored = True
    # a waiting branch spawn justifies preempting (both slots are taken)
    blocks1, lg1, ssm1 = engine.prefill(req1.prompt)
    req1.prefix_blocks, req1.last_logits = blocks1, lg1
    req1.ssm_state, req1.pending = ssm1, 1
    sch.branch_queue.append(req1)
    sch._maybe_preempt()
    # seed bug: strong (unscored, last_reward 0.0) was the victim; fixed
    # selection scores it first (0.9) and suspends the weak branch
    assert sch.suspended and sch.suspended[0] is weak
    assert weak.slot == -1
    assert strong.scored and strong.slot >= 0
    assert req1.pending == 0                 # the waiting spawn got the slot


def test_metrics_emit_unfinished_requests():
    """Satellite bugfix: a run stopped at max_steps must report still-live
    requests (finish=None) instead of silently dropping them — omitting
    them survivorship-biases percentiles exactly under overload."""
    w = SimWorkload(mean_len=60, sigma_len=0.4, prompt_len=16,
                    prm_drift=6.0, prm_noise=0.05)
    ec = SimEngineConfig(max_slots=16, page_size=8, num_pages=4096,
                         prefill_chunk=8)
    m, _ = run_sim_experiment("sart", 4, num_requests=6, workload=w,
                              engine_cfg=ec, window=20, seed=0,
                              arrival_times=[0, 0, 100, 100, 5000, 5000],
                              max_steps=300)
    assert len(m["requests"]) == 6
    assert m["completed_requests"] + m["unfinished_requests"] == 6
    assert m["unfinished_requests"] >= 2     # the t=5000 pair never arrived
    for r in m["requests"]:
        if r["finish"] is None:
            assert r["e2e"] is None and r["inference"] is None
        else:
            assert r["e2e"] == r["finish"] - r["arrival"]
    # percentiles skip the None fields instead of crashing or zeroing
    assert np.isfinite(percentile_latency(m, 50))


def test_truncated_run_drains_prefill_states():
    """Satellite bugfix: stopping run() at max_steps while prompts are
    mid-chunked-prefill must abort those ChunkedPrefillStates (freeing
    their partial KV pages) and requeue the requests — the allocator
    invariants hold after EVERY run, truncated or not."""
    w = SimWorkload(mean_len=80, sigma_len=0.4, prompt_len=256)
    engine = SimEngine(SimEngineConfig(max_slots=8, page_size=8,
                                       num_pages=4096, prefill_chunk=16),
                       w, seed=0)                # 16 chunk-steps per prompt
    cfg = SchedulerConfig(policy="sart", n=4, window=10, max_tokens=1 << 20)
    sch = Scheduler(engine, SimPRM(engine), cfg, answer_fn=extract_answer)
    for i in range(4):
        task = SimTask()
        req = sch.submit([tk.BOS] + [tk.digit(i)] * 254 + [tk.EQUALS],
                         payload=task, arrival=0)
        engine.tasks[req.request_id] = task
    m = sch.run(max_steps=1)                 # one window: prefills in flight
    assert m["unfinished_requests"] == 4
    assert sch.prefilling == [] and not engine.has_pending_prefill
    engine.allocator.check_invariants()
    assert engine.allocator.used_pages == 0  # partial prefill pages freed
    # requeued, not dropped: every unfinished request is still schedulable
    queued = {r.request_id for r in sch.request_queue}
    for r in m["requests"]:
        if r["finish"] is None:
            assert r["request_id"] in queued


@pytest.mark.parametrize("family_kw", [
    dict(arch_type="ssm", d_ff=0, ssm_state=16, ssm_head_dim=32, ssm_chunk=8),
    dict(arch_type="hybrid", ssm_state=16, ssm_head_dim=32, ssm_chunk=8),
])
def test_suspend_resume_roundtrips_ssm_state_bit_exactly(family_kw):
    """suspend_branch snapshots conv/ssd to host; resume_branch must restore
    the slot rows bit-for-bit even after another branch dirtied them."""
    cfg = tiny_config(**family_kw)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = Engine(model, params, EngineConfig(
        page_size=4, num_pages=64, max_slots=2, max_pages_per_branch=16,
        eos_id=1, sampling=SamplingParams(temperature=0.0), seed=0))
    blocks, lg, ssm = eng.prefill([2, 5, 9, 13])
    h = eng.spawn_branch(0, blocks, lg, ssm, 4)
    for _ in range(3):
        eng.decode_step()
    slot = h.slot
    conv_before = np.asarray(eng.state["conv"][:, slot])
    ssd_before = np.asarray(eng.state["ssd"][:, slot])

    eng.suspend_branch(h)
    other = eng.spawn_branch(1, blocks, lg, ssm, 4)   # dirty the slot rows
    for _ in range(2):
        eng.decode_step()
    eng.free_branch(other)
    assert eng.resume_branch(h)

    conv_after = np.asarray(eng.state["conv"][:, h.slot])
    ssd_after = np.asarray(eng.state["ssd"][:, h.slot])
    np.testing.assert_array_equal(conv_before, conv_after)
    np.testing.assert_array_equal(ssd_before, ssd_after)

    eng.free_branch(h)
    eng.release_prefix(blocks)
    assert eng.allocator.used_pages == 0


def test_request_queue_membership_is_by_identity():
    """Request declares eq=False (reprolint REP004): two requests with
    identical field values must not alias under the `in`/.remove queue
    operations the scheduler's prefill poll relies on."""
    a = Request(request_id=0, prompt=[1, 2, 3], arrival=0)
    b = Request(request_id=0, prompt=[1, 2, 3], arrival=0)
    assert a != b and a == a           # identity, not field equality
    queue = [a, b]
    assert queue.index(b) == 1         # not confused with a
    queue.remove(b)
    assert queue == [a]                # removed b itself, not a
    assert hash(a) != hash(b) or a is b
