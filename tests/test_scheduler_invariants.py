"""Scheduler/engine invariants from Algorithm 1: early stop at exactly M,
phase-1 pruning capped at beta per round, suspend/resume round-tripping
SSM state bit-exactly, and the token-budget chunk-lane packer (budget
never exceeded, bounded starvation, O(buckets x lane-configs) compiles —
see docs/scheduling.md)."""
import jax
import numpy as np
import pytest

from repro.core import OraclePRM, Scheduler, SchedulerConfig
from repro.core.pruning import TwoPhasePruner
from repro.data import tokenizer as tk
from repro.data.tasks import extract_answer
from repro.models import Model
from repro.serving import Engine, EngineConfig, SamplingParams
from repro.serving.engine import (ChunkedPrefillState, derive_lane_configs,
                                  pack_chunk_lanes)
from repro.serving.simulator import (SimEngine, SimEngineConfig, SimPRM,
                                     SimTask, SimWorkload,
                                     run_sim_experiment)

from conftest import tiny_config


def _sim_sched(policy="sart", n=8, m=4, beta=2, num_requests=12, seed=0,
               window=10, prm_drift=6.0):
    workload = SimWorkload(mean_len=80, sigma_len=0.4, overthink_p=0.1,
                           prompt_len=16, prm_drift=prm_drift, prm_noise=0.05)
    engine = SimEngine(SimEngineConfig(max_slots=32, page_size=8,
                                       num_pages=8192, prefill_chunk=8),
                       workload, seed=seed)
    cfg = SchedulerConfig(policy=policy, n=n, m=m, beta=beta, window=window,
                          max_tokens=1 << 20)
    sch = Scheduler(engine, SimPRM(engine), cfg, answer_fn=extract_answer)
    rng = np.random.default_rng(seed + 1)
    for i in range(num_requests):
        task = SimTask(answer=int(rng.integers(0, 10)))
        prompt = [tk.BOS] + [tk.digit(0)] * 14 + [tk.EQUALS]
        req = sch.submit(prompt, payload=task, arrival=i * 5)
        engine.tasks[req.request_id] = task
    return engine, sch


def test_sart_stops_at_exactly_m_completions():
    """Early stop fires at the M-th completion: no request ever records more
    than M, and requests that aren't starved by pruning record exactly M."""
    n, m = 8, 4
    engine, sch = _sim_sched(n=n, m=m)
    metrics = sch.run(max_steps=500_000)
    assert len(metrics["requests"]) == 12
    for r in metrics["requests"]:
        assert r["num_completed"] <= m, "ran past the early-stop point"
        if r["num_completed"] + r["num_pruned"] < n:
            # branches were still live when the request finalized, so the
            # only way to finish is hitting M exactly
            assert r["num_completed"] == m
    assert any(r["num_completed"] == m for r in metrics["requests"])
    assert engine.allocator.used_pages == 0


class _RecordingPruner(TwoPhasePruner):
    def __init__(self, inner: TwoPhasePruner):
        super().__init__(inner.cfg)
        self.rounds = []            # (phase_at_call, num_pruned_this_round)

    def select_prunes(self, meta, rewards):
        phase = meta.phase
        victims = super().select_prunes(meta, rewards)
        self.rounds.append((phase, len(victims)))
        return victims


def test_phase1_never_prunes_more_than_beta_per_round():
    beta = 2
    engine, sch = _sim_sched(n=8, m=4, beta=beta, prm_drift=0.5)
    sch.pruner = _RecordingPruner(sch.pruner)
    sch.run(max_steps=500_000)
    explore_rounds = [k for p, k in sch.pruner.rounds if p == "explore"]
    assert explore_rounds, "no explore-phase pruning round ever ran"
    assert all(k <= beta for k in explore_rounds), \
        "phase-1 round exceeded the beta cap"
    assert engine.allocator.used_pages == 0


def test_branch_at_block_table_capacity_is_evicted_not_crashed():
    """A branch whose prompt + generation outgrows the static block table
    must be force-completed via the memory-pressure path (latent in the
    seed: the table-refresh assert crashed the engine instead)."""
    from repro.data import tasks

    cfg = tiny_config(vocab_size=tk.VOCAB_SIZE)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    # capacity 12 pages * 4 = 48 tokens < prompt (~15) + max_tokens (64)
    eng = Engine(model, params, EngineConfig(
        page_size=4, num_pages=64, max_slots=2, max_pages_per_branch=12,
        eos_id=tk.EOS, sampling=SamplingParams(temperature=1.0), seed=1))
    prm = OraclePRM(tasks.oracle_grader, noise=0.05, seed=2)
    sch = Scheduler(eng, prm, SchedulerConfig(policy="vanilla", n=1,
                                              window=8, max_tokens=64),
                    answer_fn=extract_answer)
    rng = np.random.default_rng(3)
    for i in range(2):
        p = tasks.gen_problem(rng)
        sch.submit(p.prompt_tokens(), payload=p, arrival=i)
    m = sch.run(max_steps=10000)
    assert len(m["requests"]) == 2
    assert eng.allocator.used_pages == 0
    assert all(s is None for s in eng.slots)


@pytest.mark.parametrize("family_kw", [
    dict(arch_type="ssm", d_ff=0, ssm_state=16, ssm_head_dim=32, ssm_chunk=8),
    dict(arch_type="hybrid", ssm_state=16, ssm_head_dim=32, ssm_chunk=8),
])
def test_ssm_requests_admit_async_through_scheduler(family_kw):
    """Uniform admission (Algorithm 1, all families): ssm/hybrid requests
    go through the asynchronous chunked path — parked on ``prefilling``,
    chunks riding decode steps — and complete without leaks, with the
    bucketed compile bound holding end-to-end."""
    from repro.data import tasks

    cfg = tiny_config(vocab_size=tk.VOCAB_SIZE, **family_kw)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = Engine(model, params, EngineConfig(
        page_size=8, num_pages=256, max_slots=4, max_pages_per_branch=16,
        eos_id=tk.EOS, sampling=SamplingParams(temperature=1.0), seed=1,
        prefill_chunk=8))
    prm = OraclePRM(tasks.oracle_grader, noise=0.05, seed=2)
    sch = Scheduler(eng, prm, SchedulerConfig(policy="sart", n=2, m=1,
                                              window=8, max_tokens=24),
                    answer_fn=extract_answer)
    rng = np.random.default_rng(3)
    for i in range(3):
        p = tasks.gen_problem(rng)
        sch.submit(p.prompt_tokens(), payload=p, arrival=i * 2)

    saw_async = []
    orig = sch._admit

    def spy(req):
        orig(req)
        # sync admission harvests inline and clears prefill_state
        saw_async.append(req.prefill_state is not None
                         and not req.prefill_state.done)
    sch._admit = spy

    m = sch.run(max_steps=10000)
    assert len(m["requests"]) == 3
    assert saw_async and all(saw_async), \
        "ssm admission fell back to the synchronous path"
    assert all(r["ttfb"] is not None and r["ttfb"] >= 0
               for r in m["requests"])
    assert eng.prefill_compile_count <= 2
    assert len(eng._prefill_cache) == 0          # exact path never used
    assert eng.allocator.used_pages == 0
    assert all(s is None for s in eng.slots)


# --------------------------------------------------- token-budget chunk lanes


def _pending(*remainings):
    """ChunkedPrefillStates with given remaining token counts (packer only
    reads ``remaining`` and ``passed_over``)."""
    return [ChunkedPrefillState(prompt=[0] * r, blocks=None)
            for r in remainings]


def _bucket_for(buckets):
    def f(st):
        n = min(8, st.remaining)            # prefill_chunk = 8
        for b in buckets:
            if b >= n:
                return b
        raise AssertionError(n)
    return f


def test_lane_packer_budget_never_exceeded():
    """Randomized packer invariants: padded chunk rows never exceed the
    budget, lane counts come from the allowed configs, selection is an
    oldest-first subsequence of the queue."""
    rng = np.random.default_rng(0)
    buckets = (4, 8)
    for _ in range(300):
        budget = int(rng.choice([8, 12, 16, 24, 32, 64]))
        configs = derive_lane_configs((), budget, buckets[-1])
        pending = _pending(*(int(r) for r in
                             rng.integers(1, 30, size=rng.integers(1, 9))))
        for st in pending:                  # arbitrary starvation history
            st.passed_over = int(rng.integers(0, 6))
        selected, bucket = pack_chunk_lanes(
            pending, budget=budget, chunk_bucket=_bucket_for(buckets),
            lane_configs=configs, starvation_bound=4)
        assert selected, "budget >= max bucket always fits the oldest"
        assert bucket * len(selected) <= budget
        assert len(selected) in configs
        assert bucket == max(_bucket_for(buckets)(st) for st in selected)
        idx = [pending.index(st) for st in selected]
        assert idx == sorted(idx), "selection must keep queue order"
        assert all(st.passed_over == 0 for st in selected)


def test_lane_packer_starvation_bound_honored():
    """A request's chunk that doesn't fit the remaining budget may be
    overtaken by smaller chunks behind it — but only ``starvation_bound``
    times; then nothing behind it packs until it is served."""
    buckets, bound = (4, 8), 3
    # budget 8: A (bucket 4) + C (bucket 4) pack together; B's bucket-8
    # chunk never fits beside A, so C keeps overtaking B — until B starves
    pending = _pending(4, 8, 4)
    a, b, c = pending
    for i in range(bound):
        selected, bucket = pack_chunk_lanes(
            pending, budget=8, chunk_bucket=_bucket_for(buckets),
            lane_configs=(1, 2), starvation_bound=bound)
        assert selected == [a, c] and bucket == 4   # C overtakes B
        assert b.passed_over == i + 1
    # B is starved now: the packer refuses to pack past it, reserving the
    # next step's budget — C no longer overtakes
    selected, bucket = pack_chunk_lanes(
        pending, budget=8, chunk_bucket=_bucket_for(buckets),
        lane_configs=(1, 2), starvation_bound=bound)
    assert selected == [a] and c not in selected
    # once A drains, the starved B is served immediately
    pending.remove(a)
    selected, bucket = pack_chunk_lanes(
        pending, budget=8, chunk_bucket=_bucket_for(buckets),
        lane_configs=(1, 2), starvation_bound=bound)
    assert selected == [b] and bucket == 8


def test_lane_packer_compile_count_stays_bucketed():
    """Engine-level acceptance: ragged prompts admitted through multi-lane
    packing trace at most len(buckets) x len(lane_configs) mixed-step
    shapes, each within the token budget."""
    cfg = tiny_config()
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = Engine(model, params, EngineConfig(
        page_size=4, num_pages=512, max_slots=2, max_pages_per_branch=24,
        eos_id=1, prefill_chunk=8, step_token_budget=16))
    rng = np.random.default_rng(0)
    sts = [eng.begin_prefill([int(t) for t in
                              rng.integers(2, cfg.vocab_size, size=s)])
           for s in range(3, 19)]          # 16 distinct ragged lengths
    while any(not st.done for st in sts):
        eng.decode_step()
    bound = len(eng._buckets) * len(eng._lane_configs)
    assert eng.prefill_compile_count <= bound
    for bucket, lanes in eng._buckets_used:
        assert bucket * lanes <= 16, "a traced shape exceeded the budget"
        assert lanes in eng._lane_configs
    for st in sts:
        eng.release_prefix(st.blocks)
    assert eng.allocator.used_pages == 0


def test_lane_budget_never_exceeded_through_sim_engine():
    """SimEngine mirror: per decode step, at most budget // chunk pending
    prefills advance."""
    ec = SimEngineConfig(max_slots=8, page_size=8, num_pages=4096,
                         prefill_chunk=8, step_token_budget=24)
    eng = SimEngine(ec, SimWorkload(prompt_len=40), seed=0)
    assert eng.admission_capacity == 3
    sts = [eng.begin_prefill([0] * 40) for _ in range(6)]
    while any(not st.done for st in sts):
        before = eng.prefill_chunk_steps
        eng.decode_step()
        assert eng.prefill_chunk_steps - before <= 24 // 8
    for st in sts:
        eng.release_prefix(st.blocks)
    assert eng.allocator.used_pages == 0


def test_lane_budget_one_chunk_is_bit_exact_with_legacy_sim():
    """Acceptance: step_token_budget = one chunk reproduces the legacy
    single-lane FIFO run metric-for-metric (same seeds, bursty
    arrivals)."""
    w = SimWorkload(mean_len=100, sigma_len=0.5, prompt_len=128)
    times = [0, 0, 0, 20, 20, 40, 40, 40, 40, 60]
    runs = []
    for budget in (0, 64):
        ec = SimEngineConfig(max_slots=32, page_size=16, num_pages=65536,
                             prefill_chunk=64, step_token_budget=budget)
        m, acc = run_sim_experiment("sart", 4, num_requests=10, workload=w,
                                    engine_cfg=ec, window=50, seed=3,
                                    arrival_times=times)
        runs.append((m, acc))
    (m0, a0), (m1, a1) = runs
    assert a0 == a1 and m0["clock"] == m1["clock"]
    for r0, r1 in zip(m0["requests"], m1["requests"]):
        assert r0 == r1, "budget=one-chunk diverged from legacy FIFO"


def test_lane_multi_beats_single_ttfb_under_bursts():
    """The tentpole claim at sim scale: under Poisson-burst arrivals,
    multi-lane token-budget packing strictly improves median
    time-to-first-branch over the single FIFO lane."""
    from repro.core.scheduler import percentile_latency
    from repro.serving.simulator import poisson_burst_arrivals
    w = SimWorkload(mean_len=400, sigma_len=0.6, prompt_len=512)
    times = poisson_burst_arrivals(24, burst_gap=30, burst_mean=5)
    ttfb = {}
    for name, budget in (("single", 64), ("multi", 256)):
        ec = SimEngineConfig(max_slots=128, num_pages=500000,
                             prefill_chunk=64, step_token_budget=budget)
        m, _ = run_sim_experiment("sart", 4, num_requests=24, workload=w,
                                  engine_cfg=ec, window=100, seed=0,
                                  arrival_times=times)
        ttfb[name] = percentile_latency(m, 50, "ttfb")
    assert ttfb["multi"] < ttfb["single"]


def test_prefix_cache_improves_shared_header_burst_ttfb():
    """Prefix caching at sim scale: on a shared-few-shot-header Poisson
    burst, warm admissions skip the cached header's chunk steps, so
    single-lane median time-to-first-branch strictly improves and the
    metrics dict reports the hit rate."""
    from repro.core.scheduler import percentile_latency
    from repro.serving.simulator import poisson_burst_arrivals
    w = SimWorkload(mean_len=200, sigma_len=0.6, prompt_len=512,
                    prompt_tail=64)
    times = poisson_burst_arrivals(12, burst_gap=30, burst_mean=5)
    ttfb, hit_rate = {}, {}
    for cached in (False, True):
        ec = SimEngineConfig(max_slots=128, num_pages=500000,
                             prefill_chunk=64, step_token_budget=64,
                             prefix_cache=cached)
        m, _ = run_sim_experiment("sart", 4, num_requests=12, workload=w,
                                  engine_cfg=ec, window=100, seed=0,
                                  arrival_times=times)
        ttfb[cached] = percentile_latency(m, 50, "ttfb")
        pc = m.get("prefix_cache")
        hit_rate[cached] = pc["hit_rate"] if pc else None
    assert hit_rate[False] is None and hit_rate[True] > 0.5
    assert ttfb[True] < ttfb[False]


def test_prefix_cache_sim_conserves_pages_end_to_end():
    """A full scheduler run over a caching SimEngine drains to zero live
    pages with the allocator + cache invariants intact (release parks
    shared prefixes on the cache's LRU free-list instead of freeing
    them — they are warm capacity, not leaks)."""
    workload = SimWorkload(mean_len=60, sigma_len=0.4, prompt_len=64,
                           prm_drift=6.0, prm_noise=0.05)
    engine = SimEngine(SimEngineConfig(max_slots=16, page_size=8,
                                       num_pages=4096, prefill_chunk=16,
                                       step_token_budget=32,
                                       prefix_cache=True),
                       workload, seed=1)
    cfg = SchedulerConfig(policy="sart", n=4, m=2, window=10,
                          max_tokens=1 << 20)
    sch = Scheduler(engine, SimPRM(engine), cfg, answer_fn=extract_answer)
    rng = np.random.default_rng(2)
    for i in range(8):
        task = SimTask(answer=int(rng.integers(0, 10)))
        prompt = [tk.BOS] + [tk.digit(0)] * 56 \
            + [tk.digit(i % 3)] * 6 + [tk.EQUALS]
        req = sch.submit(prompt, payload=task, arrival=i * 5)
        engine.tasks[req.request_id] = task
    m = sch.run(max_steps=500_000)
    assert len(m["requests"]) == 8
    assert m["prefix_cache"]["hit_tokens"] > 0
    engine.allocator.check_invariants()
    assert engine.allocator.used_pages == 0, \
        "live pages leaked (cached-idle LRU pages must not count as used)"
    assert engine.prefix_cache.evictable == \
        engine.prefix_cache.tracked_pages


@pytest.mark.parametrize("family_kw", [
    dict(arch_type="ssm", d_ff=0, ssm_state=16, ssm_head_dim=32, ssm_chunk=8),
    dict(arch_type="hybrid", ssm_state=16, ssm_head_dim=32, ssm_chunk=8),
])
def test_suspend_resume_roundtrips_ssm_state_bit_exactly(family_kw):
    """suspend_branch snapshots conv/ssd to host; resume_branch must restore
    the slot rows bit-for-bit even after another branch dirtied them."""
    cfg = tiny_config(**family_kw)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = Engine(model, params, EngineConfig(
        page_size=4, num_pages=64, max_slots=2, max_pages_per_branch=16,
        eos_id=1, sampling=SamplingParams(temperature=0.0), seed=0))
    blocks, lg, ssm = eng.prefill([2, 5, 9, 13])
    h = eng.spawn_branch(0, blocks, lg, ssm, 4)
    for _ in range(3):
        eng.decode_step()
    slot = h.slot
    conv_before = np.asarray(eng.state["conv"][:, slot])
    ssd_before = np.asarray(eng.state["ssd"][:, slot])

    eng.suspend_branch(h)
    other = eng.spawn_branch(1, blocks, lg, ssm, 4)   # dirty the slot rows
    for _ in range(2):
        eng.decode_step()
    eng.free_branch(other)
    assert eng.resume_branch(h)

    conv_after = np.asarray(eng.state["conv"][:, h.slot])
    ssd_after = np.asarray(eng.state["ssd"][:, h.slot])
    np.testing.assert_array_equal(conv_before, conv_after)
    np.testing.assert_array_equal(ssd_before, ssd_after)

    eng.free_branch(h)
    eng.release_prefix(blocks)
    assert eng.allocator.used_pages == 0
