"""Chunked-bucketed prefill: equivalence with the exact-length path (all
model families — attention pad rows drop their page writes, ssm/hybrid pad
rows are masked-dt identity transitions), O(1) compile count in
prompt-length diversity, and decode-step piggybacking that never perturbs
running branches."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kv import OutOfPagesError
from repro.models import Model
from repro.serving import Engine, EngineConfig, SamplingParams

from conftest import FAMILY_CONFIGS, tiny_config

FAMILIES = {k: FAMILY_CONFIGS[k] for k in ("dense", "ssm", "hybrid")}


def _engine(cfg, temperature=0.0, slots=4, seed=0, **eng_kw):
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(seed))
    base = dict(page_size=4, num_pages=128, max_slots=slots,
                max_pages_per_branch=24, eos_id=1,
                sampling=SamplingParams(temperature=temperature), seed=seed,
                prefill_chunk=8)
    base.update(eng_kw)
    return model, params, Engine(model, params, EngineConfig(**base))


def _assert_ssm_close(ssm_a, ssm_b, atol=1e-5):
    assert (ssm_a is None) == (ssm_b is None)
    if ssm_a is not None:
        for got, want in zip(ssm_a, ssm_b):
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-4, atol=atol)


def _gather_prefix(eng, blocks, s):
    """Dense [L, s, kv, hd] view of the first s tokens of a branch's pages."""
    ps = eng.cfg.page_size
    k = np.asarray(eng.state["k_pages"])[:, :, blocks.pages]  # [L,kv,n,ps,hd]
    v = np.asarray(eng.state["v_pages"])[:, :, blocks.pages]
    k = np.moveaxis(k, 1, 3).reshape(k.shape[0], -1, k.shape[1], k.shape[-1])
    v = np.moveaxis(v, 1, 3).reshape(v.shape[0], -1, v.shape[1], v.shape[-1])
    return k[:, :s], v[:, :s]


# ragged lengths crossing page (ps=4), chunk (8) and bucket (4/8) boundaries
RAGGED = [1, 3, 4, 5, 7, 8, 9, 12, 13, 17, 23]


@pytest.mark.parametrize("s", RAGGED)
def test_chunked_matches_exact_prefill(s):
    """Same params, same prompt: the chunked-bucketed path must reproduce
    the exact-length program's last logits AND the K/V page contents."""
    cfg = tiny_config()
    rng = np.random.default_rng(s)
    prompt = [int(t) for t in rng.integers(2, cfg.vocab_size, size=s)]

    _, _, e_exact = _engine(cfg)
    _, _, e_chunk = _engine(cfg)
    b_e, lg_e, _ = e_exact.prefill(prompt, exact=True)
    b_c, lg_c, _ = e_chunk.prefill(prompt)          # chunked by default

    np.testing.assert_allclose(np.asarray(lg_e), np.asarray(lg_c),
                               rtol=1e-4, atol=1e-4)
    ke, ve = _gather_prefix(e_exact, b_e, s)
    kc, vc = _gather_prefix(e_chunk, b_c, s)
    np.testing.assert_allclose(ke, kc, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(ve, vc, rtol=1e-4, atol=1e-5)

    e_exact.release_prefix(b_e)
    e_chunk.release_prefix(b_c)
    assert e_chunk.allocator.used_pages == 0


@pytest.mark.parametrize("family", ["ssm", "hybrid"])
@pytest.mark.parametrize("s", [1, 4, 5, 8, 13, 17])
def test_chunked_matches_exact_prefill_ssm(family, s):
    """ssm/hybrid prompts through the masked-dt chunk lane must reproduce
    the exact-length program's last logits AND final per-layer (conv, ssd)
    state across ragged lengths spanning chunk/bucket/page boundaries."""
    cfg = tiny_config(**FAMILIES[family])
    rng = np.random.default_rng(s)
    prompt = [int(t) for t in rng.integers(2, cfg.vocab_size, size=s)]

    _, _, e_exact = _engine(cfg)
    _, _, e_chunk = _engine(cfg)
    b_e, lg_e, ssm_e = e_exact.prefill(prompt, exact=True)
    b_c, lg_c, ssm_c = e_chunk.prefill(prompt)      # chunked by default

    np.testing.assert_allclose(np.asarray(lg_e), np.asarray(lg_c),
                               rtol=1e-4, atol=1e-4)
    _assert_ssm_close(ssm_e, ssm_c)
    if cfg.uses_attention:
        ke, ve = _gather_prefix(e_exact, b_e, s)
        kc, vc = _gather_prefix(e_chunk, b_c, s)
        np.testing.assert_allclose(ke, kc, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(ve, vc, rtol=1e-4, atol=1e-5)
    assert len(e_chunk._prefill_cache) == 0         # exact path never used

    e_exact.release_prefix(b_e)
    e_chunk.release_prefix(b_c)
    assert e_chunk.allocator.used_pages == 0


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_chunked_then_decode_matches_exact_then_decode(family):
    """Greedy generation after a chunked prefill equals generation after an
    exact prefill — the pages and SSM state it left behind are a faithful
    cache."""
    cfg = tiny_config(**FAMILIES[family])
    prompt = [2, 5, 9, 13, 7, 3, 11, 4, 8, 6, 10]   # 11 tokens: 2 chunks

    def gen(exact):
        _, _, eng = _engine(cfg, temperature=0.0)
        blocks, lg, ssm = eng.prefill(prompt, exact=exact)
        h = eng.spawn_branch(0, blocks, lg, ssm, len(prompt))
        for _ in range(8):
            eng.decode_step()
        toks = list(h.tokens)
        eng.free_branch(h)
        eng.release_prefix(blocks)
        assert eng.allocator.used_pages == 0
        return toks

    assert gen(exact=True) == gen(exact=False)


@pytest.mark.parametrize("family,n_lengths", [
    ("dense", 16), ("ssm", 8), ("hybrid", 8)])
def test_compile_count_is_o_num_buckets(family, n_lengths):
    """Acceptance: prompts of distinct ragged lengths trace at most 4
    prefill/mixed-step shapes (the seed's exact path traced one per
    length) — for every model family, ssm/hybrid included."""
    cfg = tiny_config(**FAMILIES[family])
    _, _, eng = _engine(cfg, slots=2, num_pages=256,
                        max_pages_per_branch=32)
    lengths = list(range(3, 3 + n_lengths))         # distinct lengths
    rng = np.random.default_rng(0)
    for s in lengths:
        prompt = [int(t) for t in rng.integers(2, cfg.vocab_size, size=s)]
        blocks, lg, ssm = eng.prefill(prompt)
        eng.release_prefix(blocks)
    assert eng.prefill_compile_count <= 4
    assert len(eng._prefill_cache) == 0             # exact path never used
    assert eng.allocator.used_pages == 0


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_piggybacked_prefill_leaves_decode_untouched(family):
    """A prompt admitted mid-generation rides the decode step as extra rows;
    the running branch's greedy continuation must be bit-identical to a run
    with no concurrent prefill, and the admitted prompt must produce the
    same logits as a standalone prefill. For ssm/hybrid this additionally
    pins that the chunk lane's carried state never bleeds into the per-slot
    (conv, ssd) rows of live branches."""
    cfg = tiny_config(**FAMILIES[family])
    prompt_a = [2, 5, 9, 13, 7]
    prompt_b = [3, 8, 11, 6, 12, 4, 10, 9, 2, 7, 5, 13, 3]   # 13 tokens

    def run(piggyback):
        _, _, eng = _engine(cfg, temperature=0.0)
        blocks, lg, ssm = eng.prefill(prompt_a)
        h = eng.spawn_branch(0, blocks, lg, ssm, len(prompt_a))
        for _ in range(3):
            eng.decode_step()
        st = eng.begin_prefill(prompt_b) if piggyback else None
        for _ in range(6):                          # covers the 2 chunks
            eng.decode_step()
        lg_b = None
        if piggyback:
            assert st.done
            b_b, lg_b, _ = eng.finish_prefill(st)
            eng.release_prefix(b_b)
        toks = list(h.tokens)
        eng.free_branch(h)
        eng.release_prefix(blocks)
        assert eng.allocator.used_pages == 0
        return toks, lg_b

    toks_plain, _ = run(piggyback=False)
    toks_mixed, lg_b = run(piggyback=True)
    assert toks_plain == toks_mixed

    _, _, ref = _engine(cfg)
    b_ref, lg_ref, _ = ref.prefill(prompt_b)
    np.testing.assert_allclose(np.asarray(lg_ref), np.asarray(lg_b),
                               rtol=1e-4, atol=1e-4)
    ref.release_prefix(b_ref)


@pytest.mark.parametrize("kernel", ["fused", "decode"])
@pytest.mark.parametrize("family", ["dense", "hybrid"])
@pytest.mark.parametrize("s", [8, 9, 12, 16, 7])
def test_chunk_visible_context_pinned_at_page_edges(family, kernel, s):
    """Regression pinning the exact visible-context length of mixed-step
    chunk rows at chunk/page boundaries (page_size=4, prefill_chunk=8, so
    chunk boundaries land on and straddle page edges).

    Chunk rows attend pages the step itself just wrote, so an off-by-one in
    the causal horizon at a page edge would read one future (unwritten)
    slot. Before the final chunk, every not-yet-written slot of the
    request's pre-allocated pages is poisoned with huge values: the final
    chunk must overwrite exactly its own positions and mask everything
    past each row's own position, leaving the last logits equal to the
    exact-length prefill's."""
    cfg = tiny_config(**FAMILIES[family])
    rng = np.random.default_rng(s)
    prompt = [int(t) for t in rng.integers(2, cfg.vocab_size, size=s)]

    _, _, e_exact = _engine(cfg)
    b_e, lg_e, _ = e_exact.prefill(prompt, exact=True)

    _, _, eng = _engine(cfg, mixed_step_kernel=kernel)
    st = eng.begin_prefill(prompt)
    while st.remaining > eng.cfg.prefill_chunk:
        eng.decode_step()
    ps = eng.cfg.page_size
    pages = np.asarray(st.blocks.pages, np.int64)
    poison = np.zeros(eng.state["k_pages"].shape[2:4], bool)  # [P, ps]
    for pos in range(st.next_pos, len(pages) * ps):
        poison[pages[pos // ps], pos % ps] = True
    pz = jnp.asarray(poison)[None, None, :, :, None]
    for key in ("k_pages", "v_pages"):
        eng.state[key] = jnp.where(pz, 1e4, eng.state[key])
    while not st.done:
        eng.decode_step()
    b_c, lg_c, _ = eng.finish_prefill(st)

    np.testing.assert_allclose(np.asarray(lg_e), np.asarray(lg_c),
                               rtol=1e-4, atol=1e-4)
    e_exact.release_prefix(b_e)
    eng.release_prefix(b_c)


@pytest.mark.parametrize("family", ["dense", "hybrid"])
def test_fused_mixed_step_matches_decode_path(family):
    """Equivalence of the two mixed_step_kernel paths: same seed, same
    workload (a branch decoding greedily while a second prompt piggybacks)
    must produce bit-identical branch tokens, fp32-close harvested logits,
    and fp32-close K/V pages for the admitted prompt."""
    cfg = tiny_config(**FAMILIES[family])
    prompt_a = [2, 5, 9, 13, 7]
    prompt_b = [3, 8, 11, 6, 12, 4, 10, 9, 2, 7, 5, 13, 3]   # 13 tokens

    def run(kernel):
        _, _, eng = _engine(cfg, temperature=0.0, mixed_step_kernel=kernel)
        blocks, lg, ssm = eng.prefill(prompt_a)
        h = eng.spawn_branch(0, blocks, lg, ssm, len(prompt_a))
        for _ in range(3):
            eng.decode_step()
        st = eng.begin_prefill(prompt_b)
        while not st.done:
            eng.decode_step()
        b_b, lg_b, _ = eng.finish_prefill(st)
        kb, vb = _gather_prefix(eng, b_b, len(prompt_b))
        toks = list(h.tokens)
        eng.free_branch(h)
        eng.release_prefix(blocks)
        eng.release_prefix(b_b)
        return toks, np.asarray(lg_b), kb, vb

    toks_f, lg_f, k_f, v_f = run("fused")
    toks_d, lg_d, k_d, v_d = run("decode")
    assert toks_f == toks_d
    np.testing.assert_allclose(lg_f, lg_d, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(k_f, k_d, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(v_f, v_d, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_multi_lane_matches_single_lane(family):
    """Token-budget lane packing (step_token_budget for 2 concurrent chunk
    lanes) must reproduce the single-lane path's harvested logits, final
    SSM state AND K/V page contents for every admitted prompt — across
    ragged lengths and all model families."""
    cfg = tiny_config(**FAMILIES[family])
    rng = np.random.default_rng(1)
    prompts = [[int(t) for t in rng.integers(2, cfg.vocab_size, size=s)]
               for s in (13, 9, 17)]

    _, _, single = _engine(cfg)                      # legacy FIFO lane
    want = [single.prefill(p) for p in prompts]

    _, _, multi = _engine(cfg, step_token_budget=16)  # 2 lanes x bucket 8
    assert multi.admission_capacity == 2
    sts = [multi.begin_prefill(p) for p in prompts]
    single_steps = sum(-(-len(p) // 8) for p in prompts)
    steps = 0
    while any(not st.done for st in sts):
        multi.decode_step()
        steps += 1
    assert steps < single_steps, "packing never carried 2 lanes"
    for st, p, (b_w, lg_w, ssm_w) in zip(sts, prompts, want):
        b_m, lg_m, ssm_m = multi.finish_prefill(st)
        np.testing.assert_allclose(np.asarray(lg_w), np.asarray(lg_m),
                                   rtol=1e-4, atol=1e-4)
        _assert_ssm_close(ssm_w, ssm_m)
        if cfg.uses_attention:
            kw, vw = _gather_prefix(single, b_w, len(p))
            km, vm = _gather_prefix(multi, b_m, len(p))
            np.testing.assert_allclose(kw, km, rtol=1e-4, atol=1e-5)
            np.testing.assert_allclose(vw, vm, rtol=1e-4, atol=1e-5)
        multi.release_prefix(b_m)
    assert multi.allocator.used_pages == 0


def test_lane_budget_one_chunk_is_bit_exact_with_fifo_engine():
    """Acceptance: step_token_budget sized for exactly one chunk keeps the
    live engine bit-exact with the legacy FIFO lane — same branch tokens,
    same harvested logits, same rng stream."""
    cfg = tiny_config()
    prompt_a = [2, 5, 9, 13, 7]
    prompt_b = [3, 8, 11, 6, 12, 4, 10, 9, 2, 7, 5, 13, 3]

    def run(budget):
        _, _, eng = _engine(cfg, temperature=0.0, step_token_budget=budget)
        assert eng.admission_capacity == 1
        blocks, lg, ssm = eng.prefill(prompt_a)
        h = eng.spawn_branch(0, blocks, lg, ssm, len(prompt_a))
        for _ in range(3):
            eng.decode_step()
        st = eng.begin_prefill(prompt_b)
        while not st.done:
            eng.decode_step()
        _, lg_b, _ = eng.finish_prefill(st)
        return list(h.tokens), np.asarray(lg_b)

    toks_fifo, lg_fifo = run(0)
    toks_one, lg_one = run(8)            # budget == one bucket-8 chunk
    assert toks_fifo == toks_one
    np.testing.assert_array_equal(lg_fifo, lg_one)


def test_lane_budget_below_bucket_rejected():
    cfg = tiny_config()
    with pytest.raises(ValueError, match="cannot carry even one full"):
        _engine(cfg, step_token_budget=4)            # max bucket is 8
    with pytest.raises(ValueError, match="must include 1"):
        _engine(cfg, step_token_budget=16, chunk_lane_configs=(2,))
    # configs the packer can never fill would make admission_capacity
    # over-reserve prompts' pages — rejected at construction
    with pytest.raises(ValueError, match="exceed the"):
        _engine(cfg, chunk_lane_configs=(1, 4))      # budget 0: FIFO only
    with pytest.raises(ValueError, match="exceed the"):
        _engine(cfg, step_token_budget=16, chunk_lane_configs=(1, 8))
    # a budget without chunked admission is contradictory: sync prefill
    # has no lanes, and capacity > 1 would drain the scheduler's arrival
    # queue in one tick
    with pytest.raises(ValueError, match="requires chunked_prefill"):
        _engine(cfg, chunked_prefill=False, step_token_budget=16)


# ------------------------------------------------------ radix prefix cache


def _drain(eng, prompt):
    """Admit + drain one prompt; returns (state, steps_taken)."""
    st = eng.begin_prefill(prompt)
    steps = 0
    while not st.done:
        eng.decode_step()
        steps += 1
    eng.finish_prefill(st)
    return st, steps


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_prefix_cache_bit_exact_on_vs_off(family):
    """Acceptance: cache-on must reproduce cache-off bit-exactly — same
    greedy branch tokens, same harvested logits, same final K/V page
    contents and SSM state — while serving the shared header from cached
    pages (fewer chunk steps, hit_tokens > 0)."""
    cfg = tiny_config(**FAMILIES[family])
    rng = np.random.default_rng(3)
    header = [int(t) for t in rng.integers(2, cfg.vocab_size, size=10)]
    prompts = [header + [3, 7, 2, 9], header + [5, 2, 8, 4, 6]]

    def run(cache):
        _, _, eng = _engine(cfg, temperature=0.0, prefix_cache=cache)
        outs, steps = [], []
        for p in prompts:
            st, n = _drain(eng, p)
            steps.append(n)
            kv = (_gather_prefix(eng, st.blocks, len(p))
                  if cfg.uses_attention else None)
            outs.append((np.asarray(st.last_logits), st.ssm_state, kv))
        return eng, outs, steps

    eng_off, outs_off, steps_off = run(False)
    eng_on, outs_on, steps_on = run(True)
    assert sum(steps_on) < sum(steps_off), "warm admission saved no steps"
    assert eng_on.prefix_cache.stats()["hit_tokens"] > 0
    for (lg_a, ssm_a, kv_a), (lg_b, ssm_b, kv_b) in zip(outs_off, outs_on):
        np.testing.assert_array_equal(lg_a, lg_b)
        assert (ssm_a is None) == (ssm_b is None)
        if ssm_a is not None:
            for got, want in zip(ssm_a, ssm_b):
                np.testing.assert_array_equal(np.asarray(got),
                                              np.asarray(want))
        if kv_a is not None:
            np.testing.assert_array_equal(kv_a[0], kv_b[0])
            np.testing.assert_array_equal(kv_a[1], kv_b[1])


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_prefix_cache_greedy_decode_matches(family):
    """Branches spawned off a warm-hit prefix decode the same greedy
    tokens as off a cold prefill (the cached pages and seeded SSM state
    are a faithful KV substrate, not just matching logits)."""
    cfg = tiny_config(**FAMILIES[family])
    header = [2, 5, 9, 13, 7, 3, 11, 4]         # 2 pages (page_size 4)
    prompt = header + [8, 6, 10]

    def gen(cache, warm):
        _, _, eng = _engine(cfg, temperature=0.0, prefix_cache=cache)
        if warm:                                 # populate + idle the cache
            st, _ = _drain(eng, header + [12, 2])
            eng.release_prefix(st.blocks)
            assert eng.prefix_cache.evictable > 0
        st, _ = _drain(eng, prompt)
        assert (st.cached_tokens > 0) == warm
        h = eng.spawn_branch(0, st.blocks, st.last_logits, st.ssm_state,
                             len(prompt))
        for _ in range(8):
            eng.decode_step()
        toks = list(h.tokens)
        eng.free_branch(h)
        eng.release_prefix(st.blocks)
        assert eng.allocator.used_pages == 0
        eng.allocator.check_invariants()
        return toks

    assert gen(cache=False, warm=False) == gen(cache=True, warm=True)


def test_prefix_cache_resurrects_idle_pages_without_rewrite():
    """decref-to-LRU at engine level: releasing every reference parks the
    prompt's full pages on the cache LRU (used_pages drains to 0), and
    re-admitting the same prompt resurrects them — identical logits with
    only the capped tail recomputed and zero K/V rewrites for the rest."""
    cfg = tiny_config()
    _, _, eng = _engine(cfg, prefix_cache=True)
    prompt = [2, 5, 9, 13, 7, 3, 11, 4, 8, 6, 10, 12, 3, 7]   # 14 tokens
    st1, _ = _drain(eng, prompt)
    lg1 = np.asarray(st1.last_logits)
    eng.release_prefix(st1.blocks)
    assert eng.allocator.used_pages == 0
    assert eng.prefix_cache.evictable == 3      # 3 full pages parked
    st2, steps2 = _drain(eng, prompt)
    # capped reuse: (14-1)//4 = 3 pages = 12 tokens; 2-token tail recomputed
    assert st2.cached_tokens == 12 and steps2 == 1
    assert eng.prefix_cache.stats()["resurrections"] == 3
    np.testing.assert_array_equal(lg1, np.asarray(st2.last_logits))
    eng.release_prefix(st2.blocks)
    eng.allocator.check_invariants()


def test_prefix_cache_evicts_under_page_pressure_only():
    """A full pool reclaims idle cached pages instead of raising; pages
    still referenced by live branches are never victims; truly exhausted
    pools still raise OutOfPagesError with nothing allocated."""
    cfg = tiny_config()
    _, _, eng = _engine(cfg, num_pages=8, prefix_cache=True)
    st1, _ = _drain(eng, [2, 5, 9, 13, 7, 3, 11, 4])   # 2 pages, both full
    eng.release_prefix(st1.blocks)                      # -> LRU
    assert eng.prefix_cache.evictable == 2
    # 7 pages of new prompt force evictions of the idle pages
    st2, _ = _drain(eng, [6] * 26)
    assert eng.prefix_cache.stats()["evictions"] >= 1
    eng.allocator.check_invariants()
    # live pages are not reclaimable: an oversized prompt still fails fast
    with pytest.raises(OutOfPagesError):
        eng.begin_prefill([7] * 32)
    assert not eng.has_pending_prefill
    eng.allocator.check_invariants()
    eng.release_prefix(st2.blocks)
    assert eng.allocator.used_pages == 0


def test_prefix_cache_ssm_reuse_gated_on_boundary_state():
    """ssm/hybrid reuse is truncated to the deepest page boundary with a
    stored (conv, ssd) snapshot — page boundaries between chunk
    boundaries have attention K/V but no seedable recurrence state."""
    cfg = tiny_config(**FAMILIES["hybrid"])
    _, _, eng = _engine(cfg, prefix_cache=True)   # chunk 8, page 4
    prompt = [2, 5, 9, 13, 7, 3, 11, 4, 8, 6, 10, 12, 3, 7]   # 14 tokens
    st1, _ = _drain(eng, prompt)
    eng.release_prefix(st1.blocks)
    st2, _ = _drain(eng, prompt)
    # dense would reuse 12 tokens (3 pages); the hybrid resumes at the
    # page-aligned chunk boundary 8 where a snapshot exists
    assert st2.cached_tokens == 8
    np.testing.assert_array_equal(np.asarray(st1.last_logits),
                                  np.asarray(st2.last_logits))
    eng.release_prefix(st2.blocks)
    eng.allocator.check_invariants()


def test_prefix_cache_single_page_dispatch_per_mixed_step():
    """Acceptance pin: chunk K/V writes AND the step's CoW page copies
    execute inside the one jit'd step program — after every decode_step
    (any lane count, CoWs pending or not) the engine's page arrays are
    exactly the objects that single dispatch returned; no host-side copy
    ever touches them."""
    cfg = tiny_config()
    _, _, eng = _engine(cfg, temperature=0.0, step_token_budget=16)
    blocks, lg, ssm = eng.prefill([2, 5, 9])    # 3 tokens: partial page
    h1 = eng.spawn_branch(0, blocks, lg, ssm, 3)
    h2 = eng.spawn_branch(0, blocks, lg, ssm, 3)   # shared partial -> CoW
    sts = [eng.begin_prefill([3 + i] * 13) for i in range(2)]

    captured = []
    orig = eng._step_jit

    def spy(*args, **kw):
        out = orig(*args, **kw)
        captured.append(out[3])                 # the step's new state
        return out

    eng._step_jit = spy
    saw_multi_lane = False
    while any(not st.done for st in sts):
        before = eng.prefill_chunk_steps
        eng.decode_step()
        saw_multi_lane |= eng.prefill_chunk_steps - before > 1
        assert len(captured) == eng.decode_steps_executed, \
            "a decode step issued more than one device dispatch"
        assert eng.state["k_pages"] is captured[-1]["k_pages"]
        assert eng.state["v_pages"] is captured[-1]["v_pages"]
    assert saw_multi_lane, "no mixed step ever carried 2 lanes"
    for st in sts:
        eng.release_prefix(st.blocks)
    eng.free_branch(h1)
    eng.free_branch(h2)
    eng.release_prefix(blocks)
    assert eng.allocator.used_pages == 0


def test_prefix_cache_oversized_prompt_acquires_nothing():
    """Regression: a prompt exceeding the block-table width must fail
    BEFORE acquiring cached-prefix references — an assert after acquire
    would leak increfed pages no release path ever returns."""
    cfg = tiny_config()
    _, _, eng = _engine(cfg, prefix_cache=True)
    st, _ = _drain(eng, [2] * 12)               # populate the cache
    eng.release_prefix(st.blocks)
    idle = eng.prefix_cache.evictable
    assert idle > 0
    with pytest.raises(AssertionError, match="block-table width"):
        eng.begin_prefill([2] * 200)            # shares the cached prefix
    assert eng.prefix_cache.evictable == idle   # nothing acquired
    assert eng.allocator.used_pages == 0
    eng.allocator.check_invariants()


def test_prefix_cache_requires_chunked_prefill():
    cfg = tiny_config()
    with pytest.raises(ValueError, match="prefix_cache requires"):
        _engine(cfg, chunked_prefill=False, prefix_cache=True)


def test_mixed_step_kernel_validated():
    cfg = tiny_config()
    with pytest.raises(AssertionError):
        _engine(cfg, mixed_step_kernel="nope")


def test_pending_prefills_complete_fifo():
    """Several admitted prompts drain one chunk per step, oldest first."""
    cfg = tiny_config()
    _, _, eng = _engine(cfg, slots=2)
    sts = [eng.begin_prefill([2 + i] * (6 + 3 * i)) for i in range(3)]
    done_order = []
    for _ in range(12):
        eng.decode_step()
        for i, st in enumerate(sts):
            if st.done and i not in done_order:
                done_order.append(i)
    assert done_order == [0, 1, 2]
    for st in sts:
        eng.release_prefix(st.blocks)
    assert eng.allocator.used_pages == 0


def test_abort_prefill_releases_pages():
    cfg = tiny_config()
    _, _, eng = _engine(cfg)
    st = eng.begin_prefill([2, 5, 9, 13, 7, 3, 11, 4, 8])
    eng.decode_step()                               # first chunk in flight
    eng.abort_prefill(st)
    assert eng.allocator.used_pages == 0
    assert not eng.has_pending_prefill


@pytest.mark.parametrize("family", ["ssm", "hybrid"])
def test_ssm_configs_admit_async(family):
    """ssm/hybrid prompts now ride the bucketed chunk lane (masked-dt scan):
    begin_prefill queues instead of stalling, chunks drain one per decode
    step, and the harvested state carries the final SSM state for
    spawn_branch."""
    cfg = tiny_config(**FAMILIES[family])
    _, _, eng = _engine(cfg)
    st = eng.begin_prefill([2, 5, 9, 13, 7, 3, 11, 4, 8])  # 9 tok: 2 chunks
    assert not st.done and eng.has_pending_prefill
    steps = 0
    while not st.done:
        eng.decode_step()
        steps += 1
    assert steps == 2
    blocks, lg, ssm = eng.finish_prefill(st)
    assert ssm is not None and lg is not None
    assert eng.prefill_compile_count <= 2
    h = eng.spawn_branch(0, blocks, lg, ssm, 9)
    for _ in range(3):
        eng.decode_step()
    eng.free_branch(h)
    eng.release_prefix(blocks)
    assert eng.allocator.used_pages == 0


def test_chunked_prefill_disabled_is_synchronous():
    """chunked_prefill=False keeps the seed's synchronous exact-length
    admission for every family."""
    cfg = tiny_config(**FAMILIES["ssm"])
    _, _, eng = _engine(cfg, chunked_prefill=False)
    st = eng.begin_prefill([2, 5, 9, 13, 7])
    assert st.done and st.ssm_state is not None
    assert not eng.has_pending_prefill
    eng.release_prefix(st.blocks)
    assert eng.allocator.used_pages == 0


def test_abort_after_harvest_does_not_double_release():
    """Regression: aborting a state whose pages were already harvested (and
    forked by spawn_branch) must NOT release them again — that would decref
    pages live branches still reference and corrupt the pool."""
    cfg = tiny_config()
    _, _, eng = _engine(cfg)
    st = eng.begin_prefill([2, 5, 9, 13, 7, 3, 11, 4, 8])
    while not st.done:
        eng.decode_step()
    blocks, lg, ssm = eng.finish_prefill(st)
    h = eng.spawn_branch(0, blocks, lg, ssm, 9)
    used = eng.allocator.used_pages
    eng.abort_prefill(st)             # late abort: queue no-op, pages kept
    assert eng.allocator.used_pages == used
    eng.allocator.check_invariants()
    for _ in range(4):
        eng.decode_step()             # branch must still decode fine
    eng.free_branch(h)
    eng.release_prefix(blocks)
    assert eng.allocator.used_pages == 0
    eng.allocator.check_invariants()


def test_abort_prefill_is_idempotent():
    cfg = tiny_config()
    _, _, eng = _engine(cfg)
    st = eng.begin_prefill([2, 5, 9, 13, 7])
    eng.abort_prefill(st)
    eng.abort_prefill(st)             # BranchBlocks already emptied: no-op
    assert eng.allocator.used_pages == 0
    eng.allocator.check_invariants()


def test_bucket_overflow_raises():
    """A chunk longer than the largest bucket must fail loudly — silently
    padding to the top bucket would alias several prompt positions onto one
    step row."""
    cfg = tiny_config()
    _, _, eng = _engine(cfg)                        # buckets (4, 8)
    assert eng._bucket_for(8) == 8                  # boundary: exact fit
    with pytest.raises(ValueError, match="exceeds the largest"):
        eng._bucket_for(9)
    # misconfiguration is rejected at construction, before any admission
    with pytest.raises(ValueError, match="must cover a full"):
        _engine(cfg, prefill_buckets=(2, 4))        # top < prefill_chunk=8


# --------------------------------------- tree decode + generated-prefix cache


def _branch_state(eng, handles):
    """(tokens, page lists, K/V contents, SSM rows) of every live branch."""
    out = []
    for h in handles:
        kv = (_gather_prefix(eng, h.blocks, h.blocks.length)
              if eng.model.cfg.uses_attention else None)
        ssm = None
        if eng.model.cfg.uses_ssm:
            ssm = (np.asarray(eng.state["conv"][:, h.slot]),
                   np.asarray(eng.state["ssd"][:, h.slot]))
        out.append((list(h.tokens), list(h.blocks.pages), kv, ssm))
    return out


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_tree_decode_kernel_bit_exact_on_vs_off(family):
    """decode_kernel="tree" must be bit-exact with the per-branch paged
    path under a forking workload — same sampled tokens, same page lists,
    same K/V page contents, same SSM rows. (For the pure-SSM family the
    tree map is empty and the config must degrade to a no-op.)"""
    cfg = tiny_config(**FAMILIES[family])
    prompt = [2, 5, 9, 13, 7, 3, 11]

    def run(kernel):
        _, _, eng = _engine(cfg, temperature=0.8, seed=3,
                            decode_kernel=kernel)
        blocks, lg, ssm = eng.prefill(prompt)
        h = eng.spawn_branch(0, blocks, lg, ssm, len(prompt),
                             prompt_tokens=prompt)
        for _ in range(2):
            eng.decode_step()
        c1 = eng.fork_branch(h)          # mid-page fork
        for _ in range(2):
            eng.decode_step()
        c2 = eng.fork_branch(c1)
        assert c1 is not None and c2 is not None
        # the fork group is real: siblings share their leading page
        assert h.blocks.pages[0] == c1.blocks.pages[0] == c2.blocks.pages[0]
        for _ in range(6):
            eng.decode_step()
        state = _branch_state(eng, [h, c1, c2])
        for b in (h, c1, c2):
            eng.free_branch(b)
        eng.release_prefix(blocks)
        assert eng.allocator.used_pages == 0
        return state

    for (tok_p, pg_p, kv_p, ssm_p), (tok_t, pg_t, kv_t, ssm_t) in zip(
            run("paged"), run("tree")):
        assert tok_p == tok_t
        assert pg_p == pg_t
        if kv_p is not None:
            np.testing.assert_array_equal(kv_p[0], kv_t[0])
            np.testing.assert_array_equal(kv_p[1], kv_t[1])
        if ssm_p is not None:
            np.testing.assert_array_equal(ssm_p[0], ssm_t[0])
            np.testing.assert_array_equal(ssm_p[1], ssm_t[1])


def test_tree_decode_requires_fused_mixed_step():
    cfg = tiny_config()
    with pytest.raises(ValueError, match="decode_kernel='tree'"):
        _engine(cfg, decode_kernel="tree", mixed_step_kernel="decode")
    with pytest.raises(AssertionError):
        _engine(cfg, decode_kernel="cascade")


def test_generated_prefix_resample_admits_warm():
    """Resample-after-completion: a finished branch inserts its generated
    full pages keyed by prompt + generated tokens; re-admitting that
    trajectory (plus a fresh tail) serves the WHOLE generated prefix from
    cache — cached_tokens past the prompt, the very same page ids
    resurrected off the LRU, and zero K/V writes for the shared tokens
    (the chunk lane starts at the cached boundary)."""
    cfg = tiny_config()
    _, _, eng = _engine(cfg, temperature=0.0, prefix_cache=True)
    prompt = [2, 5, 9, 13, 7, 3, 11, 4]          # 2 full pages (ps=4)
    blocks, lg, ssm = eng.prefill(prompt)
    h = eng.spawn_branch(0, blocks, lg, ssm, len(prompt),
                         prompt_tokens=prompt)
    for _ in range(10):
        eng.decode_step()
    gen = list(h.tokens)
    written = h.blocks.length - len(prompt)      # last sample not written
    assert written == len(gen) - 1
    branch_pages = list(h.blocks.pages)
    eng.free_branch(h)                           # inserts prompt+generated
    eng.release_prefix(blocks)
    assert eng.allocator.used_pages == 0
    stats = eng.prefix_cache.stats()
    assert stats["tracked_pages"] * eng.cfg.page_size \
        >= (len(prompt) + written) // eng.cfg.page_size * eng.cfg.page_size

    resample = prompt + gen[:written] + [95]     # warm resample + new tail
    probe = eng.match_cached_tokens(resample)
    assert probe > len(prompt), "generated prefix not probeable"
    res_before = stats["resurrections"]
    st = eng.begin_prefill(resample)
    # the cached span covers the generated prefix, page-aligned and
    # capped so the last token recomputes
    cap = (len(resample) - 1) // eng.cfg.page_size * eng.cfg.page_size
    assert st.cached_tokens == cap > len(prompt)
    assert st.next_pos == st.cached_tokens       # 0 K/V bytes for the span
    n_cached = st.cached_tokens // eng.cfg.page_size
    # identical page ids: resurrected K/V, never recomputed or rewritten
    assert st.blocks.pages[:n_cached] == branch_pages[:n_cached]
    assert eng.prefix_cache.stats()["resurrections"] - res_before \
        == n_cached
    while not st.done:
        eng.decode_step()
    b2, lg2, _ = eng.finish_prefill(st)

    # bit-exactness: a cold engine prefilling the same resample prompt
    _, _, cold = _engine(cfg, temperature=0.0, prefix_cache=False)
    bc, lgc, _ = cold.prefill(resample)
    np.testing.assert_array_equal(np.asarray(lg2), np.asarray(lgc))
    k2, v2 = _gather_prefix(eng, b2, len(resample))
    kc, vc = _gather_prefix(cold, bc, len(resample))
    np.testing.assert_allclose(k2, kc, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(v2, vc, rtol=1e-4, atol=1e-5)
    eng.release_prefix(b2)
    cold.release_prefix(bc)
    eng.allocator.check_invariants()


def test_generated_prefix_ssm_snapshot_gate():
    """hybrid generated-prefix reuse is gated on boundary SSM snapshots:
    decode-time insertion snapshots (conv, ssd) at every page-aligned
    boundary, so a warm resample seeds the recurrence from the deepest
    generated boundary — and stripping that snapshot (white-box) truncates
    the match to the next-shallower seedable boundary, never serving
    attention K/V the recurrence cannot resume behind."""
    cfg = tiny_config(**FAMILIES["hybrid"])
    _, _, eng = _engine(cfg, temperature=0.0, prefix_cache=True)
    prompt = [2, 5, 9, 13, 7, 3, 11, 4]          # 2 pages, chunk boundary
    blocks, lg, ssm = eng.prefill(prompt)
    h = eng.spawn_branch(0, blocks, lg, ssm, len(prompt),
                         prompt_tokens=prompt)
    for _ in range(10):
        eng.decode_step()
    gen = list(h.tokens)
    written = h.blocks.length - len(prompt)
    eng.free_branch(h)
    eng.release_prefix(blocks)

    resample = prompt + gen[:written] + [95]
    cache = eng.prefix_cache
    m_full = eng.match_cached_tokens(resample)
    assert m_full > len(prompt), "generated boundary snapshot not seedable"
    # white-box: strip the deepest snapshot-bearing node; the gate must
    # retreat to the next boundary that can still seed (conv, ssd)
    seeded = [n for n in cache._by_page.values() if n.ssm_state is not None]
    deepest = max(seeded, key=lambda n: n.depth)
    assert deepest.depth * eng.cfg.page_size == m_full
    deepest.ssm_state = None
    m_stripped = eng.match_cached_tokens(resample)
    assert m_stripped < m_full
    remaining = [n.depth for n in cache._by_page.values()
                 if n.ssm_state is not None
                 and n.depth * eng.cfg.page_size <= m_full]
    assert m_stripped == max(remaining, default=0) * eng.cfg.page_size
    # a real admission under the stripped cache still matches a cold
    # prefill (decode-time snapshots carry the step recurrence's fp32
    # rounding, so this is allclose, not array_equal)
    st = eng.begin_prefill(resample)
    assert st.cached_tokens == m_stripped
    while not st.done:
        eng.decode_step()
    b2, lg2, _ = eng.finish_prefill(st)
    _, _, cold = _engine(cfg, temperature=0.0, prefix_cache=False)
    bc, lgc, _ = cold.prefill(resample)
    np.testing.assert_allclose(np.asarray(lg2), np.asarray(lgc),
                               rtol=1e-4, atol=1e-4)
    eng.release_prefix(b2)
    cold.release_prefix(bc)
    eng.allocator.check_invariants()
