"""Synthetic task correctness + data pipeline."""
import numpy as np
import pytest
from prop import given, settings, st

from repro.data import DataConfig, padded_batches, prm_batches, tasks
from repro.data import tokenizer as tk


def test_problem_running_values():
    rng = np.random.default_rng(0)
    for _ in range(50):
        p = tasks.gen_problem(rng)
        v = p.terms[0] % 10
        for op, t in zip(p.ops, p.terms[1:]):
            v = {"+": v + t, "-": v - t, "*": v * t}[op] % 10
        assert p.answer == v == p.running[-1]


def test_trace_roundtrip_correct():
    rng = np.random.default_rng(1)
    for _ in range(50):
        p = tasks.gen_problem(rng)
        trace = tasks.render_trace(p, rng)
        plen = len(p.prompt_tokens())
        assert trace[:plen] == p.prompt_tokens()
        assert trace[-1] == tk.EOS
        assert tasks.extract_answer(trace) == p.answer
        c, t = tasks.grade_steps(p, trace[plen:])
        assert c == t > 0                      # clean trace fully correct


def test_corrupted_trace_graded_below_one():
    rng = np.random.default_rng(2)
    found_bad = False
    for _ in range(50):
        p = tasks.gen_problem(rng)
        trace = tasks.render_trace(p, rng, error_p=0.8)
        plen = len(p.prompt_tokens())
        c, t = tasks.grade_steps(p, trace[plen:])
        if c < t:
            found_bad = True
    assert found_bad


def test_overthinking_produces_long_tail():
    rng = np.random.default_rng(3)
    lengths = []
    for _ in range(400):
        p = tasks.gen_problem(rng)
        lengths.append(len(tasks.render_trace(p, rng, overthink_p=0.3)))
    lengths = np.asarray(lengths)
    assert lengths.max() > 2.0 * np.median(lengths)  # heavy tail exists


def test_length_correctness_independence():
    """Paper Obs. 1: by construction, rechecks change length, not truth."""
    rng = np.random.default_rng(4)
    p = tasks.gen_problem(rng)
    short = tasks.render_trace(p, rng, recheck_p=0.0, overthink_p=0.0)
    long_ = tasks.render_trace(p, rng, recheck_p=1.0, overthink_p=1.0,
                               overthink_geo=0.5)
    assert len(long_) > len(short)
    assert tasks.extract_answer(short) == tasks.extract_answer(long_) \
        == p.answer


def test_partial_grading_monotone_prefix():
    rng = np.random.default_rng(5)
    p = tasks.gen_problem(rng)
    trace = tasks.render_trace(p, rng)
    plen = len(p.prompt_tokens())
    gen = trace[plen:]
    # any prefix of a correct trace grades fully correct
    for cut in range(0, len(gen), 3):
        c, t = tasks.grade_steps(p, gen[:cut])
        assert c == t


def test_oracle_grader_protocol():
    rng = np.random.default_rng(6)
    p = tasks.gen_problem(rng)

    class Req:
        payload = p

    trace = tasks.render_trace(p, rng)
    plen = len(p.prompt_tokens())
    assert tasks.oracle_grader(Req(), trace[plen:]) == 1.0
    assert tasks.oracle_grader(Req(), []) == 0.5
    wrong = [tk.STEP, tk.digit((p.running[0] + 1) % 10), tk.SEP]
    assert tasks.oracle_grader(Req(), wrong) == 0.0


def test_padded_batches_shapes_and_mask():
    cfg = DataConfig(batch_size=4, seq_len=96)
    toks, labels, mask = next(padded_batches(cfg))
    assert toks.shape == labels.shape == mask.shape == (4, 96)
    assert (toks[mask.astype(bool)] != tk.PAD).all()
    # labels are next-token shifted
    np.testing.assert_array_equal(labels[:, :-1], toks[:, 1:])


def test_prm_batches_labels_binary():
    cfg = DataConfig(batch_size=4, seq_len=96)
    toks, labels, mask = next(prm_batches(cfg))
    assert set(np.unique(labels)) <= {0.0, 1.0}
    assert mask.sum() > 0
    assert ((mask == 0) | ((labels == 0) | (labels == 1))).all()


@settings(max_examples=60, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_extract_answer_never_crashes(seed):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, tk.VOCAB_SIZE, size=rng.integers(0, 40)).tolist()
    ans = tasks.extract_answer(toks)
    assert ans is None or 0 <= ans <= 9
