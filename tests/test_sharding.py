"""Sharding rules: coverage over every arch's param tree + sanitizer."""
import types

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED, get_config
from repro.distributed.sharding import (param_pspecs, param_spec,
                                        sanitize_pspecs)
from repro.models import Model, smoke_variant


def fake_mesh(**axes):
    return types.SimpleNamespace(
        axis_names=tuple(axes),
        devices=types.SimpleNamespace(
            shape=tuple(axes.values()),
            size=int(jnp.prod(jnp.asarray(list(axes.values()))))))


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_param_rules_cover_every_leaf(arch):
    """Every parameter of every architecture matches a sharding rule."""
    cfg = smoke_variant(get_config(arch))
    model = Model(cfg)
    pshape = jax.eval_shape(lambda: model.init_params(jax.random.PRNGKey(0)))
    specs = param_pspecs(pshape)   # raises KeyError on uncovered paths
    leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves) == len(jax.tree.leaves(pshape))
    for spec, leaf in zip(leaves, jax.tree.leaves(pshape)):
        assert len(spec) <= leaf.ndim


def test_param_spec_examples():
    assert param_spec("layers/attn/wq", 3) == P(None, "data", "model")
    assert param_spec("layers/attn/wo", 3) == P(None, "model", "data")
    assert param_spec("layers/moe/w_up", 4) == P(None, "model", "data", None)
    assert param_spec("embed/embedding", 2) == P("model", None)
    assert param_spec("final_norm/scale", 1) == P(None)
    with pytest.raises(KeyError):
        param_spec("layers/unknown/w", 2)


def test_sanitize_drops_nondivisible():
    mesh = fake_mesh(data=16, model=16)
    shapes = {
        "ok": jax.ShapeDtypeStruct((32, 64), jnp.float32),
        "bad_dim0": jax.ShapeDtypeStruct((50280, 64), jnp.float32),
        "bad_dim1": jax.ShapeDtypeStruct((32, 2), jnp.float32),
    }
    specs = {
        "ok": P("data", "model"),
        "bad_dim0": P("model", None),
        "bad_dim1": P(None, "model"),
    }
    out = sanitize_pspecs(specs, shapes, mesh)
    assert out["ok"] == P("data", "model")
    assert out["bad_dim0"] == P(None, None)
    assert out["bad_dim1"] == P(None, None)


def test_sanitize_handles_tuple_axes():
    mesh = fake_mesh(pod=2, data=16, model=16)
    shapes = {"x": jax.ShapeDtypeStruct((64, 8), jnp.float32)}
    specs = {"x": P(("pod", "data"), None)}
    out = sanitize_pspecs(specs, shapes, mesh)
    assert out["x"] == P(("pod", "data"), None)      # 64 % 32 == 0
    shapes2 = {"x": jax.ShapeDtypeStruct((40, 8), jnp.float32)}
    out2 = sanitize_pspecs(specs, shapes2, mesh)
    assert out2["x"] == P(None, None)                # 40 % 32 != 0


def test_cache_specs_shape_aware():
    from repro.distributed.sharding import cache_pspecs
    kshape = {"k": jax.ShapeDtypeStruct((24, 128, 32768, 2, 64),
                                        jnp.bfloat16),
              "v": jax.ShapeDtypeStruct((24, 128, 32768, 2, 64),
                                        jnp.bfloat16)}
    specs = cache_pspecs(kshape, ("data",), tp_size=16)
    # kv=2 not divisible -> model axis lands on sequence
    assert specs["k"] == P(None, ("data",), "model", None, None)
    kshape32 = {"k": jax.ShapeDtypeStruct((24, 128, 32768, 32, 64),
                                          jnp.bfloat16)}
    specs32 = cache_pspecs(kshape32, ("data",), tp_size=16)
    assert specs32["k"] == P(None, ("data",), None, "model", None)


def test_drop_fsdp_removes_data_axis_only():
    from repro.distributed.sharding import drop_fsdp
    specs = {
        "w": P("data", "model"),
        "o": P("model", "data"),
        "tup": P(("pod", "data"), None),
        "norm": P(None),
    }
    out = drop_fsdp(specs)
    assert out["w"] == P(None, "model")
    assert out["o"] == P("model", None)
    assert out["tup"] == P(("pod",), None)
    assert out["norm"] == P(None)


def test_constrain_is_identity_outside_context():
    import jax.numpy as jnp
    from repro.distributed.logical import constrain
    x = jnp.ones((4, 4))
    assert constrain(x, "btd") is x


def test_moe_dp_chunks_reads_context():
    from repro.distributed.logical import activation_rules, moe_dp_chunks
    assert moe_dp_chunks() == 0
    with activation_rules(None, {"_moe_dp": 16}):
        assert moe_dp_chunks() == 16
    assert moe_dp_chunks() == 0


def test_analysis_mode_togglable():
    from repro.distributed.logical import analysis_mode, scan_unroll
    assert scan_unroll() is False
    with analysis_mode():
        assert scan_unroll() is True
    assert scan_unroll() is False
