"""reprolint: exact-finding fixture tests, baseline/suppression
semantics, CLI exit codes, and the repo-is-clean gate.

Each rule has a positive fixture (exact findings pinned: rule, symbol,
count) and a negative twin that must stay silent — so a rule regression
shows up as a diff here, not as CI noise. The fixtures live under
``tests/reprolint_fixtures/`` and are excluded from normal runs; these
tests lint them explicitly with ``excludes=()``.
"""
import json
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
if str(REPO) not in sys.path:          # tools/ lives at the repo root
    sys.path.insert(0, str(REPO))

from tools.reprolint import Baseline, Finding, all_rules, run_paths  # noqa: E402

FIX = REPO / "tests" / "reprolint_fixtures"
BASELINE = REPO / "tools" / "reprolint" / "baseline.txt"


def lint(*files, rules=None):
    return run_paths([str(f) for f in files], excludes=(), rules=rules)


def shapes(findings):
    """(rule, symbol) per finding — the exact-match signature."""
    return [(f.rule, f.symbol) for f in findings]


# ------------------------------------------------------------- rule catalog
def test_rule_catalog_complete():
    codes = [r.code for r in all_rules()]
    assert codes == ["REP001", "REP002", "REP003", "REP004", "REP005",
                     "REP006", "REP007"]
    for r in all_rules():
        assert r.summary and r.name != "unnamed"


# ------------------------------------------------------------------- REP001
def test_rep001_positive_exact():
    fs = lint(FIX / "rep001_pos.py")
    assert shapes(fs) == [("REP001", "drive")] * 4
    # two patterns: non-static positional/keyword args, in source order
    assert "tokens" in fs[0].message
    assert "lengths" in fs[1].message
    assert "tokens" in fs[2].message and "chunk_step" in fs[2].message
    assert "tokens" in fs[3].message and "step_jit" in fs[3].message


def test_rep001_negative_silent():
    assert lint(FIX / "rep001_neg.py") == []


def test_rep001_loop_positive_exact():
    fs = lint(FIX / "serving" / "rep001_loop_pos.py")
    assert shapes(fs) == [("REP001", "hot_loop")] * 2
    assert all("loop" in f.message for f in fs)


def test_rep001_loop_negative_silent():
    assert lint(FIX / "serving" / "rep001_loop_neg.py") == []


# ------------------------------------------------------------------- REP002
def test_rep002_positive_exact():
    fs = lint(FIX / "src" / "rep002_pos.py")
    assert shapes(fs) == [("REP002", "grow"), ("REP002", "share")]
    assert "inside a loop" in fs[0].message
    assert "after earlier" in fs[1].message


def test_rep002_negative_silent():
    assert lint(FIX / "src" / "rep002_neg.py") == []


def test_rep002_is_path_scoped():
    # the same violations outside src/ (tests drive failure paths on
    # purpose) must not fire
    import shutil
    import tempfile
    with tempfile.TemporaryDirectory(dir=FIX) as tmp:
        dst = Path(tmp) / "rep002_pos_copy.py"
        shutil.copy(FIX / "src" / "rep002_pos.py", dst)
        assert lint(dst) == []


# ------------------------------------------------------------------- REP003
def test_rep003_positive_exact():
    fs = lint(FIX / "kernels" / "rep003_pos.py")
    assert shapes(fs) == [("REP003", "_kv_index"),
                          ("REP003", "pad_kernel")]
    assert "clamp" in fs[0].message
    assert "validity" in fs[1].message


def test_rep003_negative_silent():
    assert lint(FIX / "kernels" / "rep003_neg.py") == []


# ------------------------------------------------------------------- REP004
def test_rep004_positive_exact():
    fs = lint(FIX / "rep004_pos.py")
    assert shapes(fs) == [("REP004", "Queue.cancel"),
                          ("REP004", "Queue.drop_first")]
    assert all("eq=False" in f.message for f in fs)


def test_rep004_negative_silent():
    assert lint(FIX / "rep004_neg.py") == []


def test_rep004_resolves_cross_file_dataclasses():
    # the dataclass defined in the pos fixture is visible when linting
    # both files together (ProjectContext pre-pass), and the neg file
    # still reports nothing
    fs = lint(FIX / "rep004_pos.py", FIX / "rep004_neg.py")
    assert {f.path.rsplit("/", 1)[-1] for f in fs} == {"rep004_pos.py"}


# ------------------------------------------------------------------- REP005
def test_rep005_positive_exact():
    fs = lint(FIX / "serving" / "rep005_pos.py")
    assert shapes(fs) == [("REP005", "MiniEngine.decode_loop")] * 3
    assert "np.asarray" in fs[0].message
    assert "float" in fs[1].message
    assert ".item()" in fs[2].message


def test_rep005_negative_silent():
    assert lint(FIX / "serving" / "rep005_neg.py") == []


def test_rep005_inline_suppression():
    assert lint(FIX / "serving" / "rep005_suppressed.py") == []


# ------------------------------------------------------------------- REP006
def test_rep006_positive_exact():
    fs = lint(FIX / "src" / "repro" / "kv" / "rep006_pos.py")
    assert shapes(fs) == [("REP006", "MiniStore.put"),
                          ("REP006", "lookup")]


def test_rep006_negative_silent():
    assert lint(FIX / "src" / "repro" / "kv" / "rep006_neg.py") == []


# ------------------------------------------------------------------- REP007
def test_rep007_positive_exact():
    fs = lint(FIX / "core" / "rep007_pos.py")
    assert shapes(fs) == [("REP007", "swallow_and_log"),
                          ("REP007", "swallow_bare"),
                          ("REP007", "swallow_tuple")]
    assert "bare except" in fs[1].message
    assert all("recovery path" in f.message for f in fs)


def test_rep007_negative_silent():
    assert lint(FIX / "core" / "rep007_neg.py") == []


def test_rep007_is_path_scoped():
    # the same swallows outside core//serving/ (launch glue, tools) are
    # out of the failure-domain contract's scope and must not fire
    import shutil
    import tempfile
    with tempfile.TemporaryDirectory(dir=FIX) as tmp:
        dst = Path(tmp) / "rep007_pos_copy.py"
        shutil.copy(FIX / "core" / "rep007_pos.py", dst)
        assert lint(dst) == []


# ------------------------------------------------------------------- REP000
def test_rep000_unparsable_file_is_a_finding_not_a_crash():
    fs = lint(FIX / "rep000_syntax_error.py")
    assert [f.rule for f in fs] == ["REP000"]
    assert "parse" in fs[0].message


# ----------------------------------------------------------------- baseline
def test_baseline_is_a_multiset(tmp_path):
    f1 = Finding(path="a.py", line=3, rule="REP002", message="m",
                 symbol="f")
    f2 = Finding(path="a.py", line=9, rule="REP002", message="m",
                 symbol="f")
    bl = tmp_path / "baseline.txt"
    bl.write_text("# comment line\na.py::REP002::f  # justified once\n")
    old, new = Baseline.load(bl).partition([f1, f2])
    # one grandfathered, the SECOND same-shaped finding is new
    assert old == [f1] and new == [f2]


def test_baseline_key_ignores_line_numbers():
    f = Finding(path="a.py", line=123, rule="REP004", message="m",
                symbol="Queue.cancel")
    assert f.baseline_key == "a.py::REP004::Queue.cancel"


def test_committed_baseline_entries_all_justified():
    body = BASELINE.read_text().splitlines()
    entries = [ln for ln in body if ln.strip()
               and not ln.lstrip().startswith("#")]
    assert entries, "baseline exists and carries the intentional findings"
    for i, ln in enumerate(body):
        if ln.strip() and not ln.lstrip().startswith("#"):
            # every entry has a justification comment directly above it
            assert body[i - 1].lstrip().startswith("#"), \
                f"baseline entry lacks a justification: {ln}"


def test_reprolint_repo_clean():
    """src/ and tests/ have zero non-baselined findings — the CI gate."""
    findings = run_paths([str(REPO / "src"), str(REPO / "tests")])
    _, new = Baseline.load(BASELINE).partition(findings)
    assert new == [], "\n".join(f.render() for f in new)


# ---------------------------------------------------------------------- CLI
def _cli(*args):
    return subprocess.run(
        [sys.executable, "-m", "tools.reprolint", *args],
        cwd=REPO, capture_output=True, text=True)


def test_cli_clean_run_exits_zero():
    res = _cli("src", "tests")
    assert res.returncode == 0, res.stdout + res.stderr


def test_cli_fresh_violation_fails_the_build():
    # the CI-failure demonstration: a fresh (non-baselined) violation
    # makes the exact command CI runs exit nonzero
    res = _cli("tests/reprolint_fixtures/rep004_pos.py",
               "--no-default-excludes")
    assert res.returncode == 1
    assert "REP004" in res.stdout


def test_cli_json_output():
    res = _cli("tests/reprolint_fixtures/serving/rep005_pos.py",
               "--no-default-excludes", "--json")
    assert res.returncode == 1
    data = json.loads(res.stdout)
    assert data["total"] == 3 and data["new"] == 3
    assert all(f["rule"] == "REP005" and f["new"] for f in data["findings"])


def test_cli_list_rules():
    res = _cli("--list-rules")
    assert res.returncode == 0
    for code in ("REP001", "REP002", "REP003", "REP004", "REP005",
                 "REP006"):
        assert code in res.stdout


def test_cli_write_baseline_round_trip(tmp_path):
    bl = tmp_path / "bl.txt"
    res = _cli("tests/reprolint_fixtures/rep004_pos.py",
               "--no-default-excludes", "--write-baseline",
               "--baseline", str(bl))
    assert res.returncode == 0 and bl.exists()
    res = _cli("tests/reprolint_fixtures/rep004_pos.py",
               "--no-default-excludes", "--baseline", str(bl))
    assert res.returncode == 0, res.stdout  # grandfathered -> clean


# ----------------------------------------------------------- --changed-only
from tools.reprolint.framework import changed_files  # noqa: E402


def test_changed_files_includes_untracked(tmp_path):
    scratch = FIX / "tmp_changed_only_untracked.py"
    scratch.write_text("x = 1\n", encoding="utf-8")
    try:
        assert scratch.resolve() in changed_files("HEAD")
    finally:
        scratch.unlink()


def test_changed_files_bad_ref_raises():
    with pytest.raises(RuntimeError):
        changed_files("no-such-ref-xyz")


def test_cli_changed_only_lints_only_the_changed_file():
    # an untracked copy of the REP004 fixture is "changed vs HEAD" and
    # must yield exactly the fixture's findings; the committed,
    # unmodified original must be filtered out of the same run
    original = FIX / "rep004_pos.py"
    scratch = FIX / "tmp_changed_only_rep004.py"
    scratch.write_text(original.read_text(encoding="utf-8"),
                       encoding="utf-8")
    try:
        res = _cli(str(scratch), str(original), "--no-default-excludes",
                   "--changed-only", "HEAD", "--json")
        data = json.loads(res.stdout)
        assert res.returncode == 1
        assert [(f["rule"], f["symbol"]) for f in data["findings"]] == \
            [("REP004", "Queue.cancel"), ("REP004", "Queue.drop_first")]
        assert all(f["path"].endswith("tmp_changed_only_rep004.py")
                   for f in data["findings"])
    finally:
        scratch.unlink()


def test_cli_changed_only_unchanged_file_is_clean():
    original = (FIX / "rep004_pos.py").resolve()
    if original in changed_files("HEAD"):
        pytest.skip("fixture is dirty in this checkout")
    res = _cli(str(original), "--no-default-excludes",
               "--changed-only", "HEAD")
    assert res.returncode == 0, res.stdout
    assert "0 finding(s)" in res.stdout


def test_cli_changed_only_bad_ref_is_usage_error():
    res = _cli("src", "--changed-only", "no-such-ref-xyz")
    assert res.returncode == 2
    assert "failed" in res.stderr
