import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (_attend, _attend_chunked, _project_qkv,
                                    attention_decode, attention_prefill,
                                    attention_train, causal_mask,
                                    init_attention)

from conftest import tiny_config


def _qkv(cfg, b=2, s=16, seed=0):
    p = init_attention(jax.random.PRNGKey(seed), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (b, s, cfg.d_model))
    q, k, v = _project_qkv(cfg, p, x)
    return p, x, q, k, v


@pytest.mark.parametrize("kv_heads", [1, 2, 4])
def test_chunked_matches_full(kv_heads):
    cfg = tiny_config(num_kv_heads=kv_heads)
    p, x, q, k, v = _qkv(cfg, s=32)
    full = _attend(cfg, q, k, v, causal_mask(cfg, 32, 32))
    import repro.models.attention as A
    old = A.Q_CHUNK
    A.Q_CHUNK = 8
    try:
        chunked = _attend_chunked(cfg, q, k, v)
    finally:
        A.Q_CHUNK = old
    np.testing.assert_allclose(chunked, full, atol=2e-5)


def test_chunked_matches_full_sliding_window():
    cfg = tiny_config(sliding_window=6, num_kv_heads=4)
    p, x, q, k, v = _qkv(cfg, s=32)
    full = _attend(cfg, q, k, v, causal_mask(cfg, 32, 32))
    import repro.models.attention as A
    old = A.Q_CHUNK
    A.Q_CHUNK = 8
    try:
        chunked = _attend_chunked(cfg, q, k, v)
    finally:
        A.Q_CHUNK = old
    np.testing.assert_allclose(chunked, full, atol=2e-5)


def test_chunked_nondivisible_seq():
    cfg = tiny_config(num_kv_heads=4)
    p, x, q, k, v = _qkv(cfg, s=19)
    full = _attend(cfg, q, k, v, causal_mask(cfg, 19, 19))
    import repro.models.attention as A
    old = A.Q_CHUNK
    A.Q_CHUNK = 8
    try:
        chunked = _attend_chunked(cfg, q, k, v)
    finally:
        A.Q_CHUNK = old
    np.testing.assert_allclose(chunked, full, atol=2e-5)


def test_sliding_window_masks_distant_keys():
    """An input far outside the window cannot influence the output."""
    cfg = tiny_config(sliding_window=4, num_kv_heads=4)
    p = init_attention(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, cfg.d_model))
    pos = jnp.arange(16)[None]
    base = attention_train(cfg, p, x, pos)
    x2 = x.at[0, 0].set(x[0, 0] + 100.0)
    pert = attention_train(cfg, p, x2, pos)
    # last position (15) is > window away from position 0
    np.testing.assert_allclose(base[0, -1], pert[0, -1], atol=1e-4)
    assert not np.allclose(base[0, 1], pert[0, 1], atol=1e-4)


def test_decode_ring_buffer_equals_windowed_train():
    """Ring-buffer decode == full recompute with sliding-window attention."""
    cfg = tiny_config(sliding_window=8, num_kv_heads=4)
    p = init_attention(jax.random.PRNGKey(0), cfg)
    s = 24
    x = jax.random.normal(jax.random.PRNGKey(1), (1, s, cfg.d_model))
    pos = jnp.arange(s)[None]
    ref = attention_train(cfg, p, x, pos)
    # decode token-by-token against a ring cache of exactly window size
    ck = jnp.zeros((1, 8, cfg.num_kv_heads, cfg.resolved_head_dim))
    cv = jnp.zeros_like(ck)
    outs = []
    for t in range(s):
        o, ck, cv = attention_decode(cfg, p, x[:, t:t + 1], ck, cv,
                                     jnp.array([t]))
        outs.append(o[:, 0])
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(got, ref, atol=2e-4)


def test_segment_ids_block_cross_attention():
    cfg = tiny_config(num_kv_heads=4)
    p = init_attention(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model))
    pos = jnp.arange(8)[None]
    seg = jnp.array([[0, 0, 0, 0, 1, 1, 1, 1]])
    base = attention_train(cfg, p, x, pos, segment_ids=seg)
    # perturbing segment 0 must not affect segment 1 outputs
    x2 = x.at[0, 1].add(50.0)
    pert = attention_train(cfg, p, x2, pos, segment_ids=seg)
    np.testing.assert_allclose(base[0, 4:], pert[0, 4:], atol=1e-4)


def test_softcap_applied():
    cfg = tiny_config(attn_logit_softcap=1.0, num_kv_heads=4)
    p = init_attention(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model)) * 10
    y = attention_train(cfg, p, x, jnp.arange(8)[None])
    assert jnp.isfinite(y).all()
