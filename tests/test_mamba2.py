import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd_scan.ref import ssd_scan_ref
from repro.models.mamba2 import (init_mamba2, init_mamba2_state,
                                 mamba2_decode, mamba2_forward, ssd_chunked,
                                 ssd_decode_step)

from conftest import tiny_config


def _ssm_cfg(**kw):
    base = dict(arch_type="ssm", d_ff=0, ssm_state=16, ssm_head_dim=32,
                ssm_chunk=8)
    base.update(kw)
    return tiny_config(**base)


def _inputs(rng, b=2, s=24, h=4, p=16, n=8):
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.3, size=(b, s, h)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.5, 2.0, size=(h,)), jnp.float32)
    bb = jnp.asarray(rng.normal(size=(b, s, h, n)), jnp.float32)
    cc = jnp.asarray(rng.normal(size=(b, s, h, n)), jnp.float32)
    return x, dt, a, bb, cc


@pytest.mark.parametrize("chunk", [4, 8, 16, 24])
def test_chunked_matches_naive(rng, chunk):
    x, dt, a, b, c = _inputs(rng)
    ref = ssd_scan_ref(x, dt, a, b, c)
    got, _ = ssd_chunked(x, dt, a, b, c, chunk=chunk)
    np.testing.assert_allclose(got, ref, atol=2e-4)


def test_final_state_feeds_continuation(rng):
    """Splitting a sequence and carrying the state == one long scan."""
    x, dt, a, b, c = _inputs(rng, s=32)
    full, final = ssd_chunked(x, dt, a, b, c, chunk=8)
    y1, s1 = ssd_chunked(x[:, :16], dt[:, :16], a, b[:, :16], c[:, :16],
                         chunk=8)
    y2, s2 = ssd_chunked(x[:, 16:], dt[:, 16:], a, b[:, 16:], c[:, 16:],
                         chunk=8, initial_state=s1)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), full, atol=2e-4)
    np.testing.assert_allclose(s2, final, atol=2e-4)


def test_decode_step_matches_scan(rng):
    x, dt, a, b, c = _inputs(rng, b=1, s=12)
    ref = ssd_scan_ref(x, dt, a, b, c)
    state = jnp.zeros((1, 4, 16, 8))
    outs = []
    for t in range(12):
        y, state = ssd_decode_step(state, x[:, t], dt[:, t], a, b[:, t],
                                   c[:, t])
        outs.append(y)
    got = jnp.stack(outs, 1)
    np.testing.assert_allclose(got, ref, atol=2e-4)


def test_mamba2_block_decode_matches_forward(rng):
    """Full mixer (conv + SSD + gating): stepwise decode == forward."""
    cfg = _ssm_cfg()
    p = init_mamba2(jax.random.PRNGKey(0), cfg)
    s = 16
    x = jnp.asarray(rng.normal(size=(1, s, cfg.d_model)), jnp.float32)
    ref, _ = mamba2_forward(cfg, p, x)
    conv, ssd = init_mamba2_state(cfg, 1)
    outs = []
    for t in range(s):
        y, conv, ssd = mamba2_decode(cfg, p, x[:, t:t + 1], conv, ssd)
        outs.append(y[:, 0])
    got = jnp.stack(outs, 1)
    np.testing.assert_allclose(got, ref, atol=5e-4)


def test_prefill_state_continues_decode(rng):
    cfg = _ssm_cfg()
    p = init_mamba2(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.normal(size=(1, 20, cfg.d_model)), jnp.float32)
    full, _ = mamba2_forward(cfg, p, x)
    _, (conv, ssd) = mamba2_forward(cfg, p, x[:, :15])
    y = None
    for t in range(15, 20):
        y, conv, ssd = mamba2_decode(cfg, p, x[:, t:t + 1], conv, ssd)
    np.testing.assert_allclose(y[:, 0], full[:, -1], atol=5e-4)


def test_groups_broadcast(rng):
    """ssm_groups > 1: group-specific B/C streams broadcast to heads."""
    cfg = _ssm_cfg(ssm_groups=2)
    p = init_mamba2(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.normal(size=(2, 8, cfg.d_model)), jnp.float32)
    y, _ = mamba2_forward(cfg, p, x)
    assert y.shape == x.shape
    assert jnp.isfinite(y).all()
