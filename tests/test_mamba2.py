import jax
import jax.numpy as jnp
import numpy as np
import pytest
from prop import given, settings, st

from repro.kernels.ssd_scan.ref import ssd_scan_ref
from repro.models.mamba2 import (init_mamba2, init_mamba2_state,
                                 mamba2_decode, mamba2_forward, ssd_chunked,
                                 ssd_decode_step)

from conftest import tiny_config


def _ssm_cfg(**kw):
    base = dict(arch_type="ssm", d_ff=0, ssm_state=16, ssm_head_dim=32,
                ssm_chunk=8)
    base.update(kw)
    return tiny_config(**base)


def _inputs(rng, b=2, s=24, h=4, p=16, n=8):
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.3, size=(b, s, h)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.5, 2.0, size=(h,)), jnp.float32)
    bb = jnp.asarray(rng.normal(size=(b, s, h, n)), jnp.float32)
    cc = jnp.asarray(rng.normal(size=(b, s, h, n)), jnp.float32)
    return x, dt, a, bb, cc


@pytest.mark.parametrize("chunk", [4, 8, 16, 24])
def test_chunked_matches_naive(rng, chunk):
    x, dt, a, b, c = _inputs(rng)
    ref = ssd_scan_ref(x, dt, a, b, c)
    got, _ = ssd_chunked(x, dt, a, b, c, chunk=chunk)
    np.testing.assert_allclose(got, ref, atol=2e-4)


def test_final_state_feeds_continuation(rng):
    """Splitting a sequence and carrying the state == one long scan."""
    x, dt, a, b, c = _inputs(rng, s=32)
    full, final = ssd_chunked(x, dt, a, b, c, chunk=8)
    y1, s1 = ssd_chunked(x[:, :16], dt[:, :16], a, b[:, :16], c[:, :16],
                         chunk=8)
    y2, s2 = ssd_chunked(x[:, 16:], dt[:, 16:], a, b[:, 16:], c[:, 16:],
                         chunk=8, initial_state=s1)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), full, atol=2e-4)
    np.testing.assert_allclose(s2, final, atol=2e-4)


def test_decode_step_matches_scan(rng):
    x, dt, a, b, c = _inputs(rng, b=1, s=12)
    ref = ssd_scan_ref(x, dt, a, b, c)
    state = jnp.zeros((1, 4, 16, 8))
    outs = []
    for t in range(12):
        y, state = ssd_decode_step(state, x[:, t], dt[:, t], a, b[:, t],
                                   c[:, t])
        outs.append(y)
    got = jnp.stack(outs, 1)
    np.testing.assert_allclose(got, ref, atol=2e-4)


def test_mamba2_block_decode_matches_forward(rng):
    """Full mixer (conv + SSD + gating): stepwise decode == forward."""
    cfg = _ssm_cfg()
    p = init_mamba2(jax.random.PRNGKey(0), cfg)
    s = 16
    x = jnp.asarray(rng.normal(size=(1, s, cfg.d_model)), jnp.float32)
    ref, _ = mamba2_forward(cfg, p, x)
    conv, ssd = init_mamba2_state(cfg, 1)
    outs = []
    for t in range(s):
        y, conv, ssd = mamba2_decode(cfg, p, x[:, t:t + 1], conv, ssd)
        outs.append(y[:, 0])
    got = jnp.stack(outs, 1)
    np.testing.assert_allclose(got, ref, atol=5e-4)


def test_prefill_state_continues_decode(rng):
    cfg = _ssm_cfg()
    p = init_mamba2(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.normal(size=(1, 20, cfg.d_model)), jnp.float32)
    full, _ = mamba2_forward(cfg, p, x)
    _, (conv, ssd) = mamba2_forward(cfg, p, x[:, :15])
    y = None
    for t in range(15, 20):
        y, conv, ssd = mamba2_decode(cfg, p, x[:, t:t + 1], conv, ssd)
    np.testing.assert_allclose(y[:, 0], full[:, -1], atol=5e-4)


# ------------------------------------------------------------ masked dt
# Zeroing dt makes a position's state transition an exact identity
# (decay exp(0·a) = 1, update dt·B·x = 0) — the property that lets
# right-padded chunk rows ride the serving mixed step without polluting
# the recurrence (see docs/kernels.md, "ssd_scan" masked-dt contract).


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 16), st.sampled_from([4, 8, 16]))
def test_masked_scan_matches_unpadded_prefix(vl, chunk):
    """ssd_chunked over a right-padded sequence with a validity mask must
    reproduce the unpadded scan: same valid-position outputs, same final
    state — for every ragged length / chunking combination."""
    rng = np.random.default_rng(vl * 31 + chunk)
    x, dt, a, b, c = _inputs(rng, b=2, s=16)
    valid = jnp.arange(16)[None, :] < vl
    y_m, st_m = ssd_chunked(x, dt, a, b, c, chunk=chunk, valid=valid)
    y_u, st_u = ssd_chunked(x[:, :vl], dt[:, :vl], a, b[:, :vl], c[:, :vl],
                            chunk=chunk)
    np.testing.assert_allclose(st_m, st_u, atol=2e-4)
    np.testing.assert_allclose(y_m[:, :vl], y_u, atol=2e-4)


def test_masked_scan_all_invalid_is_bit_exact_identity(rng):
    """dt == 0 everywhere: the carried state must pass through bit-exactly
    (state·exp(0) + 0·B·x), not merely within tolerance."""
    x, dt, a, b, c = _inputs(rng, b=2, s=8)
    init = jnp.asarray(rng.normal(size=(2, 4, 16, 8)), jnp.float32)
    _, st_out = ssd_chunked(x, dt, a, b, c, chunk=8, initial_state=init,
                            valid=jnp.zeros((2, 8), bool))
    np.testing.assert_array_equal(np.asarray(st_out), np.asarray(init))


def test_masked_decode_step_freezes_state(rng):
    """ssd_decode_step with valid=[True, False]: the invalid row's state is
    bit-identical; the valid row matches the unmasked step."""
    x, dt, a, b, c = _inputs(rng, b=2, s=1)
    state = jnp.asarray(rng.normal(size=(2, 4, 16, 8)), jnp.float32)
    y_u, st_u = ssd_decode_step(state, x[:, 0], dt[:, 0], a, b[:, 0], c[:, 0])
    _, st_m = ssd_decode_step(state, x[:, 0], dt[:, 0], a, b[:, 0], c[:, 0],
                              valid=jnp.asarray([True, False]))
    np.testing.assert_array_equal(np.asarray(st_m[1]), np.asarray(state[1]))
    np.testing.assert_array_equal(np.asarray(st_m[0]), np.asarray(st_u[0]))


def test_mamba2_decode_valid_freezes_conv_and_ssd(rng):
    """Full mixer one-token decode: invalid rows keep BOTH the conv tail
    and the SSD state bit-exact (inert rows in the serving mixed step)."""
    cfg = _ssm_cfg()
    p = init_mamba2(jax.random.PRNGKey(0), cfg)
    conv, ssd = init_mamba2_state(cfg, 2)
    conv = conv + jnp.asarray(rng.normal(size=conv.shape), jnp.float32)
    ssd = ssd + jnp.asarray(rng.normal(size=ssd.shape), jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 1, cfg.d_model)), jnp.float32)
    _, c_u, s_u = mamba2_decode(cfg, p, x, conv, ssd)
    _, c_m, s_m = mamba2_decode(cfg, p, x, conv, ssd,
                                valid=jnp.asarray([False, True]))
    np.testing.assert_array_equal(np.asarray(c_m[0]), np.asarray(conv[0]))
    np.testing.assert_array_equal(np.asarray(s_m[0]), np.asarray(ssd[0]))
    np.testing.assert_array_equal(np.asarray(c_m[1]), np.asarray(c_u[1]))
    np.testing.assert_array_equal(np.asarray(s_m[1]), np.asarray(s_u[1]))


def test_mamba2_forward_valid_len_matches_unpadded(rng):
    """Full mixer over a right-padded chunk: valid_len masking reproduces
    the unpadded forward's outputs AND both carried states (the conv tail
    must come from the valid stream, not the padding)."""
    cfg = _ssm_cfg()
    p = init_mamba2(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.normal(size=(1, 12, cfg.d_model)), jnp.float32)
    for vl in (1, 2, 7, 9, 12):
        y_m, (c_m, s_m) = mamba2_forward(cfg, p, x, valid_len=vl)
        y_u, (c_u, s_u) = mamba2_forward(cfg, p, x[:, :vl])
        np.testing.assert_allclose(np.asarray(c_m), np.asarray(c_u),
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(s_m), np.asarray(s_u),
                                   atol=5e-4)
        np.testing.assert_allclose(np.asarray(y_m[:, :vl]), np.asarray(y_u),
                                   atol=5e-4)


def test_groups_broadcast(rng):
    """ssm_groups > 1: group-specific B/C streams broadcast to heads."""
    cfg = _ssm_cfg(ssm_groups=2)
    p = init_mamba2(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.normal(size=(2, 8, cfg.d_model)), jnp.float32)
    y, _ = mamba2_forward(cfg, p, x)
    assert y.shape == x.shape
    assert jnp.isfinite(y).all()
