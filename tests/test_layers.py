import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig
from repro.models.layers import (apply_mlp, apply_norm, apply_rope,
                                 apply_mrope, embed_tokens, init_embedding,
                                 init_mlp, init_norm, sinusoidal_embedding,
                                 unembed)

from conftest import tiny_config


def test_rmsnorm_unit_scale():
    cfg = tiny_config()
    p = init_norm(cfg, 64)
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 64)) * 5
    y = apply_norm(cfg, p, x)
    rms = jnp.sqrt(jnp.mean(jnp.square(y), axis=-1))
    np.testing.assert_allclose(rms, 1.0, atol=1e-3)


def test_layernorm_zero_mean():
    cfg = tiny_config(norm_type="layernorm")
    p = init_norm(cfg, 64)
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 64)) + 3.0
    y = apply_norm(cfg, p, x)
    np.testing.assert_allclose(jnp.mean(y, -1), 0.0, atol=1e-5)
    np.testing.assert_allclose(jnp.std(y, -1), 1.0, atol=1e-3)


@pytest.mark.parametrize("act,gated", [("silu", True), ("gelu", True),
                                       ("gelu", False), ("relu2", False)])
def test_mlp_variants(act, gated):
    cfg = tiny_config(mlp_activation=act, mlp_gated=gated)
    p = init_mlp(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 64))
    y = apply_mlp(cfg, p, x)
    assert y.shape == x.shape
    assert jnp.isfinite(y).all()


def test_relu2_is_squared_relu():
    cfg = tiny_config(mlp_activation="relu2", mlp_gated=False, d_ff=64)
    p = init_mlp(jax.random.PRNGKey(0), cfg)
    x = jnp.ones((1, 1, 64))
    up = x @ p["w_up"]
    expect = jnp.maximum(up, 0) ** 2 @ p["w_down"]
    np.testing.assert_allclose(apply_mlp(cfg, p, x), expect, rtol=1e-6)


def test_rope_relative_property():
    """RoPE dot products depend only on relative offsets."""
    cfg = tiny_config()
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, 64))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 64))
    def dot_at(pq, pk):
        qa = apply_rope(cfg, q, jnp.array([[pq]]))
        ka = apply_rope(cfg, k, jnp.array([[pk]]))
        return float(jnp.sum(qa * ka))
    assert abs(dot_at(5, 3) - dot_at(105, 103)) < 1e-3
    assert abs(dot_at(5, 3) - dot_at(6, 3)) > 1e-4  # actually differs


def test_partial_rope_preserves_tail():
    cfg = tiny_config(rope_pct=0.25)
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 4, 2, 64))
    y = apply_rope(cfg, x, jnp.arange(4)[None])
    rot = int(64 * 0.25) // 2 * 2
    np.testing.assert_allclose(y[..., rot:], x[..., rot:], atol=1e-6)
    assert not np.allclose(y[..., :rot], x[..., :rot])


def test_mrope_degenerates_to_rope_for_text():
    """Equal position streams == 1-D RoPE with remapped frequencies."""
    cfg = tiny_config(pos_embedding="mrope")
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 6, 2, 64))
    pos = jnp.arange(6)[None]
    pos3 = jnp.broadcast_to(pos[..., None], (1, 6, 3))
    y3 = apply_mrope(cfg, x, pos3)
    # relative property: dot(q_i, k_j) depends only on i - j
    q = y3[:, 3:4]
    k = y3[:, 1:2]
    pos3b = pos3 + 7
    y3b = apply_mrope(cfg, x, pos3b)
    np.testing.assert_allclose(
        jnp.einsum("bshd,bthd->", q, k),
        jnp.einsum("bshd,bthd->", y3b[:, 3:4], y3b[:, 1:2]), rtol=1e-4)


def test_mrope_distinct_streams_differ():
    cfg = tiny_config(pos_embedding="mrope")
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 4, 2, 64))
    same = jnp.broadcast_to(jnp.arange(4)[None, :, None], (1, 4, 3))
    spatial = same.at[..., 1].add(5)
    assert not np.allclose(apply_mrope(cfg, x, same),
                           apply_mrope(cfg, x, spatial))


def test_sinusoidal_shapes():
    e = sinusoidal_embedding(jnp.arange(10), 64)
    assert e.shape == (10, 64)
    assert jnp.isfinite(e).all()


def test_embedding_scale_and_tie():
    cfg = tiny_config(embedding_scale=True, tie_embeddings=True)
    p = init_embedding(jax.random.PRNGKey(0), cfg)
    assert "lm_head" not in p
    toks = jnp.array([[1, 2, 3]])
    x = embed_tokens(cfg, p, toks)
    raw = p["embedding"][jnp.array([1, 2, 3])]
    np.testing.assert_allclose(x[0], raw * np.sqrt(cfg.d_model), rtol=1e-6)
    logits = unembed(cfg, p, x)
    assert logits.shape == (1, 3, cfg.vocab_size)


def test_logit_softcap_bounds():
    cfg = tiny_config(logit_softcap=5.0, tie_embeddings=True)
    p = init_embedding(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 64)) * 100
    logits = unembed(cfg, p, x)
    assert float(jnp.max(jnp.abs(logits))) <= 5.0 + 1e-4
