"""Algorithm 1 scheduler: policies, early stopping, pruning, no leaks."""
import jax
import numpy as np
import pytest

from repro.core import OraclePRM, Scheduler, SchedulerConfig
from repro.core.scheduler import percentile_latency
from repro.data import tasks
from repro.data import tokenizer as tk
from repro.models import Model
from repro.serving import Engine, EngineConfig, SamplingParams

from conftest import tiny_config


def _setup(policy, n=4, slots=8, window=8, max_tokens=48, seed=1,
           num_requests=4, arrival_gap=5):
    cfg = tiny_config(vocab_size=tk.VOCAB_SIZE)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = Engine(model, params, EngineConfig(
        page_size=8, num_pages=256, max_slots=slots,
        max_pages_per_branch=16, eos_id=tk.EOS,
        sampling=SamplingParams(temperature=1.0, top_p=0.95), seed=seed))
    prm = OraclePRM(tasks.oracle_grader, noise=0.05, seed=seed + 1)
    sch = Scheduler(eng, prm, SchedulerConfig(
        policy=policy, n=n, window=window, max_tokens=max_tokens),
        answer_fn=tasks.extract_answer)
    rng = np.random.default_rng(seed + 2)
    probs = [tasks.gen_problem(rng) for _ in range(num_requests)]
    for i, p in enumerate(probs):
        sch.submit(p.prompt_tokens(), payload=p, arrival=i * arrival_gap)
    return eng, sch, probs


@pytest.mark.parametrize("policy", ["vanilla", "sc", "sart", "sart_noprune",
                                    "rebase"])
def test_policy_completes_all_requests(policy):
    eng, sch, probs = _setup(policy)
    m = sch.run(max_steps=20000)
    assert len(m["requests"]) == len(probs)
    assert all(r["finish"] >= r["arrival"] for r in m["requests"])
    eng.allocator.check_invariants()
    assert eng.allocator.used_pages == 0, f"{policy}: page leak"
    assert all(s is None for s in eng.slots), f"{policy}: slot leak"


def test_vanilla_single_branch():
    eng, sch, _ = _setup("vanilla")
    m = sch.run(max_steps=20000)
    for r in m["requests"]:
        assert r["num_completed"] == 1
        assert r["num_pruned"] == 0
        assert len(r["response_lengths"]) == 1


def test_sc_waits_for_all_n():
    eng, sch, _ = _setup("sc", n=4)
    m = sch.run(max_steps=20000)
    for r in m["requests"]:
        assert r["num_completed"] == 4


def test_sart_early_stops_at_m():
    eng, sch, _ = _setup("sart", n=4)      # m defaults to n//2 = 2
    m = sch.run(max_steps=20000)
    for r in m["requests"]:
        assert r["num_completed"] + r["num_pruned"] <= 4
        assert r["num_completed"] >= 1
        # early stop: never more than m completions + the window slack
        assert r["num_completed"] <= 2


def test_sart_noprune_never_prunes():
    eng, sch, _ = _setup("sart_noprune", n=4)
    m = sch.run(max_steps=20000)
    assert all(r["num_pruned"] == 0 for r in m["requests"])


def test_pruning_occurs_with_hostile_prm():
    """A PRM that hates everything prunes aggressively in phase 1. A short
    window makes the first pruning round run before random-EOS completions
    can flip the pruner into exploit phase (threshold 0.0 prunes nothing)."""
    eng, sch, probs = _setup("sart", n=4, num_requests=2, window=2)
    sch.prm = OraclePRM(lambda req, toks: 0.0, noise=0.0)
    m = sch.run(max_steps=20000)
    assert any(r["num_pruned"] > 0 for r in m["requests"])
    assert eng.allocator.used_pages == 0


def test_metrics_structure():
    eng, sch, _ = _setup("sart", num_requests=3)
    m = sch.run(max_steps=20000)
    r = m["requests"][0]
    for key in ("e2e", "queue", "inference", "arrival", "finish"):
        assert key in r
    assert r["e2e"] == r["queue"] + r["inference"] + \
        (r["first_service"] - r["first_service"])  # identity check
    assert np.isfinite(percentile_latency(m, 97))
    t = m["timeline"]
    assert len(t.steps) == len(t.live_branches) == len(t.live_tokens)


def test_fcfs_first_service_ordering():
    eng, sch, _ = _setup("sart", num_requests=4, arrival_gap=30)
    m = sch.run(max_steps=20000)
    fs = [r["first_service"] for r in
          sorted(m["requests"], key=lambda r: r["arrival"])]
    assert fs == sorted(fs)


def test_queue_latency_grows_under_load():
    """Tiny slot budget + many branches => later requests queue (the
    phenomenon SART's pruning attacks)."""
    eng, sch, _ = _setup("sc", n=4, slots=4, num_requests=4, arrival_gap=0)
    m = sch.run(max_steps=40000)
    qs = [r["queue"] for r in m["requests"]]
    assert max(qs) > 0


def test_preemptive_scheduling():
    """Beyond-paper: preemption suspends the weakest branch to admit a
    waiting request, cutting its queuing delay; everything still completes
    with no slot or page leaks."""
    eng, sch, probs = _setup("sart", n=4, slots=4, num_requests=4,
                             arrival_gap=0)
    sch.cfg = sch.cfg.__class__(**{**sch.cfg.__dict__, "preempt": True})
    m = sch.run(max_steps=40000)
    assert len(m["requests"]) == 4
    eng.allocator.check_invariants()
    assert eng.allocator.used_pages == 0
    assert all(s is None for s in eng.slots)
    # with preemption under full contention, later requests get service
    # earlier than the non-preemptive run
    eng2, sch2, _ = _setup("sart", n=4, slots=4, num_requests=4,
                           arrival_gap=0)
    m2 = sch2.run(max_steps=40000)
    q_pre = sorted(r["queue"] for r in m["requests"])
    q_base = sorted(r["queue"] for r in m2["requests"])
    assert q_pre[-1] <= q_base[-1]


def test_suspend_resume_preserves_generation():
    """A suspended+resumed branch continues exactly where it left off."""
    import jax
    from repro.models import Model
    from repro.serving import Engine, EngineConfig, SamplingParams
    from conftest import tiny_config

    cfg = tiny_config()
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))

    def run(with_suspend):
        eng = Engine(model, params, EngineConfig(
            page_size=4, num_pages=64, max_slots=2, max_pages_per_branch=16,
            eos_id=1, sampling=SamplingParams(temperature=0.0), seed=0))
        blocks, lg, ssm = eng.prefill([2, 5, 9, 13])
        h = eng.spawn_branch(0, blocks, lg, ssm, 4)
        for _ in range(4):
            eng.decode_step()
        if with_suspend:
            eng.suspend_branch(h)
            # another branch occupies the slot meanwhile
            other = eng.spawn_branch(1, blocks, lg, ssm, 4)
            eng.decode_step()
            eng.free_branch(other)
            assert eng.resume_branch(h)
        for _ in range(4):
            eng.decode_step()
        toks = list(h.tokens)
        eng.free_branch(h)
        eng.release_prefix(blocks)
        assert eng.allocator.used_pages == 0
        return toks

    assert run(False) == run(True)
