"""Figure 2: response length vs correctness (weak correlation).

Live path: sample many responses per question from the trained tiny
reasoner, bin by length, count correct/wrong per bin, report the
length-correctness point-biserial correlation. Falls back to the synthetic
trace generator when no checkpoint exists (same claim, oracle-rendered)."""
from __future__ import annotations

import os

import numpy as np


def synthetic(num_questions=3, responses=64, seed=0):
    from repro.data import tasks
    rng = np.random.default_rng(seed)
    rows = []
    for qi in range(num_questions):
        prob = tasks.gen_problem(rng)
        lengths, corrects = [], []
        for _ in range(responses):
            # stochastic verbosity + occasional wrong steps, independent
            trace = tasks.render_trace(prob, rng, recheck_p=0.3,
                                       error_p=0.08, overthink_p=0.15)
            plen = len(prob.prompt_tokens())
            lengths.append(len(trace) - plen)
            ans = tasks.extract_answer(trace)
            c, t = tasks.grade_steps(prob, trace[plen:])
            corrects.append(ans == prob.answer and c == t)
        rows.append((qi, np.asarray(lengths), np.asarray(corrects)))
    return rows


def live(ckpt_dir, num_questions=3, responses=64, max_tokens=96, seed=0):
    import jax

    from repro.data import tasks
    from repro.data import tokenizer as tk
    from repro.launch.serve import load_reasoner
    from repro.serving import Engine, EngineConfig, SamplingParams

    model, params, _ = load_reasoner(ckpt_dir)
    rng = np.random.default_rng(seed)
    rows = []
    for qi in range(num_questions):
        prob = tasks.gen_problem(rng)
        eng = Engine(model, params, EngineConfig(
            page_size=8, num_pages=2048, max_slots=16,
            max_pages_per_branch=24, eos_id=tk.EOS,
            sampling=SamplingParams(temperature=1.0, top_p=0.95),
            seed=seed + qi))
        blocks, logits, ssm = eng.prefill(prob.prompt_tokens())
        lengths, corrects = [], []
        remaining = responses
        while remaining > 0:
            hs = []
            while remaining > 0 and eng.free_slots:
                h = eng.spawn_branch(0, blocks, logits, ssm,
                                     len(prob.prompt_tokens()))
                hs.append(h)
                remaining -= 1
            live_set = set(h.branch_id for h in hs)
            while live_set:
                eng.decode_step()
                for h in hs:
                    if h.branch_id in live_set and (
                            h.tokens[-1] == tk.EOS
                            or len(h.tokens) >= max_tokens):
                        lengths.append(len(h.tokens))
                        corrects.append(
                            tasks.extract_answer(h.tokens) == prob.answer)
                        live_set.discard(h.branch_id)
                        eng.free_branch(h)
        eng.release_prefix(blocks)
        rows.append((qi, np.asarray(lengths), np.asarray(corrects)))
    return rows


def correlation(lengths, corrects):
    if corrects.std() == 0 or lengths.std() == 0:
        return 0.0
    return float(np.corrcoef(lengths, corrects.astype(float))[0, 1])


def main(quick: bool = False, ckpt="checkpoints/reasoner"):
    n_resp = 16 if quick else 64
    use_live = os.path.exists(os.path.join(ckpt, "lm.npz")) and not quick
    rows = (live(ckpt, responses=n_resp) if use_live
            else synthetic(responses=n_resp))
    mode = "live" if use_live else "synthetic"
    for qi, lengths, corrects in rows:
        r = correlation(lengths, corrects)
        print(f"fig2_q{qi}_{mode},{lengths.mean():.1f},"
              f"acc={corrects.mean():.2f};len_corr={r:+.3f};"
              f"len_range={lengths.min()}-{lengths.max()}")


if __name__ == "__main__":
    main()
