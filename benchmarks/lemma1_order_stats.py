"""Lemma 1 validation (paper §3): analytic order-statistic CDF of the M-th
completion vs Monte-Carlo, and the induced early-stopping speedup."""
from __future__ import annotations

import numpy as np

from repro.core import (empirical_mth_completion, expected_speedup,
                        order_statistic_cdf, order_statistic_expectation)


def run(mean_log=7.0, sigma=0.8, samples=20000, seed=0, quick=False):
    rng = np.random.default_rng(seed)
    lengths = rng.lognormal(mean_log, sigma, size=samples
                            if not quick else 2000)
    rows = []
    for (m, n) in [(4, 4), (4, 6), (4, 8), (4, 12), (8, 8), (8, 16)]:
        analytic = order_statistic_expectation(lengths, m, n)
        mc = float(empirical_mth_completion(
            lengths, m, n, trials=4000 if not quick else 500).mean())
        rows.append({
            "m": m, "n": n,
            "analytic_E": analytic, "monte_carlo_E": mc,
            "rel_err": abs(analytic - mc) / mc,
            "speedup_vs_waiting_all_m": expected_speedup(lengths, m, n),
        })
    return rows


def main(quick: bool = False):
    for r in run(quick=quick):
        print(f"lemma1_m{r['m']}_n{r['n']},{r['analytic_E']:.1f},"
              f"mc={r['monte_carlo_E']:.1f};err={r['rel_err']:.3f};"
              f"speedup={r['speedup_vs_waiting_all_m']:.2f}x")


if __name__ == "__main__":
    main()
