"""Kernel micro-benchmarks: paged flash-decode attention and SSD scan.

On CPU the timings exercise the jnp reference path (what the live engine
runs); the Pallas kernels themselves are validated via interpret mode. The
derived column reports bytes touched per call — the quantity that matters
for the memory-bound decode roofline on the TPU target."""
from __future__ import annotations

import sys
import time

try:
    import repro  # noqa: F401  (deferred per-bench imports hide the error)
except ModuleNotFoundError:
    sys.exit(
        "kernel_bench: the `repro` package is not importable — run from the "
        "repo root with PYTHONPATH=src, e.g.\n"
        "    PYTHONPATH=src python -m benchmarks.kernel_bench\n"
        "or use the wrapper: scripts/bench.sh kernel_bench")

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, iters=20):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def bench_paged_attention(quick=False):
    from repro.kernels.paged_attention.ops import paged_attention
    rng = np.random.default_rng(0)
    shapes = [(8, 8, 2, 64, 16, 32)] if quick else [
        (8, 8, 2, 64, 16, 32),
        (16, 16, 8, 128, 16, 64),
        (32, 8, 2, 64, 16, 128),
    ]
    rows = []
    for (b, qh, kvh, hd, ps, pps) in shapes:
        npages = b * pps + 1
        q = jnp.asarray(rng.normal(size=(b, qh, hd)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(kvh, npages, ps, hd)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(kvh, npages, ps, hd)), jnp.float32)
        bt = jnp.asarray(rng.integers(0, npages, size=(b, pps)), jnp.int32)
        lens = jnp.full((b,), pps * ps, jnp.int32)
        fn = jax.jit(lambda q, k, v, bt, l: paged_attention(
            q, k, v, bt, l, use_kernel=False))
        us = _time(fn, q, k, v, bt, lens, iters=5 if quick else 20)
        kv_bytes = 2 * b * pps * ps * kvh * hd * 4
        rows.append((f"paged_attn_b{b}_s{pps * ps}_h{qh}", us,
                     f"kv_bytes={kv_bytes}"))
    return rows


def bench_ssd(quick=False):
    from repro.kernels.ssd_scan.ops import ssd
    rng = np.random.default_rng(0)
    shapes = [(2, 256, 4, 32, 16, 32)] if quick else [
        (2, 256, 4, 32, 16, 32),
        (4, 1024, 8, 64, 64, 64),
    ]
    rows = []
    for (b, s, h, p, n, q) in shapes:
        x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
        dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(b, s, h)), jnp.float32)
        a = -jnp.ones((h,), jnp.float32)
        bb = jnp.asarray(rng.normal(size=(b, s, h, n)), jnp.float32)
        cc = jnp.asarray(rng.normal(size=(b, s, h, n)), jnp.float32)
        from repro.models.mamba2 import ssd_chunked
        fn = jax.jit(lambda *args: ssd_chunked(*args, chunk=q)[0])
        us = _time(fn, x, dt, a, bb, cc, iters=5 if quick else 20)
        flops = 2 * b * (s // q) * h * (q * q * n + q * q * p + 2 * q * n * p)
        rows.append((f"ssd_b{b}_s{s}_h{h}", us, f"flops={flops}"))
    return rows


def bench_mixed_step(quick=False):
    """Chunk-row attention inside the mixed decode+prefill step: the
    per-token flash-decode path (every chunk row streams the whole context)
    vs the fused paged flash-prefill kernel (each q block streams it once).
    The bytes column is the analytic K+V HBM read — the memory-bound
    quantity that gates time-to-first-branch on the TPU target; wall-clock
    here times the jnp reference of each path (what the CPU engine runs),
    as an interpret-normalized op-count proxy."""
    from repro.kernels.flash_prefill.ops import (mixed_step_bytes_read,
                                                 paged_flash_prefill)
    from repro.kernels.paged_attention.ops import paged_attention
    rng = np.random.default_rng(0)
    shapes = [(64, 256, 4, 2, 64, 16)] if quick else [
        (256, 2048, 8, 8, 64, 16),
        (256, 4096, 8, 2, 64, 16),
    ]  # (chunk T, context pos0, q_heads, kv_heads, head_dim, page_size)
    rows = []
    for (t, pos0, qh, kvh, hd, ps) in shapes:
        need = -(-(pos0 + t) // ps)
        npages = need + 1
        pps = need + 2
        q = jnp.asarray(rng.normal(size=(t, qh, hd)), jnp.float32)
        kp = jnp.asarray(rng.normal(size=(kvh, npages, ps, hd)), jnp.float32)
        vp = jnp.asarray(rng.normal(size=(kvh, npages, ps, hd)), jnp.float32)
        bt = np.full((pps,), npages, np.int32)
        bt[:need] = rng.permutation(npages)[:need]
        bt = jnp.asarray(bt)
        iters = 3 if quick else 10

        fused = jax.jit(lambda q, kp, vp, bt: paged_flash_prefill(
            q, kp, vp, bt, jnp.int32(pos0), jnp.int32(t), use_kernel=False))
        us_f = _time(fused, q, kp, vp, bt, iters=iters)
        by_f = mixed_step_bytes_read(t, pos0, ps, kvh, hd, path="fused")
        rows.append((f"mixed_step_fused_c{t}_ctx{pos0}_kv{kvh}", us_f,
                     f"kv_bytes={by_f}"))

        bt_rows = jnp.broadcast_to(bt, (t, pps))
        lens = pos0 + jnp.arange(t) + 1
        decode = jax.jit(lambda q, kp, vp, bt, ln: paged_attention(
            q, kp, vp, bt, ln, use_kernel=False))
        us_d = _time(decode, q, kp, vp, bt_rows, lens, iters=iters)
        by_d = mixed_step_bytes_read(t, pos0, ps, kvh, hd, path="decode")
        rows.append((f"mixed_step_decode_c{t}_ctx{pos0}_kv{kvh}", us_d,
                     f"kv_bytes={by_d} ({by_d / by_f:.1f}x fused)"))
    return rows


def bench_tree_decode(quick=False):
    """Sibling-branch decode attention: the per-branch flash-decode loop
    (every sibling re-streams the shared ancestor pages) vs the tree
    kernel (shared pages streamed once per step, suffixes once each).
    The bytes column is the analytic K+V HBM read per decode step — for
    N siblings over a deep shared prefix the tree path approaches an N×
    reduction on the shared-page traffic, which is the memory-bound win
    for the SART resampling workload (many short branches over one
    prompt). Wall-clock times the jnp reference of each path."""
    from repro.kernels.paged_attention.ops import (paged_attention,
                                                   paged_tree_attention,
                                                   tree_decode_bytes_read)
    rng = np.random.default_rng(0)
    qh, kvh, hd, ps = 8, 2, 64, 16
    shared, suffix = (4, 1) if quick else (32, 2)   # pages
    branch_counts = [2] if quick else [2, 4, 8]
    rows = []
    for n in branch_counts:
        pps = shared + suffix + 1                   # +1 pad column
        npages = shared + n * suffix + 1
        perm = rng.permutation(npages - 1)
        shared_ids = perm[:shared]
        q = jnp.asarray(rng.normal(size=(n, qh, hd)), jnp.float32)
        kp = jnp.asarray(rng.normal(size=(kvh, npages, ps, hd)), jnp.float32)
        vp = jnp.asarray(rng.normal(size=(kvh, npages, ps, hd)), jnp.float32)
        full_bt = np.full((n, pps), npages, np.int32)
        branch_bt = np.full((n, pps), npages, np.int32)
        for b in range(n):
            ids = perm[shared + b * suffix:shared + (b + 1) * suffix]
            full_bt[b, :shared] = shared_ids
            full_bt[b, shared:shared + suffix] = ids
            branch_bt[b, :suffix] = ids
        lens = jnp.full((n,), (shared + suffix) * ps, jnp.int32)
        row_group = jnp.zeros((n,), jnp.int32)
        shared_tab = jnp.asarray(
            np.pad(shared_ids, (0, pps - shared),
                   constant_values=npages)[None, :], jnp.int32)
        shared_lens = jnp.asarray([shared * ps], jnp.int32)
        iters = 3 if quick else 10

        branch = jax.jit(lambda q, kp, vp, bt, ln: paged_attention(
            q, kp, vp, bt, ln, use_kernel=False))
        us_b = _time(branch, q, kp, vp, jnp.asarray(full_bt), lens,
                     iters=iters)
        by_b = tree_decode_bytes_read(shared, [suffix] * n, ps, kvh, hd,
                                      path="branch")
        rows.append((f"tree_decode_branch_n{n}_sh{shared * ps}", us_b,
                     f"kv_bytes={by_b}"))

        tree = jax.jit(lambda q, kp, vp, rg, sbt, sl, bbt, ln:
                       paged_tree_attention(q, kp, vp, rg, sbt, sl, bbt,
                                            ln, use_kernel=False))
        us_t = _time(tree, q, kp, vp, row_group, shared_tab, shared_lens,
                     jnp.asarray(branch_bt), lens, iters=iters)
        by_t = tree_decode_bytes_read(shared, [suffix] * n, ps, kvh, hd,
                                      path="tree")
        rows.append((f"tree_decode_tree_n{n}_sh{shared * ps}", us_t,
                     f"kv_bytes={by_t} ({by_b / by_t:.1f}x less than "
                     "branch)"))
    return rows


def bench_engine_decode_step(quick=False):
    """Whole-engine decode step (model fwd + paged attention + sampling)."""
    from repro.data import tokenizer as tk
    from repro.models import Model, ModelConfig
    from repro.serving import Engine, EngineConfig

    cfg = ModelConfig(name="b", arch_type="dense", num_layers=2, d_model=128,
                      vocab_size=tk.VOCAB_SIZE, num_heads=4, num_kv_heads=2,
                      d_ff=512)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = Engine(model, params, EngineConfig(page_size=8, num_pages=512,
                                             max_slots=8, eos_id=tk.EOS))
    blocks, lg, ssm = eng.prefill([2, 3, 4, 5])
    hs = [eng.spawn_branch(0, blocks, lg, ssm, 4) for _ in range(8)]
    for _ in range(3):
        eng.decode_step()     # warmup / page setup
    t0 = time.perf_counter()
    iters = 10 if quick else 50
    for _ in range(iters):
        eng.decode_step()
    us = (time.perf_counter() - t0) / iters * 1e6
    for h in hs:
        eng.free_branch(h)
    eng.release_prefix(blocks)
    return [("engine_decode_step_b8", us, "tokens_per_step=8")]


def bench_chunked_prefill(quick=False):
    """Admission cost across ragged prompt lengths: the chunked-bucketed
    path compiles O(num_buckets) shapes where the exact-length path compiles
    one program per distinct length — the dominant admission latency when
    prompt lengths are diverse. Run for an attention-only AND an ssm config:
    since the masked-dt chunk lane, ssm/hybrid admission is bucketed too."""
    from repro.data import tokenizer as tk
    from repro.models import Model, ModelConfig
    from repro.serving import Engine, EngineConfig

    arch_cfgs = {
        "dense": ModelConfig(name="b", arch_type="dense", num_layers=2,
                             d_model=128, vocab_size=tk.VOCAB_SIZE,
                             num_heads=4, num_kv_heads=2, d_ff=512),
        "ssm": ModelConfig(name="b-ssm", arch_type="ssm", num_layers=2,
                           d_model=128, vocab_size=tk.VOCAB_SIZE,
                           num_heads=4, num_kv_heads=2, d_ff=0,
                           ssm_state=16, ssm_head_dim=32, ssm_chunk=8),
    }
    rng = np.random.default_rng(0)
    n_prompts = 6 if quick else 16
    lengths = rng.permutation(np.arange(5, 5 + n_prompts))
    prompts = [[int(t) for t in rng.integers(2, 16, size=int(s))]
               for s in lengths]

    rows = []
    for arch, cfg in arch_cfgs.items():
        model = Model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        for mode in ("chunked", "exact"):
            eng = Engine(model, params, EngineConfig(
                page_size=8, num_pages=512, max_slots=8,
                max_pages_per_branch=16, eos_id=tk.EOS, prefill_chunk=8))
            t0 = time.perf_counter()
            for p in prompts:
                blocks, _, _ = eng.prefill(p, exact=(mode == "exact"))
                eng.release_prefix(blocks)
            us = (time.perf_counter() - t0) / len(prompts) * 1e6
            compiles = (eng.prefill_compile_count if mode == "chunked"
                        else len(eng._prefill_cache))
            rows.append((f"prefill_{mode}_{arch}_ragged{len(prompts)}", us,
                         f"compiles={compiles}"))
    return rows


def bench_prefix_cache(quick=False):
    """Admission cost of a shared few-shot header, cold vs warm: the radix
    page-hash prefix cache serves the cached page-aligned prefix from
    resident pages, so a warm admission computes and writes K/V only for
    the uncached tail. The derived column reports the analytic K/V bytes
    *written* during admission (tokens actually chunked x 2 x L x kv x hd
    x 4B) plus the hit tokens — the acceptance quantity: cached tokens
    cost ~0 bytes and ~0 prefill compute on warm hits."""
    import jax as _jax

    from repro.data import tokenizer as tk
    from repro.models import Model, ModelConfig
    from repro.serving import Engine, EngineConfig

    cfg = ModelConfig(name="b", arch_type="dense", num_layers=2, d_model=128,
                      vocab_size=tk.VOCAB_SIZE, num_heads=4, num_kv_heads=2,
                      d_ff=512)
    model = Model(cfg)
    params = model.init_params(_jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    header_pages = 4 if quick else 16
    ps, chunk = 16, 32
    header = [int(t) for t in rng.integers(2, 16, size=header_pages * ps)]
    tail = lambda i: [int(t) for t in rng.integers(2, 16, size=ps - 1)]
    n_warm = 2 if quick else 4
    kv_token_bytes = 2 * cfg.num_layers * cfg.num_kv_heads * \
        (cfg.d_model // cfg.num_heads) * 4

    def admit(eng, prompt):
        t0 = time.perf_counter()
        st = eng.begin_prefill(prompt)
        written = len(prompt) - st.next_pos
        while not st.done:
            eng.decode_step()
        eng.finish_prefill(st)
        us = (time.perf_counter() - t0) * 1e6
        return st, written, us

    rows = []
    eng = Engine(model, params, EngineConfig(
        page_size=ps, num_pages=512, max_slots=4, max_pages_per_branch=32,
        eos_id=tk.EOS, prefill_chunk=chunk, prefix_cache=True))
    st, written, us = admit(eng, header + tail(0))   # cold: full compute
    rows.append((f"prefix_cache_cold_admit_s{len(st.prompt)}", us,
                 f"kv_bytes_written={written * kv_token_bytes};"
                 f"hit_tokens=0"))
    eng.release_prefix(st.blocks)
    warm_us, warm_written = [], []
    for i in range(1, n_warm + 1):                   # warm: header cached
        st, written, us = admit(eng, header + tail(i))
        warm_us.append(us)
        warm_written.append(written)
        eng.release_prefix(st.blocks)
    hit = eng.prefix_cache.stats()["hit_tokens"] // n_warm
    warm_bytes = int(np.mean(warm_written)) * kv_token_bytes
    rows.append((f"prefix_cache_warm_admit_s{len(st.prompt)}",
                 float(np.mean(warm_us)),
                 f"kv_bytes_written={warm_bytes};hit_tokens={hit}"))
    return rows


def collect(quick: bool = False):
    rows = []
    for bench in (bench_paged_attention, bench_ssd, bench_mixed_step,
                  bench_tree_decode, bench_engine_decode_step,
                  bench_chunked_prefill, bench_prefix_cache):
        rows.extend(bench(quick))
    return rows


def main(argv=None, quick=None) -> int:
    # benchmarks.run calls ``main(quick=...)`` directly — that legacy
    # harness path must not touch sys.argv (run.py owns --full)
    if quick is not None:
        for name, us, derived in collect(quick):
            print(f"{name},{us:.1f},{derived}")
        return 0
    import argparse
    import json
    parser = argparse.ArgumentParser(
        prog="python -m benchmarks.kernel_bench",
        description="kernel/engine micro-benchmarks (CPU reference paths)")
    parser.add_argument("--quick", action="store_true",
                        help="small shapes, few iterations (smoke run)")
    parser.add_argument("--json", action="store_true", dest="as_json",
                        help="machine-readable summary (CI artifact): "
                             "[{name, us, derived}, ...]")
    args = parser.parse_args(argv)
    rows = collect(args.quick)
    if args.as_json:
        print(json.dumps([{"name": name, "us": round(us, 1),
                           "derived": derived}
                          for name, us, derived in rows], indent=2))
    else:
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
