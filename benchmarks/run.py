"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (the second column is the
headline quantity of that experiment: latency steps, expected length,
microseconds, or roofline seconds — see each module).

  python -m benchmarks.run [--full]   (default is quick mode)
"""
from __future__ import annotations

import sys
import time
import traceback

from . import (fig2_length_correctness, fig3_branch_utilization, fig5_e2e,
               fig6_ablation, fig7_sensitivity, kernel_bench,
               lemma1_order_stats, roofline)

MODULES = [
    ("lemma1", lemma1_order_stats),
    ("fig2", fig2_length_correctness),
    ("fig3", fig3_branch_utilization),
    ("fig5", fig5_e2e),
    ("fig6", fig6_ablation),
    ("fig7", fig7_sensitivity),
    ("kernels", kernel_bench),
    ("roofline", roofline),
]


def main() -> None:
    quick = "--full" not in sys.argv
    print("name,us_per_call,derived")
    failures = []
    for name, mod in MODULES:
        t0 = time.time()
        try:
            mod.main(quick=quick)
            print(f"_section_{name},{(time.time() - t0) * 1e6:.0f},ok",
                  flush=True)
        except Exception:
            traceback.print_exc()
            failures.append(name)
            print(f"_section_{name},0,FAILED", flush=True)
    if failures:
        sys.exit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
