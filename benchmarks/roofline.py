"""Roofline analysis (§g): three terms per (arch x shape x mesh) from the
dry-run's compiled artifacts.

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw

HLO quantities come from ``experiments/dryrun/*.json`` (written by
``repro.launch.dryrun``), loop-corrected via the unrolled-L extrapolation
(see dryrun.py — XLA counts while bodies once). The SPMD module is the
per-device program, so per-device numbers divide by per-chip peaks directly
(equivalent to total/(chips x peak) under even sharding).

MODEL_FLOPS = 6·N·D (train) or 2·N·D (inference), N = active params,
D = tokens processed; the ratio MODEL_FLOPS/HLO_FLOPs measures how much
compiled compute is useful (remat, attention, GQA-padding and dispatch
overheads all push it below 1).
"""
from __future__ import annotations

import glob
import json
import os
import sys

PEAK_FLOPS = 197e12     # bf16 FLOP/s per v5e chip
HBM_BW = 819e9          # B/s
LINK_BW = 50e9          # B/s per ICI link

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def model_flops_per_device(rec: dict) -> float:
    n_active = rec["params_active"]
    if rec["kind"] == "train":
        tokens = rec["global_batch"] * rec["seq_len"]
        total = 6.0 * n_active * tokens
    elif rec["kind"] == "prefill":
        tokens = rec["global_batch"] * rec["seq_len"]
        total = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n_active * rec["global_batch"]
    return total / rec["num_devices"]


def analyze(rec: dict) -> dict:
    ex = rec.get("extrapolated", {})
    if ex.get("ok"):
        # clamp: constant overheads can make m(2) marginally < m(1), which
        # extrapolates to tiny negative totals on near-zero terms
        flops = max(ex["flops"], rec["flops_per_device"])
        bytes_ = max(ex["bytes"], 0.0)
        coll = max(ex["coll_total"], 0.0)
        corrected = True
    else:
        flops, bytes_ = rec["flops_per_device"], rec["bytes_per_device"]
        coll = rec["collectives"]["total_bytes"]
        corrected = False
    t_c = flops / PEAK_FLOPS
    t_m = bytes_ / HBM_BW
    t_n = coll / LINK_BW
    dominant = max(("compute", t_c), ("memory", t_m),
                   ("collective", t_n), key=lambda kv: kv[1])[0]
    mf = model_flops_per_device(rec)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_n,
        "dominant": dominant,
        "model_flops_per_dev": mf,
        "useful_ratio": mf / flops if flops else 0.0,
        "hlo_flops_per_dev": flops,
        "hlo_bytes_per_dev": bytes_,
        "coll_bytes_per_dev": coll,
        "temp_gib_per_dev": rec["memory"]["temp_bytes"] / 2 ** 30,
        "loop_corrected": corrected,
    }


def suggestion(row: dict) -> str:
    d = row["dominant"]
    if d == "collective":
        return ("shrink all-gather/all-reduce traffic: fewer resharding "
                "boundaries, reduce-scatter grads, or move the hot dim off "
                "the mesh axis that forces the collective")
    if d == "memory":
        return ("cut bytes/step: fuse elementwise chains, keep bf16 end-to-"
                "end, avoid re-materializing the KV cache or remat'd "
                "activations")
    return ("raise MXU utilization: larger effective matmul tiles, remove "
            "GQA/vocab padding waste, reduce remat recompute")


def load_records(mesh: str = "single", tag: str = ""):
    """tag="" loads baselines only; perf-variant records carry a tag."""
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("mesh") == mesh and rec.get("tag", "") == tag:
            recs.append(rec)
    return recs


def table(mesh: str = "single", fmt: str = "md") -> str:
    rows = [analyze(r) for r in load_records(mesh)]
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])))
    if fmt == "csv":
        out = ["arch,shape,compute_s,memory_s,collective_s,dominant,"
               "useful_ratio,temp_gib"]
        for r in rows:
            out.append(f"{r['arch']},{r['shape']},{r['compute_s']:.3e},"
                       f"{r['memory_s']:.3e},{r['collective_s']:.3e},"
                       f"{r['dominant']},{r['useful_ratio']:.3f},"
                       f"{r['temp_gib_per_dev']:.2f}")
        return "\n".join(out)
    out = ["| arch | shape | compute (s) | memory (s) | collective (s) | "
           "dominant | 6ND/HLO | temp GiB/dev |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.2e} | "
            f"{r['memory_s']:.2e} | {r['collective_s']:.2e} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['temp_gib_per_dev']:.2f} |")
    return "\n".join(out)


def main(quick: bool = False):
    rows = [analyze(r) for r in load_records("single")]
    if not rows:
        print("roofline,0,no-dryrun-records")
        return
    for r in sorted(rows, key=lambda r: (r["arch"],
                                         SHAPE_ORDER.index(r["shape"]))):
        total = max(r["compute_s"], r["memory_s"], r["collective_s"])
        print(f"roofline_{r['arch']}_{r['shape']},{total * 1e6:.1f},"
              f"dominant={r['dominant']};useful={r['useful_ratio']:.2f}")


if __name__ == "__main__":
    fmt = sys.argv[1] if len(sys.argv) > 1 else "md"
    mesh = sys.argv[2] if len(sys.argv) > 2 else "single"
    print(table(mesh=mesh, fmt=fmt))
