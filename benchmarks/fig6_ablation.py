"""Figure 6 ablation: Self-Consistency vs SART-without-pruning vs full SART.

Left plots: response-length and queuing-time distributions; right: E2E
latency + accuracy vs N. Isolates the two mechanisms: early stopping
shortens served lengths; pruning shrinks queuing."""
from __future__ import annotations

import numpy as np

from repro.core.scheduler import percentile_latency
from repro.serving.simulator import (SimEngineConfig, SimWorkload,
                                     run_sim_experiment)


def run(quick: bool = False, seed: int = 0):
    w = SimWorkload(mean_len=250 if quick else 2000, sigma_len=0.6,
                    overthink_p=0.12)
    ec = SimEngineConfig(max_slots=16, num_pages=500000)
    nreq = 12 if quick else 40
    gap = 8 if quick else 60
    out = {}
    for policy, n in [("sc", 4), ("sart_noprune", 8), ("sart", 8)]:
        m, acc = run_sim_experiment(policy, n, m=4, num_requests=nreq,
                                    arrival_gap=gap, workload=w,
                                    engine_cfg=ec,
                                    window=100 if quick else 400, seed=seed)
        lengths = [l for r in m["requests"] for l in r["response_lengths"]]
        queues = [r["queue"] for r in m["requests"]]
        out[policy] = {
            "acc": acc,
            "mean_len": float(np.mean(lengths)),
            "p90_len": float(np.percentile(lengths, 90)),
            "mean_queue": float(np.mean(queues)),
            "p90_queue": float(np.percentile(queues, 90)),
            "p50_e2e": percentile_latency(m, 50),
            "p97_e2e": percentile_latency(m, 97),
        }
    return out


def main(quick: bool = False):
    out = run(quick=quick)
    for policy, r in out.items():
        print(f"fig6_{policy},{r['p50_e2e']:.0f},"
              f"mean_len={r['mean_len']:.0f};p90_len={r['p90_len']:.0f};"
              f"mean_queue={r['mean_queue']:.0f};"
              f"p90_queue={r['p90_queue']:.0f};acc={r['acc']:.2f}")
    # claims: early stop shortens lengths; pruning shrinks queues
    es_len = out["sart_noprune"]["mean_len"] <= out["sc"]["mean_len"]
    pr_q = out["sart"]["mean_queue"] <= out["sart_noprune"]["mean_queue"]
    print(f"fig6_claims,{int(es_len) + int(pr_q)},"
          f"early_stop_shortens={es_len};pruning_cuts_queue={pr_q}")


if __name__ == "__main__":
    main()
