"""Figure 3: running branches / live KV tokens over time, with and without
two-phase pruning (redundant sampling N=8, M=4 enabled in both)."""
from __future__ import annotations

import numpy as np

from repro.serving.simulator import (SimEngineConfig, SimWorkload,
                                     run_sim_experiment)


def run(quick: bool = False):
    w = SimWorkload(mean_len=300 if quick else 1500, sigma_len=0.6,
                    overthink_p=0.15)
    ec = SimEngineConfig(max_slots=16, num_pages=200000)
    out = {}
    for name, policy in [("with_pruning", "sart"),
                         ("without_pruning", "sart_noprune")]:
        m, _ = run_sim_experiment(policy, 8, m=4, num_requests=1,
                                  arrival_gap=0, workload=w, engine_cfg=ec,
                                  window=50, seed=0)
        t = m["timeline"]
        out[name] = {
            "steps": t.steps,
            "branches": t.live_branches,
            "tokens": t.live_tokens,
            "finish": m["requests"][0]["finish"],
        }
    return out


def main(quick: bool = False):
    out = run(quick=quick)
    for name, tl in out.items():
        tok = np.asarray(tl["tokens"])
        br = np.asarray(tl["branches"])
        # branch-steps integral = total resource consumption (Fig. 3's area)
        print(f"fig3_{name},{tok.mean():.0f},"
              f"peak_tokens={tok.max()};branch_steps={int(br.sum())};"
              f"finish={tl['finish']}")


if __name__ == "__main__":
    main()
