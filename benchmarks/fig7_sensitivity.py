"""Figure 7: sensitivity of SART to N — P50/P90/P97/P99 of E2E and
inference (E2E minus queuing) latency."""
from __future__ import annotations

from repro.core.scheduler import percentile_latency
from repro.serving.simulator import (SimEngineConfig, SimWorkload,
                                     run_sim_experiment)


def run(quick: bool = False, seed: int = 0):
    w = SimWorkload(mean_len=250 if quick else 2000, sigma_len=0.7,
                    overthink_p=0.2)
    ec = SimEngineConfig(max_slots=64, num_pages=500000)
    nreq = 16 if quick else 40
    gap = 30 if quick else 60
    rows = []
    for n in (1, 2, 4, 8):
        m, acc = run_sim_experiment("sart" if n > 1 else "vanilla",
                                    max(n, 1), num_requests=nreq,
                                    arrival_gap=gap, workload=w,
                                    engine_cfg=ec,
                                    window=100 if quick else 400,
                                    seed=seed)
        rows.append({
            "n": n, "acc": acc,
            **{f"p{q}": percentile_latency(m, q) for q in (50, 90, 97, 99)},
            **{f"inf_p{q}": percentile_latency(m, q, "inference")
               for q in (50, 97)},
        })
    return rows


def main(quick: bool = False):
    rows = run(quick=quick)
    for r in rows:
        print(f"fig7_n{r['n']},{r['p50']:.0f},"
              f"p90={r['p90']:.0f};p97={r['p97']:.0f};p99={r['p99']:.0f};"
              f"inf_p50={r['inf_p50']:.0f};inf_p97={r['inf_p97']:.0f};"
              f"acc={r['acc']:.2f}")
    tail_gain = rows[0]["p97"] / max(rows[-1]["p97"], 1e-9)
    print(f"fig7_tail_p97_n1_over_n8,{tail_gain:.2f},"
          f"redundant_sampling_cuts_tail={tail_gain > 1.0}")


if __name__ == "__main__":
    main()
