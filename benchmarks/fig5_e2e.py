"""Figure 5: end-to-end latency + accuracy of Vanilla / Self-Consistency /
Rebase / SART across N, at two arrival rates (trace-driven simulator at
paper-scale response lengths; the live tiny-model variant of the same
comparison runs in examples/sart_vs_baselines.py).

Also reports ``ttfb50`` (median time-to-first-branch) under Poisson-burst
arrivals for single-lane vs token-budget multi-lane chunk scheduling
(``SimEngineConfig.step_token_budget`` — see docs/scheduling.md): under
bursty admission the single FIFO chunk lane serializes prompts one chunk
per decode step, so the lane budget is what bounds time-to-first-branch at
high arrival rates. The burst prompts share a few-shot header, and each
lane configuration additionally runs with the radix prefix cache on
(``SimEngineConfig.prefix_cache`` — ``*_cached`` rows with their hit
rate): warm admissions skip the cached header's chunk steps entirely."""
from __future__ import annotations

import numpy as np

from repro.core.scheduler import percentile_latency
from repro.data import tokenizer as tk
from repro.serving.simulator import (SimEngine, SimEngineConfig, SimWorkload,
                                     adversarial_shared_header_mix,
                                     mixed_deadline_workload,
                                     poisson_burst_arrivals,
                                     run_sim_experiment)


def run_burst(quick: bool = False, seed: int = 0):
    """ttfb under Poisson-burst arrivals: step_token_budget set to one
    chunk (bit-exact legacy single-lane FIFO) vs multi-lane packing, each
    with the radix prefix cache off vs on. The burst prompts share a
    few-shot header (``SimWorkload.prompt_tail`` distinct tokens per
    request), so warm admissions skip the cached header's chunk steps —
    the cache rows report the hit rate alongside ttfb."""
    w = SimWorkload(mean_len=200 if quick else 400, sigma_len=0.6,
                    overthink_p=0.12, correct_p=0.55, prompt_len=512,
                    prompt_tail=64)
    nreq = 12 if quick else 24
    chunk = 64
    # high arrival rate: bursts of ~6 prompts every 30 steps; each prompt
    # is 8 chunks, so the single lane serializes ~48 chunk-steps per burst
    times = poisson_burst_arrivals(nreq, burst_gap=30, burst_mean=5)
    rows = []
    for lanes_name, budget in [("single", chunk), ("multi4", 4 * chunk)]:
        for cached in (False, True):
            ec = SimEngineConfig(max_slots=128, num_pages=500000,
                                 prefill_chunk=chunk,
                                 step_token_budget=budget,
                                 prefix_cache=cached)
            m, acc = run_sim_experiment(
                "sart", 4, num_requests=nreq, workload=w, engine_cfg=ec,
                window=100, seed=seed, arrival_times=times)
            pc = m.get("prefix_cache")
            rows.append({
                "lanes": lanes_name, "budget": budget, "accuracy": acc,
                "cached": cached,
                "hit_rate": pc["hit_rate"] if pc else 0.0,
                "p50": percentile_latency(m, 50),
                "ttfb50": percentile_latency(m, 50, "ttfb"),
                "ttfb97": percentile_latency(m, 97, "ttfb"),
            })
    return rows


def run_resample_burst(quick: bool = False, seed: int = 0):
    """Generated-prefix warm resample (the SART resampling workload):
    seeder requests at arrival 0 decode a branch past several page
    boundaries, publishing its generated full pages into the radix
    prefix cache keyed by prompt + generated tokens; a burst then
    *resamples* each request with prompt = original prompt + that
    branch's generated tokens (continue-from-here). Warm admission
    serves the generated prefix from resident pages, so the chunk-step
    and computed-token accounting — the sim's K/V-write proxy — drops
    below a cold admission of the same resample prompt. ``gen_hit_rate``
    is the fraction of *generated* resample tokens served from cache;
    it must be nonzero (prompt-only prefix caching cannot reach past
    the prompt boundary)."""
    ps, chunk = 16, 32
    n_seed = 3 if quick else 6
    prompt_len = 4 * ps
    gen_steps = 3 * ps if quick else 6 * ps
    w = SimWorkload(mean_len=100_000, sigma_len=0.1, overthink_p=0.0,
                    correct_p=0.55, prompt_len=prompt_len)
    ec = SimEngineConfig(max_slots=16, num_pages=4096, page_size=ps,
                         prefill_chunk=chunk, step_token_budget=chunk,
                         prefix_cache=True)
    eng = SimEngine(ec, w, seed=seed)
    rng = np.random.default_rng(seed + 0x5EED)

    def admit(prompt):
        before = eng.prefill_chunk_steps
        st = eng.begin_prefill(prompt)
        while not st.done:
            eng.decode_step()
        eng.finish_prefill(st)
        return st, eng.prefill_chunk_steps - before

    # --- seeders (arrival 0): decode one branch each, free it — its
    # generated full pages park warm on the cache LRU -------------------
    resamples = []
    for rid in range(n_seed):
        prompt = [tk.BOS] + [int(t) for t in
                             rng.integers(2, 16, size=prompt_len - 2)] \
            + [tk.EQUALS]
        st, _ = admit(prompt)
        blocks, lg, ssm = st.blocks, st.last_logits, st.ssm_state
        h = eng.spawn_branch(rid, blocks, lg, ssm, len(prompt),
                             prompt_tokens=prompt)
        for _ in range(gen_steps):
            eng.decode_step()
        written = h.blocks.length - len(prompt)
        resamples.append(prompt + h.tokens[:written])
        eng.free_branch(h)
        eng.release_prefix(blocks)

    # --- resample burst: original prompt + generated tokens ------------
    warm_steps = cold_steps = 0
    warm_tokens = cold_tokens = gen_hit = gen_total = 0
    for rp in resamples:
        st, steps = admit(rp)
        warm_steps += steps
        cold_steps += -(-len(rp) // chunk)
        warm_tokens += len(rp) - st.cached_tokens
        cold_tokens += len(rp)
        gen_hit += max(0, st.cached_tokens - prompt_len)
        gen_total += len(rp) - prompt_len
        eng.release_prefix(st.blocks)
    return {
        "warm_chunk_steps": warm_steps, "cold_chunk_steps": cold_steps,
        "warm_tokens": warm_tokens, "cold_tokens": cold_tokens,
        "gen_hit_rate": gen_hit / max(1, gen_total),
        "hit_rate": eng.prefix_cache.stats()["hit_rate"],
    }


def run_policies(quick: bool = False, seed: int = 0):
    """Admission-policy comparison table (docs/scheduling.md).

    Two workloads, each adversarial for FIFO admission:

    * cache row set — an adversarial shared-header burst
      (``adversarial_shared_header_mix``) under real page pressure
      (``num_pages=280``: the cold prompts' allocations can evict the idle
      warm header). ``warm_hit`` is the fraction of prompt tokens served
      from the radix prefix cache (per-request ``cached_tokens`` recorded
      at prefill harvest, so OutOfPages admission retries don't inflate
      it). ``lpm`` admits cached-prefix matches first, pinning the header
      pages before the colds can evict them.

    * slo row set — a mixed-deadline workload
      (``mixed_deadline_workload``) on a serialized single chunk lane:
      loose-deadline requests arrive (and are submitted) just before
      tight-deadline ones. ``edf`` reorders the arrived set by absolute
      deadline; ``attainment`` is the met fraction among
      deadline-carrying requests.
    """
    rows = []
    # --- cache-aware admission: lpm vs fifo under page pressure ---------
    prompts, times = adversarial_shared_header_mix(seed=seed)
    w = SimWorkload(mean_len=80 if quick else 120, sigma_len=0.5,
                    overthink_p=0.1, correct_p=0.55, prompt_len=512)
    ec = SimEngineConfig(max_slots=128, num_pages=280, prefill_chunk=64,
                         step_token_budget=256, prefix_cache=True)
    for policy in ("fifo", "lpm", "priority+lpm"):
        # the composed row tiers the warm half as high priority, showing
        # lexicographic composition reaches the same ordering
        priorities = ([0] + [0] * 8 + [1] * 6 if policy.startswith("priority")
                      else None)
        m, acc = run_sim_experiment(
            "sart", 4, num_requests=len(prompts), workload=w, engine_cfg=ec,
            window=100, seed=seed, arrival_times=times, prompts=prompts,
            admission_policy=policy, priorities=priorities)
        recs = m["requests"]
        warm_hit = (sum(r["cached_tokens"] for r in recs)
                    / max(1, sum(r["prompt_tokens"] for r in recs)))
        rows.append({
            "mix": "shared_header", "policy": policy, "accuracy": acc,
            "warm_hit": warm_hit, "attainment": None,
            "ttfb50": percentile_latency(m, 50, "ttfb"),
            "p50": percentile_latency(m, 50),
        })
    # --- slo-aware admission: edf vs fifo on mixed deadlines ------------
    times, deadlines = mixed_deadline_workload()
    w = SimWorkload(mean_len=40, sigma_len=0.5, overthink_p=0.1,
                    correct_p=0.55, prompt_len=512)
    ec = SimEngineConfig(max_slots=64, num_pages=500000, prefill_chunk=64,
                         step_token_budget=64)
    for policy in ("fifo", "edf"):
        m, acc = run_sim_experiment(
            "sart", 4, num_requests=len(times), workload=w, engine_cfg=ec,
            window=100, seed=seed, arrival_times=times,
            admission_policy=policy, deadlines=deadlines)
        rows.append({
            "mix": "mixed_deadline", "policy": policy, "accuracy": acc,
            "warm_hit": None, "attainment": m["slo"]["attainment"],
            "misses": m["slo"]["deadline_missed"],
            "ttfb50": percentile_latency(m, 50, "ttfb"),
            "p50": percentile_latency(m, 50),
        })
    return rows


def run_chaos(quick: bool = False, seed: int = 0):
    """Chaos benchmark (docs/robustness.md): goodput, survivor completion
    rate and post-crash recovery time versus injected fault rate, on a
    fixed burst workload. Each row's ``FaultPlan`` injects step
    exceptions, OutOfPages storms and slow steps at ``rate`` (plus one
    hard mid-run crash/restart for nonzero rates) and includes one
    poisoned request that must end quarantined, never dropped. The
    rate-0 row runs with NO injector — ``run_sim_experiment`` leaves the
    engine unwrapped — so it is bit-exact with pre-chaos behavior
    (pinned by tests/test_faults.py)."""
    from repro.serving.faults import FaultPlan

    w = SimWorkload(mean_len=100 if quick else 200, sigma_len=0.5,
                    overthink_p=0.1, correct_p=0.55, prompt_len=256,
                    prompt_tail=32)
    nreq = 10 if quick else 20
    times = poisson_burst_arrivals(nreq, burst_gap=30, burst_mean=4,
                                   seed=seed + 7)
    poison = tk.STEP  # never in a normal prompt; planted in one below
    prompts = []
    for i in range(nreq):
        prompt = [tk.BOS] + [tk.digit(0)] * 222 + [tk.digit(i % 10)] * 32 \
            + [tk.EQUALS]
        prompts.append(prompt)
    # one poisoned request (admission always rejects it under a plan with
    # poison_token set): quarantine accounting must absorb it
    prompts[nreq // 2] = list(prompts[nreq // 2])
    prompts[nreq // 2][1] = poison
    rows = []
    for rate in (0.0, 0.05, 0.1, 0.2):
        plan = None
        if rate > 0:
            plan = FaultPlan(seed=seed + 1, step_rate=rate,
                             oop_rate=rate / 2, slow_rate=rate,
                             crash_at=(150,), poison_token=poison)
        ec = SimEngineConfig(max_slots=64, num_pages=500000,
                             prefill_chunk=64, step_token_budget=256,
                             prefix_cache=True)
        m, acc = run_sim_experiment(
            "sart", 4, num_requests=nreq, workload=w, engine_cfg=ec,
            window=100, seed=seed, arrival_times=times, prompts=prompts,
            fault_plan=plan)
        f = m["faults"]
        quarantined = f["quarantined_requests"]
        survivors = nreq - quarantined
        completed = m["completed_requests"]
        # recovery time: first finish after the last engine restart
        finishes = [r["finish"] for r in m["requests"]
                    if r["finish"] is not None]
        post = [t - f["last_restart_clock"] for t in finishes
                if t >= f["last_restart_clock"] >= 0]
        rows.append({
            "fault_rate": rate,
            "goodput": completed / max(1, m["clock"]),
            "survivor_completion": completed / max(1, survivors),
            "quarantined": quarantined,
            "retries": f["retries"],
            "restarts": f["engine_restarts"],
            "recovered": f["recovered"],
            "recovery_steps": min(post) if post else None,
            "accuracy": acc,
            "clock": m["clock"],
        })
    return rows


def run(quick: bool = False, seed: int = 0):
    w = SimWorkload(mean_len=250 if quick else 2000, sigma_len=0.6,
                    overthink_p=0.12, correct_p=0.55)
    ec = SimEngineConfig(max_slots=32, num_pages=500000)
    nreq = 12 if quick else 48
    rows = []
    # arrival gaps model the paper's 1 vs 4 req/s
    for rate_name, gap in [("slow", 120 if not quick else 30),
                           ("fast", 30 if not quick else 8)]:
        for policy in ["vanilla", "sc", "rebase", "sart"]:
            for n in ([4] if policy == "vanilla" else [2, 4, 8]):
                if policy == "vanilla" and n != 4:
                    continue
                m, acc = run_sim_experiment(
                    policy, 1 if policy == "vanilla" else n,
                    num_requests=nreq, arrival_gap=gap, workload=w,
                    engine_cfg=ec, window=100 if quick else 400, seed=seed)
                rows.append({
                    "rate": rate_name, "policy": policy,
                    "n": 1 if policy == "vanilla" else n,
                    "accuracy": acc,
                    "p50": percentile_latency(m, 50),
                    "p97": percentile_latency(m, 97),
                    # time-to-first-branch: admission-to-seated delay, the
                    # quantity chunked prefill piggybacking attacks
                    "ttfb50": percentile_latency(m, 50, "ttfb"),
                })
    return rows


def main(quick: bool = False):
    rows = run(quick=quick)
    # headline: speedup of SART over SC at equal N (paper: up to 28.2x)
    for r in rows:
        print(f"fig5_{r['rate']}_{r['policy']}_n{r['n']},{r['p50']:.0f},"
              f"p97={r['p97']:.0f};acc={r['accuracy']:.2f};"
              f"ttfb50={r['ttfb50']:.0f}")
    by = {(r["rate"], r["policy"], r["n"]): r for r in rows}
    for rate in ("slow", "fast"):
        sc = by.get((rate, "sc", 8))
        sa = by.get((rate, "sart", 8))
        if sc and sa and sa["p50"] > 0:
            print(f"fig5_{rate}_speedup_sart_vs_sc_n8,"
                  f"{sc['p50'] / sa['p50']:.2f},"
                  f"acc_delta={sa['accuracy'] - sc['accuracy']:+.2f}")
    burst = run_burst(quick=quick)
    for r in burst:
        cache_tag = "_cached" if r["cached"] else ""
        print(f"fig5_burst_{r['lanes']}_budget{r['budget']}{cache_tag},"
              f"{r['ttfb50']:.0f},ttfb97={r['ttfb97']:.0f};"
              f"p50={r['p50']:.0f};acc={r['accuracy']:.2f};"
              f"hit_rate={r['hit_rate']:.2f}")
    # always print the acceptance rows — a 0/NaN denominator is itself a
    # signal and must not silently drop the headline metric
    by = {(r["lanes"], r["cached"]): r for r in burst}
    single, multi = by[("single", False)], by[("multi4", False)]
    speedup = (single["ttfb50"] / multi["ttfb50"] if multi["ttfb50"] > 0
               else float("inf") if single["ttfb50"] > 0 else float("nan"))
    print(f"fig5_burst_ttfb50_speedup_multi_vs_single,{speedup:.2f},"
          f"budget={multi['budget']}")
    # prefix-cache acceptance: cached vs uncached ttfb50 on the shared-
    # few-shot-header burst (single lane, where admission throughput is
    # the bottleneck the cache relieves)
    cached = by[("single", True)]
    cache_speedup = (single["ttfb50"] / cached["ttfb50"]
                     if cached["ttfb50"] > 0
                     else float("inf") if single["ttfb50"] > 0
                     else float("nan"))
    print(f"fig5_burst_ttfb50_speedup_cached_vs_uncached,"
          f"{cache_speedup:.2f},hit_rate={cached['hit_rate']:.2f}")
    # generated-prefix acceptance: warm resample (prompt + generated
    # tokens) must hit past the prompt boundary and cost fewer admission
    # chunk steps / computed tokens (the K/V-write proxy) than cold
    rs = run_resample_burst(quick=quick)
    print(f"fig5_resample_burst_warm,{rs['warm_chunk_steps']},"
          f"cold_chunk_steps={rs['cold_chunk_steps']};"
          f"tokens_computed={rs['warm_tokens']} (cold={rs['cold_tokens']});"
          f"gen_hit_rate={rs['gen_hit_rate']:.2f};"
          f"hit_rate={rs['hit_rate']:.2f}")
    # admission-policy table: cache-aware (lpm) and slo-aware (edf)
    # ordering vs the fifo default on workloads adversarial for fifo
    pol = run_policies(quick=quick)
    for r in pol:
        extra = (f"warm_hit={r['warm_hit']:.3f}" if r["warm_hit"] is not None
                 else f"attainment={r['attainment']:.2f};"
                      f"misses={r['misses']}")
        print(f"fig5_policy_{r['mix']}_{r['policy'].replace('+', '_')},"
              f"{r['ttfb50']:.0f},p50={r['p50']:.0f};"
              f"acc={r['accuracy']:.2f};{extra}")
    byp = {(r["mix"], r["policy"]): r for r in pol}
    lpm = byp[("shared_header", "lpm")]
    fifo = byp[("shared_header", "fifo")]
    print(f"fig5_policy_lpm_vs_fifo_warm_hit,"
          f"{lpm['warm_hit']:.3f},fifo={fifo['warm_hit']:.3f},"
          f"strict={lpm['warm_hit'] > fifo['warm_hit']}")
    edf = byp[("mixed_deadline", "edf")]
    fifo = byp[("mixed_deadline", "fifo")]
    print(f"fig5_policy_edf_vs_fifo_attainment,"
          f"{edf['attainment']:.2f},fifo={fifo['attainment']:.2f},"
          f"strict={edf['attainment'] > fifo['attainment']}")
    # chaos acceptance: goodput / survivor completion / recovery vs
    # injected fault rate; the rate-0 row runs uninjected (bit-exact)
    chaos = run_chaos(quick=quick)
    for r in chaos:
        rec = ("none" if r["recovery_steps"] is None
               else f"{r['recovery_steps']}")
        print(f"fig5_chaos_rate{r['fault_rate']:.2f},"
              f"{r['goodput'] * 1000:.2f},"
              f"survivor_completion={r['survivor_completion']:.2f};"
              f"quarantined={r['quarantined']};retries={r['retries']};"
              f"restarts={r['restarts']};recovered={r['recovered']};"
              f"recovery_steps={rec};acc={r['accuracy']:.2f};"
              f"clock={r['clock']}")


if __name__ == "__main__":
    main()
