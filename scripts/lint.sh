#!/usr/bin/env bash
# Run the repo's static-analysis gate locally (mirrors CI's `lint` job).
#
#   scripts/lint.sh               # reprolint + stepcheck + mypy strict set
#   scripts/lint.sh --json        # flags pass through to reprolint
#
# reprolint is stdlib-only and always runs; the mypy lane is skipped with
# a warning when mypy is not installed (it is not baked into the dev
# container — CI installs it from requirements-dev.txt); the stepcheck
# trace lane runs whenever jax imports (it is baked into the container).
# See docs/analysis.md for the rule catalogs and baseline workflows.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m tools.reprolint src tests "$@"

if python -c "import mypy" 2>/dev/null; then
  python -m mypy src/repro/kv src/repro/core/policies.py \
    src/repro/kernels/flash_prefill
else
  echo "lint.sh: mypy not installed — skipping the typing lane" \
       "(pip install -r requirements-dev.txt to enable)" >&2
fi

if python -c "import jax" 2>/dev/null; then
  python -m tools.stepcheck
else
  echo "lint.sh: jax not installed — skipping the trace lane (stepcheck)" >&2
fi
