#!/usr/bin/env bash
# Run the repo's static-analysis gate locally (mirrors CI's `lint` job).
#
#   scripts/lint.sh               # reprolint (src tests) + mypy strict set
#   scripts/lint.sh --json        # flags pass through to reprolint
#
# reprolint is stdlib-only and always runs; the mypy lane is skipped with
# a warning when mypy is not installed (it is not baked into the dev
# container — CI installs it from requirements-dev.txt).
# See docs/analysis.md for the rule catalog and the baseline workflow.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m tools.reprolint src tests "$@"

if python -c "import mypy" 2>/dev/null; then
  python -m mypy src/repro/kv src/repro/core/policies.py
else
  echo "lint.sh: mypy not installed — skipping the typing lane" \
       "(pip install -r requirements-dev.txt to enable)" >&2
fi
