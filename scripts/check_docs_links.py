#!/usr/bin/env python3
"""Check that intra-repo markdown links resolve (CI docs lane).

Scans every tracked ``*.md`` file for inline links ``[text](target)`` and
verifies, for each non-external target:

  * the referenced file exists (relative to the linking file);
  * a ``#fragment`` resolves to a heading in the target file, using
    GitHub's slugification (lowercase, strip punctuation, spaces->dashes).

External links (``http(s)://``, ``mailto:``) are ignored — this lane is
about keeping the docs/ tree internally consistent, not about the
network. Exits non-zero listing every dead link.
"""
from __future__ import annotations

import re
import subprocess
import sys
from pathlib import Path

LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: strip markdown emphasis/code, lowercase, drop
    punctuation except dashes/underscores, spaces become dashes."""
    h = re.sub(r"[`*_]", "", heading.strip())
    h = h.lower()
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def anchors_of(md_path: Path) -> set:
    text = md_path.read_text(encoding="utf-8")
    return {github_slug(m.group(1)) for m in HEADING_RE.finditer(text)}


def repo_md_files(root: Path):
    # tracked AND untracked-but-not-ignored, so a dead link in a page that
    # hasn't been `git add`ed yet still fails locally, not just in CI
    out = subprocess.run(
        ["git", "ls-files", "--cached", "--others", "--exclude-standard",
         "*.md", "**/*.md"],
        cwd=root, capture_output=True, text=True)
    files = [root / p for p in out.stdout.splitlines() if p.strip()]
    if files:
        return files
    return [p for p in root.rglob("*.md") if ".git" not in p.parts]


def check(root: Path):
    errors = []
    for md in repo_md_files(root):
        text = md.read_text(encoding="utf-8")
        for m in LINK_RE.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path_part, _, frag = target.partition("#")
            if path_part:
                dest = (md.parent / path_part).resolve()
                if not dest.exists():
                    errors.append(f"{md.relative_to(root)}: dead link "
                                  f"-> {target} (no such file)")
                    continue
            else:
                dest = md                     # same-file anchor
            if frag and dest.suffix == ".md":
                if github_slug(frag) not in anchors_of(dest):
                    errors.append(f"{md.relative_to(root)}: dead anchor "
                                  f"-> {target}")
    return errors


def main():
    root = Path(__file__).resolve().parent.parent
    errors = check(root)
    for e in errors:
        print(f"check_docs_links: {e}", file=sys.stderr)
    if errors:
        sys.exit(f"check_docs_links: {len(errors)} dead link(s)")
    print("check_docs_links: all intra-repo markdown links resolve")


if __name__ == "__main__":
    main()
