#!/usr/bin/env bash
# Run a benchmarks/ entry point with the repo's PYTHONPATH set up.
#
#   scripts/bench.sh                      # quick benchmark harness (run.py)
#   scripts/bench.sh kernel_bench         # one module
#   scripts/bench.sh run --full           # full harness
#
# See docs/benchmarks.md for what each module measures.
set -euo pipefail
cd "$(dirname "$0")/.."
mod="${1:-run}"
shift || true
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" exec python -m "benchmarks.${mod}" "$@"
