"""Regenerate the data-driven sections of EXPERIMENTS.md from
experiments/dryrun/*.json. Usage:

    PYTHONPATH=src:. python scripts/gen_experiments.py > /tmp/sections.md
"""
from __future__ import annotations

import json
import sys

sys.path.insert(0, "benchmarks")
sys.path.insert(0, ".")

from benchmarks.roofline import (SHAPE_ORDER, analyze, load_records,
                                 suggestion, table)


def dryrun_table(mesh: str) -> str:
    recs = load_records(mesh)
    recs.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])))
    out = ["| arch | shape | step | devices | args GiB/dev | temp GiB/dev | "
           "collective ops | compile s |",
           "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        m = r["memory"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} | "
            f"{r['num_devices']} | {m['argument_bytes']/2**30:.2f} | "
            f"{m['temp_bytes']/2**30:.2f} | "
            f"{r['collectives']['total_count']} | {r['compile_s']:.0f} |")
    return "\n".join(out)


def roofline_with_suggestions() -> str:
    rows = [analyze(r) for r in load_records("single")]
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])))
    out = ["| arch | shape | compute (s) | memory (s) | collective (s) | "
           "dominant | 6ND/HLO | next lever |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.2e} | "
            f"{r['memory_s']:.2e} | {r['collective_s']:.2e} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{suggestion(r)} |")
    return "\n".join(out)


if __name__ == "__main__":
    print("## §Dry-run (single-pod 16x16 = 256 chips)\n")
    print(dryrun_table("single"))
    print("\n## §Dry-run (multi-pod 2x16x16 = 512 chips)\n")
    print(dryrun_table("multi"))
    print("\n## §Roofline (single-pod baselines)\n")
    print(roofline_with_suggestions())
