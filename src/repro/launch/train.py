"""Training launcher.

Two modes:
  * CPU end-to-end (default): train the tiny reasoner LM (+ PRM head) on the
    synthetic CoT task — the model the live serving experiments use.
      PYTHONPATH=src python -m repro.launch.train --steps 400 \
          --out checkpoints/reasoner
  * Smoke an assigned architecture (reduced variant, one step on CPU):
      PYTHONPATH=src python -m repro.launch.train --arch dbrx-132b --smoke

The production-mesh path for the full configs is exercised via
``repro.launch.dryrun`` (compile-only on this CPU container).
"""
from __future__ import annotations

import argparse
import json
import os


def train_reasoner(steps: int, prm_steps: int, out_dir: str, d_model: int,
                   num_layers: int, seed: int):
    import jax

    from ..data import DataConfig, padded_batches, prm_batches
    from ..data import tokenizer as tk
    from ..models import Model, ModelConfig
    from ..training import (AdamWConfig, save_checkpoint, train_lm,
                            train_prm_head)

    cfg = ModelConfig(
        name="tiny-reasoner", arch_type="dense", num_layers=num_layers,
        d_model=d_model, vocab_size=tk.VOCAB_SIZE,
        num_heads=max(d_model // 32, 2), num_kv_heads=max(d_model // 64, 1),
        d_ff=d_model * 4, max_seq_len=512)
    model = Model(cfg)
    data_cfg = DataConfig(batch_size=32, seq_len=160, seed=seed)

    print(f"[train] {cfg.name}: L={cfg.num_layers} d={cfg.d_model} "
          f"({cfg.param_count()/1e6:.2f}M params), {steps} steps")
    params, hist = train_lm(
        model, padded_batches(data_cfg), steps,
        AdamWConfig(lr=1e-3, warmup_steps=50, total_steps=steps),
        seed=seed, logger=lambda r: print(f"  step {r['step']:4d} "
                                          f"loss {r['loss']:.4f}"))

    print(f"[train] PRM head: {prm_steps} steps")
    head, phist = train_prm_head(
        model, params, prm_batches(data_cfg), prm_steps, seed=seed,
        logger=lambda r: print(f"  step {r['step']:4d} "
                               f"prm_loss {r['prm_loss']:.4f}"))

    os.makedirs(out_dir, exist_ok=True)
    save_checkpoint(os.path.join(out_dir, "lm.npz"), params)
    save_checkpoint(os.path.join(out_dir, "prm.npz"), head)
    with open(os.path.join(out_dir, "config.json"), "w") as f:
        json.dump({"d_model": cfg.d_model, "num_layers": cfg.num_layers,
                   "num_heads": cfg.num_heads,
                   "num_kv_heads": cfg.num_kv_heads, "d_ff": cfg.d_ff,
                   "vocab_size": cfg.vocab_size,
                   "history": hist, "prm_history": phist}, f)
    print(f"[train] saved to {out_dir}")
    return params, head


def smoke_arch(arch: str, seed: int = 0):
    """One forward + one train step of the reduced family variant on CPU."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from ..configs import smoke
    from ..models import Model
    from ..training import AdamWConfig, init_opt_state, make_train_step

    cfg = smoke(arch)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(seed))
    b, s = 2, 64
    rng = np.random.default_rng(seed)
    batch = {
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
        "mask": jnp.ones((b, s), jnp.float32),
    }
    if cfg.multimodal:
        batch["embeds"] = jnp.asarray(rng.normal(size=(b, s, cfg.d_model)),
                                      jnp.float32)
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    step = jax.jit(make_train_step(model, AdamWConfig(total_steps=10)))
    opt = init_opt_state(params)
    params, opt, metrics = step(params, opt, batch)
    loss = float(metrics["loss"])
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    print(f"[smoke] {arch}: train step ok, loss={loss:.4f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="assigned arch to smoke")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--prm-steps", type=int, default=200)
    ap.add_argument("--out", default="checkpoints/reasoner")
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.arch:
        smoke_arch(args.arch, args.seed)
    else:
        train_reasoner(args.steps, args.prm_steps, args.out, args.d_model,
                       args.layers, args.seed)


if __name__ == "__main__":
    main()
