"""Serving launcher: SART (or a baseline policy) on the live engine.

    PYTHONPATH=src python -m repro.launch.serve --ckpt checkpoints/reasoner \
        --policy sart --n 8 --requests 16 --rate 0.2

Runs the trained tiny reasoner behind the Algorithm-1 scheduler with the
requested policy, reports accuracy and step-latency percentiles. With no
checkpoint, falls back to an untrained model (scheduling behaviour only).
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Optional, Tuple


# untrained-fallback trunks per family (scheduling behaviour only): lets the
# CLI drive the ssm/hybrid serving paths — masked-dt chunked admission — end
# to end without a checkpoint
_FALLBACK_FAMILIES = {
    "dense": dict(arch_type="dense", d_ff=512),
    "ssm": dict(arch_type="ssm", d_ff=0, ssm_state=16, ssm_head_dim=32,
                ssm_chunk=16),
    "hybrid": dict(arch_type="hybrid", d_ff=512, ssm_state=16,
                   ssm_head_dim=32, ssm_chunk=16),
}


def load_reasoner(ckpt_dir: Optional[str], arch: str = "dense"):
    """Returns (model, params, prm_head_params_or_None)."""
    import jax

    from ..data import tokenizer as tk
    from ..models import Model, ModelConfig
    from ..training import load_checkpoint

    has_ckpt = ckpt_dir and os.path.exists(
        os.path.join(ckpt_dir, "config.json"))
    if arch != "dense" and has_ckpt:
        import sys
        print(f"warning: checkpoint {ckpt_dir} is dense-only; "
              f"--arch {arch} serves the untrained fallback trunk instead",
              file=sys.stderr)
    if arch == "dense" and has_ckpt:
        with open(os.path.join(ckpt_dir, "config.json")) as f:
            c = json.load(f)
        cfg = ModelConfig(
            name="tiny-reasoner", arch_type="dense",
            num_layers=c["num_layers"], d_model=c["d_model"],
            vocab_size=c["vocab_size"], num_heads=c["num_heads"],
            num_kv_heads=c["num_kv_heads"], d_ff=c["d_ff"], max_seq_len=512)
        model = Model(cfg)
        like = jax.eval_shape(
            lambda: model.init_params(jax.random.PRNGKey(0)))
        params = load_checkpoint(os.path.join(ckpt_dir, "lm.npz"))
        prm = None
        prm_path = os.path.join(ckpt_dir, "prm.npz")
        if os.path.exists(prm_path):
            prm = load_checkpoint(prm_path)
        return model, params, prm
    cfg = ModelConfig(name=f"untrained-{arch}", num_layers=2,
                      d_model=128, vocab_size=tk.VOCAB_SIZE, num_heads=4,
                      num_kv_heads=2, max_seq_len=512,
                      **_FALLBACK_FAMILIES[arch])
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return model, params, None


def serve(policy: str, n: int, num_requests: int, rate_gap: int,
          ckpt: Optional[str], prm_kind: str, window: int, max_tokens: int,
          max_slots: int, seed: int, temperature: float,
          arch: str = "dense", mixed_step_kernel: str = "fused",
          step_token_budget: int = 0, prefix_cache: bool = False,
          admission_policy: str = "fifo",
          deadline: Optional[int] = None,
          fault_plan: Optional[str] = None) -> dict:
    import numpy as np

    from ..core import OraclePRM, RewardHeadPRM, Scheduler, SchedulerConfig
    from ..core.scheduler import percentile_latency
    from ..data import tasks
    from ..data import tokenizer as tk
    from ..serving import (Engine, EngineConfig, FaultInjector, FaultPlan,
                           SamplingParams)

    model, params, prm_head = load_reasoner(ckpt, arch)
    engine = Engine(model, params, EngineConfig(
        page_size=16, num_pages=4096, max_slots=max_slots,
        max_pages_per_branch=32, eos_id=tk.EOS,
        sampling=SamplingParams(temperature=temperature, top_p=0.95),
        seed=seed, mixed_step_kernel=mixed_step_kernel,
        step_token_budget=step_token_budget, prefix_cache=prefix_cache),
        prm_params=prm_head)
    if prm_kind == "head" and prm_head is not None:
        prm = RewardHeadPRM(engine)
    else:
        prm = OraclePRM(tasks.oracle_grader, noise=0.05, seed=seed + 1)

    driven = engine
    if fault_plan:
        # seeded chaos harness: the scheduler drives the injector through
        # the identical duck-typed interface (docs/robustness.md)
        driven = FaultInjector(engine, FaultPlan.parse(fault_plan))
    sch = Scheduler(driven, prm,
                    SchedulerConfig(policy=policy, n=n, window=window,
                                    max_tokens=max_tokens,
                                    admission_policy=admission_policy),
                    answer_fn=tasks.extract_answer)
    rng = np.random.default_rng(seed + 2)
    problems = []
    for i in range(num_requests):
        prob = tasks.gen_problem(rng)
        problems.append(prob)
        arrival = i * rate_gap
        sch.submit(prob.prompt_tokens(), payload=prob, arrival=arrival,
                   deadline=(arrival + deadline
                             if deadline is not None else None))
    metrics = sch.run(max_steps=2_000_000)
    correct = sum(
        1 for r, prob in zip(metrics["requests"], problems)
        if tasks.is_correct(prob, r["answer"]))
    acc = correct / max(num_requests, 1)
    out = {
        "policy": policy, "n": n, "accuracy": acc,
        "p50": percentile_latency(metrics, 50),
        "p90": percentile_latency(metrics, 90),
        "p97": percentile_latency(metrics, 97),
        "p99": percentile_latency(metrics, 99),
        "queue_p50": percentile_latency(metrics, 50, "queue"),
        "decode_steps": metrics["decode_steps"],
        "clock": metrics["clock"],
        "ttfb50": percentile_latency(metrics, 50, "ttfb"),
        # O(buckets x lane-configs) for every family (masked-dt chunk lane
        # + token-budget lane packing)
        "prefill_compile_count": engine.prefill_compile_count,
        "mixed_step_kernel": mixed_step_kernel,
        "step_token_budget": step_token_budget,
        "chunk_lane_capacity": engine.admission_capacity,
        # avg chunk lanes per mixed step: > 1 means the token budget packed
        # concurrent prefills onto single decode ticks
        "chunk_lanes_per_mixed_step": (
            engine.prefill_chunk_steps / engine.mixed_steps_executed
            if engine.mixed_steps_executed else 0.0),
        # radix prefix-cache counters (None with --prefix-cache off):
        # hit_rate > 0 under shared-header workloads means warm admission
        # skipped those tokens' chunk compute and K/V writes entirely
        "prefix_cache": engine.prefix_cache_stats(),
        # admission ordering + SLO attainment (deadline_met fraction among
        # requests carrying a --deadline; None without deadlines)
        "admission_policy": metrics["admission_policy"],
        "slo": metrics["slo"],
        "completed_requests": metrics["completed_requests"],
        "unfinished_requests": metrics["unfinished_requests"],
        # failure-domain counters (quarantine/retry/restart/recovered) +
        # the injector's tallies when --fault-plan drives chaos
        "faults": metrics["faults"],
    }
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="sart",
                    choices=["vanilla", "sc", "sart", "sart_noprune",
                             "rebase"])
    ap.add_argument("--n", type=int, default=8)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate-gap", type=int, default=8,
                    help="decode steps between arrivals")
    ap.add_argument("--ckpt", default="checkpoints/reasoner")
    ap.add_argument("--arch", default="dense",
                    choices=sorted(_FALLBACK_FAMILIES),
                    help="untrained-fallback trunk family (ssm/hybrid "
                         "exercise the masked-dt chunked admission path)")
    ap.add_argument("--mixed-step-kernel", default="fused",
                    choices=["fused", "decode"],
                    help="chunk-row attention path of the mixed step: one "
                         "fused paged flash-prefill pass vs the per-token "
                         "flash-decode fallback")
    ap.add_argument("--step-token-budget", type=int, default=0,
                    help="max chunk-row tokens per mixed step, drawn from "
                         "multiple in-flight prefills (token-budget lane "
                         "scheduling); 0 = legacy one-FIFO-chunk-per-step")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix page-hash prompt prefix cache: admission "
                         "reuses cached page-aligned prefixes (shared "
                         "headers) instead of recomputing them")
    ap.add_argument("--admission-policy", default="fifo",
                    help="admission ordering over the arrived set: fifo "
                         "(legacy, bit-exact), lpm (longest cached prefix "
                         "first; pair with --prefix-cache), edf (earliest "
                         "--deadline first), priority, or compositions "
                         "like priority+lpm")
    ap.add_argument("--deadline", type=int, default=None,
                    help="per-request SLO: finish within this many decode "
                         "steps of arrival (drives edf ordering and the "
                         "slo attainment metrics)")
    ap.add_argument("--fault-plan", default=None,
                    help="seeded chaos injection, e.g. "
                         "'seed=3,step_rate=0.1,oop_rate=0.05,crash_at=50"
                         "+120,poison_token=5' (see repro.serving.FaultPlan"
                         ".parse); the run reports quarantine/retry/restart"
                         "/recovered counters under 'faults'")
    ap.add_argument("--prm", default="oracle", choices=["oracle", "head"])
    ap.add_argument("--window", type=int, default=8)
    ap.add_argument("--max-tokens", type=int, default=96)
    ap.add_argument("--slots", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.9)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    out = serve(args.policy, args.n, args.requests, args.rate_gap,
                args.ckpt, args.prm, args.window, args.max_tokens,
                args.slots, args.seed, args.temperature, args.arch,
                args.mixed_step_kernel, args.step_token_budget,
                args.prefix_cache, args.admission_policy, args.deadline,
                args.fault_plan)
    print(json.dumps(out, indent=2))


if __name__ == "__main__":
    main()
