# The dry-run (and ONLY the dry-run) needs 512 placeholder devices so
# jax.make_mesh can build the production meshes. Must be set before ANY
# other import — jax locks the device count on first init.
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh) combo.

Proves the distribution config is coherent without real hardware: sharding
mismatches, compile-time OOM and unsupported collectives all surface here as
hard failures. Per combo we record:
  * memory_analysis()  — per-device argument/output/temp bytes (fits check)
  * cost_analysis()    — per-device HLO FLOPs and bytes accessed
  * collective bytes   — parsed from the compiled HLO, by collective kind
into ``experiments/dryrun/<arch>__<shape>__<mesh>.json``, which §Roofline
(benchmarks/roofline.py) consumes.

Usage:
  python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh single|multi|both] [--subprocess]
"""
import argparse
import json
import re
import subprocess
import sys
import time
import traceback

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    """Sum bytes over every `dtype[d0,d1,...]` in an HLO shape string."""
    total = 0
    for m in re.finditer(r"(\w+)\[([0-9,]*)\]", shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Per-kind byte totals of collective ops in the compiled HLO (per
    device: SPMD module shapes are already per-shard)."""
    out = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    pat = re.compile(
        r"=\s+((?:\([^)]*\))|(?:\S+))\s+(" + "|".join(_COLLECTIVES) +
        r")(?:-start)?\(")
    for line in hlo_text.splitlines():
        m = pat.search(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        out[kind]["count"] += 1
        out[kind]["bytes"] += _shape_bytes(shape_str)
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    out["total_count"] = sum(v["count"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


def _measure(compiled) -> dict:
    ca = compiled.cost_analysis() or {}
    colls = parse_collectives(compiled.as_text())
    out = {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
    }
    for kind in _COLLECTIVES:
        out[f"coll_{kind}"] = float(colls[kind]["bytes"])
    out["coll_total"] = float(colls["total_bytes"])
    return out


def run_one(arch: str, shape_name: str, mesh_kind: str,
            save_hlo: bool = False, param_mode: str = "2d",
            tag: str = "", moe_dp: int = 0) -> dict:
    import jax
    import jax.numpy as jnp

    from ..configs import get_config
    from .mesh import make_production_mesh
    from .shapes import SHAPES, adapt_config
    from .steps import build_step

    t0 = time.time()
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    jitted, example_args = build_step(cfg, shape, mesh,
                                      param_mode=param_mode, moe_dp=moe_dp)

    lowered = jitted.lower(*example_args)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    colls = parse_collectives(hlo)

    # --- roofline metrics: XLA counts while-loop bodies once, so compile
    # fully-unrolled L=1 and L=2 analysis variants and extrapolate the
    # per-layer delta to the real depth.
    extrap = {}
    try:
        m = {}
        for l in (1, 2):
            jit_l, args_l = build_step(cfg.replace(num_layers=l), shape,
                                       mesh, analysis=True,
                                       param_mode=param_mode, moe_dp=moe_dp)
            m[l] = _measure(jit_l.lower(*args_l).compile())
        L = cfg.num_layers
        for key in m[1]:
            body = m[2][key] - m[1][key]
            extrap[key] = m[1][key] + (L - 1) * body
        extrap["per_layer_flops"] = m[2]["flops"] - m[1]["flops"]
        extrap["ok"] = True
    except Exception as e:  # keep the lowering proof even if analysis fails
        extrap = {"ok": False, "error": f"{type(e).__name__}: {e}"}

    acfg = adapt_config(cfg, shape)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "param_mode": param_mode,
        "moe_dp": moe_dp,
        "tag": tag,
        "mesh_shape": list(mesh.devices.shape),
        "num_devices": int(mesh.devices.size),
        "kind": shape.kind,
        "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
        "params_total": acfg.param_count(),
        "params_active": acfg.active_param_count(),
        "sliding_window_adapted": bool(
            acfg.sliding_window and not cfg.sliding_window),
        "flops_per_device": float(ca.get("flops", 0.0)),
        "bytes_per_device": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
        "extrapolated": extrap,   # loop-corrected per-device roofline terms
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_bytes": ma.peak_memory_in_bytes,
        },
        "collectives": colls,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
    }
    os.makedirs(OUT_DIR, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    path = os.path.join(OUT_DIR,
                        f"{arch}__{shape_name}__{mesh_kind}{suffix}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    if save_hlo:
        with open(path.replace(".json", ".hlo.txt"), "w") as f:
            f.write(hlo)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=[None, "train_4k",
                    "prefill_32k", "decode_32k", "long_500k"])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true",
                    help="every assigned arch x shape")
    ap.add_argument("--subprocess", action="store_true",
                    help="isolate each combo in a fresh process")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--param-mode", default="2d", choices=["2d", "tp"])
    ap.add_argument("--moe-dp", type=int, default=0)
    ap.add_argument("--tag", default="",
                    help="suffix for the output json (perf variants)")
    args = ap.parse_args()

    from ..configs import ASSIGNED
    archs = ASSIGNED if (args.all or args.arch is None) else [args.arch]
    shapes = (["train_4k", "prefill_32k", "decode_32k", "long_500k"]
              if (args.all or args.shape is None) else [args.shape])
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mesh in meshes:
                tag = f"{arch} x {shape} x {mesh}"
                out = os.path.join(OUT_DIR, f"{arch}__{shape}__{mesh}.json")
                if args.skip_existing and os.path.exists(out):
                    print(f"[skip] {tag}", flush=True)
                    continue
                if args.subprocess:
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape, "--mesh", mesh]
                    if args.save_hlo:
                        cmd.append("--save-hlo")
                    r = subprocess.run(cmd, capture_output=True, text=True)
                    ok = r.returncode == 0
                    tail = (r.stdout + r.stderr).strip().splitlines()
                    print(f"[{'ok' if ok else 'FAIL'}] {tag}"
                          + ("" if ok else f"  {tail[-1] if tail else ''}"),
                          flush=True)
                    if not ok:
                        failures.append(tag)
                else:
                    try:
                        rec = run_one(arch, shape, mesh,
                                      save_hlo=args.save_hlo,
                                      param_mode=args.param_mode,
                                      tag=args.tag, moe_dp=args.moe_dp)
                        print(f"[ok] {tag}: "
                              f"flops/dev={rec['flops_per_device']:.3e} "
                              f"coll={rec['collectives']['total_bytes']:.3e}B "
                              f"peak={rec['memory']['peak_bytes']/2**30:.2f}GiB "
                              f"compile={rec['compile_s']}s", flush=True)
                    except Exception:
                        traceback.print_exc()
                        failures.append(tag)
                        print(f"[FAIL] {tag}", flush=True)
    if failures:
        print(f"{len(failures)} FAILURES: {failures}", flush=True)
        sys.exit(1)
    print("dry-run: all combinations lowered and compiled", flush=True)


if __name__ == "__main__":
    main()
