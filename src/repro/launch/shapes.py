"""Assigned input shapes and ShapeDtypeStruct input specs per (arch, shape).

Decode shapes lower ``serve_step`` (ONE new token against a seq_len cache);
``train_4k`` lowers ``train_step``; ``prefill_32k`` lowers ``prefill_step``.

``long_500k`` requires sub-quadratic attention state: ssm/hybrid run
natively (O(1) SSM state; hymba's attention is already sliding-window); for
attention archs without a window the config is adapted to sliding-window
attention (window 8192, ring-buffer KV) — the carve-out documented in
DESIGN.md §Arch-applicability.

VLM/audio backbones: ``train``/``prefill`` consume precomputed frontend
embeddings (``embeds``) per the assignment's frontend-stub carve-out; decode
consumes generated token ids.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from ..models import Model, ModelConfig

LONG_WINDOW = 8192


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def adapt_config(cfg: ModelConfig, shape: ShapeSpec) -> ModelConfig:
    """Shape-specific config adaptation (long-context window carve-out)."""
    cfg = cfg.replace(max_seq_len=max(cfg.max_seq_len, shape.seq_len))
    if shape.name == "long_500k" and cfg.uses_attention \
            and not cfg.sliding_window:
        cfg = cfg.replace(sliding_window=LONG_WINDOW)
    return cfg


def input_specs(cfg: ModelConfig, shape: ShapeSpec, dtype=jnp.bfloat16
                ) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input — weak-type-correct,
    shardable, no device allocation."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        specs = {"labels": sds((b, s), i32), "mask": sds((b, s), jnp.float32)}
        if cfg.multimodal:
            specs["embeds"] = sds((b, s, cfg.d_model), dtype)
            specs["tokens"] = None
        else:
            specs["tokens"] = sds((b, s), i32)
            specs["embeds"] = None
        return specs
    if shape.kind == "prefill":
        if cfg.multimodal:
            return {"embeds": sds((b, s, cfg.d_model), dtype), "tokens": None}
        return {"tokens": sds((b, s), i32), "embeds": None}
    # decode: one token against a seq_len-deep cache
    model = Model(cfg, dtype=dtype)
    cache_shape = jax.eval_shape(lambda: model.init_cache(b, s))
    return {
        "tokens": sds((b,), i32),
        "positions": sds((b,), i32),
        "cache": cache_shape,
    }
