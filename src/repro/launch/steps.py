"""Jittable step functions (train / prefill / serve) + their shardings.

These are the functions the multi-pod dry-run lowers and the launchers run.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..distributed.logical import (activation_rules, analysis_mode,
                                   standard_rules)
from ..distributed.sharding import (TP, batch_axes, cache_pspecs, drop_fsdp,
                                    opt_pspecs, param_pspecs,
                                    sanitize_pspecs, shardings)
from ..models import Model, ModelConfig, cross_entropy_loss
from ..training.optimizer import AdamWConfig, adamw_update
from .shapes import ShapeSpec, adapt_config, input_specs


def make_train_step_fn(model: Model, opt_cfg: AdamWConfig):
    def train_step(params, opt_state, batch):
        def loss_fn(p):
            logits, aux = model.forward(
                p, tokens=batch.get("tokens"), embeds=batch.get("embeds"))
            loss = cross_entropy_loss(logits, batch["labels"], batch["mask"])
            return loss + aux, {"loss": loss, "aux_loss": aux}

        (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt_state, gnorm = adamw_update(opt_cfg, params, grads,
                                                opt_state)
        metrics["grad_norm"] = gnorm
        return params, opt_state, metrics

    return train_step


def make_prefill_step_fn(model: Model, max_len: int):
    def prefill_step(params, batch):
        logits, cache = model.prefill(
            params, tokens=batch.get("tokens"), embeds=batch.get("embeds"),
            max_len=max_len)
        return logits, cache

    return prefill_step


def make_serve_step_fn(model: Model):
    def serve_step(params, cache, tokens, positions):
        logits, cache, hidden = model.decode_step(params, tokens, cache,
                                                  positions)
        return logits, cache, hidden

    return serve_step


# --------------------------------------------------------------------- dryrun


def build_step(arch_cfg: ModelConfig, shape: ShapeSpec, mesh,
               dtype=jnp.bfloat16, analysis: bool = False,
               param_mode: str = "2d", moe_dp: int = 0):
    """Returns (jitted_fn, example_args) ready to .lower(*example_args).

    ``example_args`` are ShapeDtypeStructs — nothing is allocated.
    ``analysis=True`` fully unrolls every scan so cost_analysis counts all
    iterations (XLA counts a while body once); used with small num_layers
    variants by the dry-run's roofline extrapolation.
    ``param_mode``: "2d" (baseline, FSDP+TP) or "tp" (decode perf lever:
    weights replicated over 'data', sharded only on 'model').
    """
    multi_pod = "pod" in mesh.axis_names
    dp_axes_t = batch_axes(multi_pod)
    replicate_batch = shape.kind == "decode" and shape.global_batch == 1
    rules = standard_rules(dp_axes_t, replicate_batch=replicate_batch)
    if moe_dp:
        rules["_moe_dp"] = moe_dp   # shard-local MoE dispatch (perf lever)

    def with_rules(fn):
        @functools.wraps(fn)
        def wrapped(*args, **kw):
            with activation_rules(mesh, rules):
                if analysis:
                    with analysis_mode():
                        return fn(*args, **kw)
                return fn(*args, **kw)
        return wrapped

    cfg = adapt_config(arch_cfg, shape)
    model = Model(cfg, dtype=dtype)
    pshape = jax.eval_shape(
        lambda: model.init_params(jax.random.PRNGKey(0)))
    pshape = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, dtype
                                       if l.dtype == jnp.float32 else l.dtype),
        pshape)
    pspecs = sanitize_pspecs(param_pspecs(pshape), pshape, mesh)
    if param_mode == "tp":
        assert shape.kind == "decode", "pure-TP layout is a decode lever"
        pspecs = drop_fsdp(pspecs)
    tp_size = dict(zip(mesh.axis_names, mesh.devices.shape))[TP]
    sh = lambda tree: shardings(mesh, tree)
    specs = input_specs(cfg, shape, dtype=dtype)

    if shape.kind == "train":
        opt_cfg = AdamWConfig()
        fn = with_rules(make_train_step_fn(model, opt_cfg))
        opt_shape = {
            "mu": jax.tree.map(
                lambda l: jax.ShapeDtypeStruct(l.shape, jnp.float32), pshape),
            "nu": jax.tree.map(
                lambda l: jax.ShapeDtypeStruct(l.shape, jnp.float32), pshape),
            "step": jax.ShapeDtypeStruct((), jnp.int32),
        }
        ospecs = {"mu": pspecs, "nu": pspecs, "step": P()}
        batch_shape = {k: v for k, v in specs.items() if v is not None}
        bspecs = {}
        for k, v in batch_shape.items():
            bspecs[k] = P(*( (batch_axes(multi_pod),) +
                             (None,) * (len(v.shape) - 1) ))
        bspecs = sanitize_pspecs(bspecs, batch_shape, mesh)
        jitted = jax.jit(
            fn,
            in_shardings=(sh(pspecs), sh(ospecs), sh(bspecs)),
            out_shardings=(sh(pspecs), sh(ospecs),
                           sh({"loss": P(), "aux_loss": P(),
                               "grad_norm": P()})),
            donate_argnums=(0, 1),
        )
        return jitted, (pshape, opt_shape, batch_shape)

    if shape.kind == "prefill":
        fn = with_rules(make_prefill_step_fn(model, shape.seq_len))
        batch_shape = {k: v for k, v in specs.items() if v is not None}
        bspecs = {k: P(*( (batch_axes(multi_pod),) +
                          (None,) * (len(v.shape) - 1) ))
                  for k, v in batch_shape.items()}
        bspecs = sanitize_pspecs(bspecs, batch_shape, mesh)
        cache_shape = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len))
        cspecs = sanitize_pspecs(
            cache_pspecs(cache_shape, batch_axes(multi_pod),
                         tp_size=tp_size), cache_shape, mesh)
        logit_shape = jax.ShapeDtypeStruct(
            (shape.global_batch, cfg.vocab_size), dtype)
        lspec = sanitize_pspecs(P(batch_axes(multi_pod), TP), logit_shape,
                                mesh)
        jitted = jax.jit(
            fn,
            in_shardings=(sh(pspecs), sh(bspecs)),
            out_shardings=(sh(lspec), sh(cspecs)),
        )
        return jitted, (pshape, batch_shape)

    # decode
    fn = with_rules(make_serve_step_fn(model))
    shard_seq = shape.global_batch == 1          # long_500k
    dp_axes = batch_axes(multi_pod)
    cache_shape = specs["cache"]
    cspecs = sanitize_pspecs(
        cache_pspecs(cache_shape, dp_axes, shard_seq=shard_seq,
                     tp_size=tp_size), cache_shape, mesh)
    tok_spec = P(None) if shard_seq else P(dp_axes)
    logit_shape = jax.ShapeDtypeStruct(
        (shape.global_batch, cfg.vocab_size), dtype)
    lspec = sanitize_pspecs(P(None if shard_seq else dp_axes, TP),
                            logit_shape, mesh)
    jitted = jax.jit(
        fn,
        in_shardings=(sh(pspecs), sh(cspecs), sh(tok_spec), sh(tok_spec)),
        out_shardings=(sh(lspec),
                       sh(cspecs),
                       sh(P(None if shard_seq else dp_axes, None))),
        donate_argnums=(1,),
    )
    return jitted, (pshape, cache_shape, specs["tokens"], specs["positions"])
