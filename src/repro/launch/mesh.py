"""Production meshes for the multi-pod dry-run (TPU v5e target).

Defined as functions so importing this module never touches jax device
state (jax locks the device count on first backend init).
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5 exposes explicit axis types; 0.4.x builds the same
    # (fully "auto") mesh without the kwarg
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on pinned jax
    AxisType = None


def make_mesh_compat(axis_shapes, axis_names):
    """``jax.make_mesh`` across jax versions.

    On jax >= 0.5 every axis is pinned to ``AxisType.Auto`` (the semantics
    all our pjit code assumes); on jax 0.4.x — where ``axis_types`` does not
    exist and Auto is the only behaviour — the kwarg is simply omitted.
    """
    if AxisType is None:
        return jax.make_mesh(axis_shapes, axis_names)
    return jax.make_mesh(axis_shapes, axis_names,
                         axis_types=(AxisType.Auto,) * len(axis_names))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


# v5e hardware constants for the roofline analysis (per chip)
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW = 50e9                     # B/s per link
