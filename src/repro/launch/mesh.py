"""Production meshes for the multi-pod dry-run (TPU v5e target).

Defined as functions so importing this module never touches jax device
state (jax locks the device count on first backend init).
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


# v5e hardware constants for the roofline analysis (per chip)
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW = 50e9                     # B/s per link
