"""SART's scheduling workflow (paper Algorithm 1) + baseline policies.

Time base: one decode step of the fixed-size branch batch is one clock tick
(decoding is memory-bound, so step latency is ~independent of how full the
batch is — the quantity SART optimizes is the *number* of steps a request
spans, plus the steps it waits in queue). Prefill counts one tick. The clock
also advances while the system is idle waiting for arrivals.

Policies (all sharing the engine + continuous batching, as the paper does for
fair comparison):
  * ``vanilla``        — N=1, no early stop, no pruning.
  * ``sc``             — Self-Consistency: N branches, wait for all N,
                         majority vote.
  * ``sart``           — redundant sampling (N>M) + early stop at M
                         completions + two-phase pruning; best-of-N by reward.
  * ``sart_noprune``   — ablation (paper Fig. 6): early stop only.
  * ``rebase``         — reward-guided tree search baseline (fork strong
                         leaves, cull weak ones, ≤N live leaves).

Public contracts (documented in docs/architecture.md and
docs/scheduling.md, which deep-link here):

  * **Engine-agnostic**: the scheduler drives anything implementing the
    engine interface (``repro.serving.Engine`` live, ``SimEngine`` traced)
    through the same code path — policies compare on identical control
    flow.
  * **Admission keeps the chunk lanes fed**: ``_admit_one`` keeps up to
    ``engine.admission_capacity`` prefills in flight (1 for legacy
    single-lane engines); ``_poll_prefills`` harvests finished prefills
    every tick and, when the engine packs multiple lanes, tops the
    in-flight set back up from the arrival queue (the scheduler half of
    token-budget lane scheduling).
  * **Admission order is a pluggable policy**: each admission
    opportunity, ``_arrived`` hands the *whole* arrived set to the
    configured ``AdmissionPolicy`` (``repro.core.policies``) — ``fifo``
    (default, legacy order), ``lpm`` (longest cached prompt prefix
    first, probed non-mutatingly via ``probe_cached_tokens``), ``edf``
    (earliest ``Request.deadline``), ``priority``, and compositions —
    under a starvation bound so no request is passed over unboundedly.
  * **Eager release**: completions, prunes and early stops free engine
    slots and pages the moment they happen; ``metrics()`` is only valid
    because ``_finalize`` releases the request's prefix exactly once.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from ..kv import OutOfPagesError
from ..serving.engine import BranchHandle, Engine
from .ensemble import best_of_n, majority_vote
from .policies import make_policy, select_next
from .pruning import PruningConfig, RequestMeta, TwoPhasePruner
from .prm import PRM

POLICIES = ("vanilla", "sc", "sart", "sart_noprune", "rebase")


class EvictionStallError(RuntimeError):
    """Raised (into the engine-fault path) when ``OutOfPagesError``
    pressure cannot be relieved: force-completing every live branch freed
    zero allocator pages — the pre-fix scheduler span forever here."""


class SchedulerFaultError(RuntimeError):
    """Engine faults exhausted ``max_engine_restarts``: the failure is
    persistent, so it propagates out of ``run()`` with the last cause
    chained instead of restarting forever."""


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    policy: str = "sart"
    n: int = 8                    # branches sampled per request
    m: int = 0                    # early-stop count (0 -> N//2, paper default)
    alpha: float = 0.5            # phase-1 prune threshold
    beta: int = 0                 # phase-1 prune cap (0 -> N//2)
    window: int = 16              # T: decode steps between pruning rounds
    max_tokens: int = 256         # per-branch generation cap
    rebase_temp: float = 0.2      # softmax temperature for rebase expansion
    preempt: bool = False         # beyond-paper: preemptible scheduling —
                                  # suspend the weakest running branch to
                                  # admit a waiting request's prefill
                                  # (the paper lists this as future work)
    # Admission-ordering policy over the arrived set ("fifo", "lpm",
    # "edf", "priority", or compositions like "priority+lpm" — see
    # repro.core.policies). "fifo" is bit-exact legacy behavior.
    admission_policy: str = "fifo"
    # Pass-overs by younger requests a waiting request tolerates before
    # it preempts the policy ordering (mirrors the chunk-lane packer's
    # prefill_starvation_bound, one layer up).
    admission_starvation_bound: int = 4
    # Failure-domain isolation (docs/robustness.md). Attributable
    # admission faults retry up to retry_budget times with exponential
    # backoff (retry_backoff * 2**(retries-1) ticks) before the request
    # is quarantined; step_fault_tolerance consecutive non-attributable
    # decode faults trigger an engine restart, bounded by
    # max_engine_restarts before the fault propagates out of run().
    retry_budget: int = 3
    retry_backoff: int = 4
    step_fault_tolerance: int = 3
    max_engine_restarts: int = 8

    def resolve(self) -> "SchedulerConfig":
        """Normalized copy with policy-dependent defaults applied:
        vanilla forces n=m=1, sc/rebase keep all n branches, and m<=0
        becomes the paper's N//2 early-stop default (clamped to [1, n])."""
        n, m = self.n, self.m
        if self.policy == "vanilla":
            n, m = 1, 1
        elif self.policy in ("sc", "rebase"):
            m = n
        elif m <= 0:
            m = max(n // 2, 1)
        return dataclasses.replace(self, n=n, m=max(min(m, n), 1))


# eq=False: scheduler queues (prefilling, waiting) test membership and
# remove by identity — two requests with equal fields are still distinct
# requests (reprolint REP004)
@dataclasses.dataclass(eq=False)
class Request:
    request_id: int
    prompt: List[int]
    arrival: int
    payload: object = None        # task object (answer key, oracle grader)
    deadline: Optional[int] = None  # absolute clock the SLO wants finish by
    priority: int = 0             # tier (higher = more urgent)
    # runtime state
    passed_over: int = 0          # admissions of younger requests ahead of us
    meta: Optional[RequestMeta] = None
    prefill_state: object = None  # ChunkedPrefillState while chunks pend
    prefix_blocks: object = None
    last_logits: object = None
    ssm_state: object = None
    live: Dict[int, BranchHandle] = dataclasses.field(default_factory=dict)
    pending: int = 0              # branches awaiting a slot
    cached_tokens: int = 0        # prompt tokens served warm at admission
    completed: List = dataclasses.field(default_factory=list)
    first_service: int = -1
    first_branch: int = -1        # clock when the first branch was seated
    finish: int = -1
    final_answer: object = None
    # failure-domain state (docs/robustness.md)
    retries: int = 0              # attributable faults charged so far
    not_before: int = 0           # backoff: earliest re-admission clock
    quarantined: bool = False     # terminal: retry budget exhausted
    quarantine_reason: Optional[str] = None
    had_fault: bool = False       # saw any fault (drives `recovered`)

    @property
    def done(self) -> bool:
        """True once the scheduler stamped a finish clock (terminal)."""
        return self.finish >= 0


@dataclasses.dataclass
class Timeline:
    steps: List[int] = dataclasses.field(default_factory=list)
    live_branches: List[int] = dataclasses.field(default_factory=list)
    live_tokens: List[int] = dataclasses.field(default_factory=list)

    def record(self, step: int, branches: int, tokens: int) -> None:
        """Append one sample of the live-branch/live-token occupancy."""
        self.steps.append(step)
        self.live_branches.append(branches)
        self.live_tokens.append(tokens)


class Scheduler:
    """Algorithm 1, parameterized by policy."""

    def __init__(self, engine: Engine, prm: PRM, cfg: SchedulerConfig,
                 answer_fn: Callable):
        self.engine = engine
        self.prm = prm
        self.cfg = cfg.resolve()
        self.answer_fn = answer_fn
        self.pruner = TwoPhasePruner(PruningConfig(
            alpha=self.cfg.alpha, beta=self.cfg.beta,
            enabled=self.cfg.policy == "sart"))
        self.admission = make_policy(self.cfg.admission_policy)
        self.request_queue: deque = deque()
        self.branch_queue: deque = deque()   # requests with pending spawns
        self.prefilling: List[Request] = []  # admitted, chunks still pending
        self.suspended: deque = deque()      # preempted branches to resume
        self.requests: Dict[int, Request] = {}
        self.clock = 0
        self.timeline = Timeline()
        self._next_request_id = 0
        # failure-domain accounting (docs/robustness.md): quarantine /
        # retry / restart / recovered counters surface in metrics()
        self.fault_counters = {"step_faults": 0, "retries": 0,
                               "quarantined": 0, "requeued": 0,
                               "engine_restarts": 0, "recovered": 0,
                               "last_restart_clock": -1}
        self._fault_streak = 0    # consecutive non-attributable faults

    # ---------------------------------------------------------------- intake
    def submit(self, prompt: List[int], payload=None, arrival: int = 0,
               deadline: Optional[int] = None, priority: int = 0) -> Request:
        """Queue a request. ``deadline`` is an absolute clock tick the SLO
        wants ``finish`` by (drives ``edf`` ordering and the SLO-attainment
        metrics); ``priority`` is the tier for ``priority`` ordering."""
        req = Request(self._next_request_id, list(prompt), arrival, payload,
                      deadline=deadline, priority=priority)
        self._next_request_id += 1
        self.requests[req.request_id] = req
        self.request_queue.append(req)
        return req

    # ------------------------------------------------------------------ main
    def run(self, max_steps: int = 1_000_000) -> Dict:
        """Drive everything submitted so far to completion."""
        while self.clock < max_steps and not self._all_done():
            self._fill_batch()
            if self.engine.num_active == 0 and not self.prefilling:
                self.clock += 1            # idle: waiting for arrivals
                continue
            self._decode_window()
            self._window_bookkeeping()
        self._drain_truncated()
        return self.metrics()

    def _all_done(self) -> bool:
        """Quarantined requests are terminal too — the retry budget is
        exhausted, so waiting on them would spin forever."""
        return all(r.done or r.quarantined for r in self.requests.values())

    def _drain_truncated(self) -> None:
        """A run stopped at ``max_steps`` can leave admitted prompts with
        chunks still pending; abort their prefill states through the
        engine's normal release path so ``PageAllocator.check_invariants``
        holds after *every* run, and requeue the requests (they surface as
        unfinished in metrics, never dropped)."""
        if not self.prefilling:
            return
        for req in reversed(self.prefilling):
            if req.prefill_state is not None:
                self.engine.abort_prefill(req.prefill_state)
                req.prefill_state = None
            self.request_queue.appendleft(req)
            self.fault_counters["requeued"] += 1
        self.prefilling.clear()

    def probe_cached_tokens(self, req: Request) -> int:
        """Non-mutating prefix-cache probe for LPM ordering: how many of
        ``req``'s prompt tokens a warm admission would serve from cache
        right now. 0 for engines without a cache (LPM degrades to FIFO).
        The probe takes no page references and pollutes no hit counters —
        only actual admission does."""
        probe = getattr(self.engine, "match_cached_tokens", None)
        return probe(req.prompt) if probe is not None else 0

    def _arrived(self) -> Optional[Request]:
        """Select the next request to admit from the *whole* arrived set
        (the seed peeked only the queue head, so an arrived request parked
        behind a future-arrival head was never admitted). The configured
        admission policy orders the set; the starvation bound caps how
        often a request may be passed over (under ``fifo`` the choice is
        always the oldest arrived request — legacy order, bit-exact)."""
        arrived = [r for r in self.request_queue
                   if r.arrival <= self.clock
                   and r.not_before <= self.clock]
        if not arrived:
            return None
        chosen = select_next(self.admission, arrived, self,
                             self.cfg.admission_starvation_bound)
        self.request_queue.remove(chosen)
        return chosen

    # --------------------------------------------------------- batch filling
    def _fill_batch(self):
        """Algorithm 1 lines 3-11: branches first, then prefill requests.
        With ``preempt``, suspended branches resume with top priority."""
        while self.engine.free_slots:
            if self.suspended:
                h = self.suspended[0]
                if h.done or not self.engine.resume_branch(h):
                    self.suspended.popleft()
                    continue
                self.suspended.popleft()
            elif self.branch_queue:
                req = self.branch_queue[0]
                if req.done or req.pending <= 0:
                    self.branch_queue.popleft()
                    continue
                self._spawn_one(req)
                if req.pending <= 0:
                    self.branch_queue.popleft()
            else:
                # keep as many prefills in flight as the engine can pack
                # into one mixed step (admission_capacity = max chunk
                # lanes; 1 without a token budget) — admitting beyond that
                # would reserve prompts' pages long before any chunk runs,
                # starving live decode branches into eviction
                if not self._admit_one():
                    break
        # admission consumes no slot (chunks ride the decode step), so a
        # saturated batch doesn't block it — keep the lanes fed
        if not self.engine.free_slots:
            while self._admit_one():
                pass
        if self.cfg.preempt and not self.engine.free_slots:
            self._maybe_preempt()

    def _maybe_preempt(self):
        """Make progress for waiting work when every slot is taken.

        Admission consumes no slot (prefill chunks ride the decode step)
        and is handled by ``_fill_batch`` even when the batch is full, so
        only a waiting *branch* spawn justifies suspending the weakest
        running branch — the victim resumes as soon as a slot frees."""
        if not self.branch_queue:
            return
        victims = [h for h in self.engine.slots
                   if h is not None
                   and len(self.requests[h.request_id].live) > 1]
        if not victims:
            return
        # never-scored candidates default last_reward=0.0 and would tie
        # below every scored branch — score them first so a strong branch
        # that simply hasn't hit a scoring window isn't the victim
        for h in victims:
            if not h.scored:
                h.last_reward = self.prm.score(
                    self.requests[h.request_id], [h])[0]
                h.scored = True
        victim = min(victims, key=lambda h: h.last_reward)
        self.engine.suspend_branch(victim)
        self.suspended.append(victim)
        req = self.branch_queue[0]
        if not req.done and req.pending > 0:
            self._spawn_one(req)

    def _admit_one(self) -> bool:
        """Admit one arrived request if the engine's chunk lanes have room
        (``admission_capacity``: the max lanes one mixed step can carry —
        1 for legacy single-lane FIFO engines). Returns True if a request
        was admitted, False when at capacity, out of arrivals, or out of
        pages (the request is requeued). Any other admission exception is
        *attributable* to the request being admitted: it is routed to the
        quarantine/retry path instead of crashing ``run()`` — the seed
        popped the request from the arrived set and dropped it."""
        capacity = getattr(self.engine, "admission_capacity", 1)
        if len(self.prefilling) >= capacity:
            return False
        req = self._arrived()
        if req is None:
            return False
        try:
            self._admit(req)
        except OutOfPagesError:
            self.request_queue.appendleft(req)
            return False
        except Exception as exc:  # attributable: quarantine, don't crash
            self._quarantine_or_requeue(req, exc)
        return True

    def _quarantine_or_requeue(self, req: Request, exc: Exception) -> None:
        """Charge an attributable fault to ``req``: requeue it with
        exponential backoff while the retry budget lasts, then quarantine
        it terminally — it stays in metrics (finish=None, quarantined)
        rather than being dropped or retried forever."""
        if req in self.prefilling:
            self.prefilling.remove(req)
        if req.prefill_state is not None:
            self.engine.abort_prefill(req.prefill_state)
            req.prefill_state = None
        req.retries += 1
        req.had_fault = True
        if req.retries > self.cfg.retry_budget:
            req.quarantined = True
            req.quarantine_reason = repr(exc)
            self.fault_counters["quarantined"] += 1
        else:
            self.fault_counters["retries"] += 1
            req.not_before = (self.clock + self.cfg.retry_backoff
                              * (1 << (req.retries - 1)))
            self.request_queue.append(req)

    def _admit(self, req: Request):
        """Algorithm 1 PREFILL, now asynchronous and uniform across model
        families (attention, ssm, hybrid — ssm/hybrid chunks ride the
        masked-dt mixed step): admission allocates the prompt's pages and
        enqueues its chunks; they piggyback on decode steps instead of
        stalling the batch. With the engine's prefix cache enabled,
        ``begin_prefill`` serves the longest cached page-aligned prompt
        prefix from shared pages, so the state arrives with ``next_pos``
        already past the cached tokens and fewer chunks to drain. Only
        engines explicitly configured with ``chunked_prefill=False``
        return an already-done state and keep the seed's one-tick
        synchronous accounting."""
        req.prefill_state = self.engine.begin_prefill(req.prompt)
        if req.prefill_state.done:
            req.first_service = self.clock    # seed-exact sync accounting
            self.clock += 1               # legacy synchronous prefill tick
            self._harvest_prefill(req)
        else:
            self.prefilling.append(req)

    def _harvest_prefill(self, req: Request):
        """Prefill finished: collect its outputs, queue N branch spawns.
        Async requests get first_service stamped here — once their chunks
        have actually been served — so queueing delay keeps its meaning."""
        if req.first_service < 0:
            req.first_service = self.clock
        # prompt tokens the admission actually served from the prefix
        # cache — recorded once per request (unlike the cache's lookup
        # counters, which also see rolled-back OutOfPages retries)
        req.cached_tokens = getattr(req.prefill_state, "cached_tokens", 0)
        blocks, logits, ssm_state = self.engine.finish_prefill(
            req.prefill_state)
        req.prefill_state = None
        req.prefix_blocks = blocks
        req.last_logits = logits
        req.ssm_state = ssm_state
        if req.meta is None:
            req.meta = self.pruner.new_meta(self.cfg.n, self.cfg.m)
            req.pending = (self._rebase_initial_width()
                           if self.cfg.policy == "rebase" else self.cfg.n)
        # else: re-admission after an engine restart or snapshot restore —
        # pruner meta and completed branches survive; ``pending`` already
        # carries the branch budget the teardown preserved (in-flight
        # decode work resumes as resampling)
        self.branch_queue.append(req)

    def _poll_prefills(self) -> bool:
        """Harvest finished prefills and keep the engine's chunk lanes fed.

        With token-budget lane scheduling (``admission_capacity > 1``) this
        is the scheduler half of the lane packer: every decode tick it
        refills the in-flight prefill set from the admission queue up to
        the lane capacity, oldest-first — the engine-side
        ``pack_chunk_lanes`` then chooses which of them ride the next
        mixed step under the token budget (with its starvation bound).
        Legacy single-lane engines (capacity 1) keep the seed's admission
        points (window start + harvest refill) untouched."""
        harvested = False
        for req in [r for r in self.prefilling if r.prefill_state.done]:
            self.prefilling.remove(req)
            self._harvest_prefill(req)
            harvested = True
        if getattr(self.engine, "admission_capacity", 1) > 1:
            while self._admit_one():
                pass
        return harvested

    def _rebase_initial_width(self) -> int:
        return max(self.cfg.n // 2, 1)

    def _spawn_one(self, req: Request):
        h = self.engine.spawn_branch(
            req.request_id, req.prefix_blocks, req.last_logits,
            req.ssm_state, len(req.prompt), prompt_tokens=req.prompt)
        if h is None:
            return
        if req.first_branch < 0:
            req.first_branch = self.clock   # time-to-first-branch anchor
        req.live[h.branch_id] = h
        req.pending -= 1

    # -------------------------------------------------------------- decoding
    def _decode_window(self):
        """Up to T decode steps; completions release slots eagerly. Each
        step also advances one chunk of any pending prefill (mixed step);
        chunk-only steps keep ticking while the decode batch is empty."""
        for _ in range(self.cfg.window):
            if self.engine.num_active == 0 and not self.prefilling:
                break
            try:
                self.engine.decode_step()
            except OutOfPagesError:
                if not self._evict_longest():
                    # nothing evictable freed pages: route the stall to
                    # the engine-fault domain (bounded restarts) instead
                    # of retrying OutOfPages forever without progress
                    self._on_engine_fault(EvictionStallError(
                        "OutOfPages with no evictable progress: "
                        "force-completing every live branch freed 0 pages"))
                continue
            except Exception as exc:  # non-attributable: engine fault domain
                self._on_engine_fault(exc)
                continue
            self._fault_streak = 0
            # a faulty-but-alive engine can report slow steps (deadline
            # pressure): charge the extra ticks the step actually cost
            self.clock += 1 + getattr(self.engine, "last_step_penalty", 0)
            if self._poll_prefills():
                # seed parity: branches spawned the moment prefill finished;
                # refill mid-window instead of waiting out the window
                self._fill_batch()
            self._check_completions()
            self.timeline.record(self.clock, self.engine.num_active,
                                 self.engine.live_tokens())

    def _evict_longest(self) -> bool:
        """Memory pressure: force-complete live branches, longest first,
        until allocator pages are actually freed. Returns False when no
        victim frees anything (pages all prefix-cache-shared, or no live
        branches) — the pre-fix code force-completed one victim blindly
        and span the rest of the window retrying ``OutOfPagesError``."""
        live = sorted((h for h in self.engine.slots if h is not None),
                      key=lambda h: h.blocks.length, reverse=True)
        for victim in live:
            req = self.requests[victim.request_id]
            before = self.engine.allocator.free_pages
            self._complete_branch(req, victim, truncated=True)
            self._maybe_finalize(req)
            if self.engine.allocator.free_pages > before:
                return True
        return False

    def _on_engine_fault(self, exc: Exception) -> None:
        """Non-attributable engine failure during decode: burn the tick,
        and after ``step_fault_tolerance`` consecutive faults restart the
        engine instead of crashing ``run()`` (bounded by
        ``max_engine_restarts``)."""
        self.fault_counters["step_faults"] += 1
        self._fault_streak += 1
        self.clock += 1               # the faulted step still cost a tick
        if self._fault_streak >= self.cfg.step_fault_tolerance:
            self._restart_engine(exc)

    def _restart_engine(self, cause: Optional[Exception] = None) -> None:
        """Engine-restart path: tear down all engine-resident state
        through the normal release paths (aborted prefills, freed
        branches, released prefixes — so allocator invariants hold and
        generated pages park warm on the prefix cache), requeue every
        unfinished request, and restart the engine if it supports it.
        Request-level progress (completed branches, rewards, pruner meta)
        survives; lost in-flight decode work resumes as resampling."""
        if (self.fault_counters["engine_restarts"]
                >= self.cfg.max_engine_restarts):
            raise SchedulerFaultError(
                f"engine fault persists after "
                f"{self.cfg.max_engine_restarts} restarts") from cause
        self.fault_counters["engine_restarts"] += 1
        self.fault_counters["last_restart_clock"] = self.clock
        self._fault_streak = 0
        survivors = []
        for req in self.requests.values():
            if req.done or req.quarantined:
                continue
            if req.prefill_state is not None:
                self.engine.abort_prefill(req.prefill_state)
                req.prefill_state = None
            if req.live:
                # in-flight branches are lost with the engine; preserve
                # the branch budget so they resample after re-admission
                req.pending += len(req.live)
                for h in list(req.live.values()):
                    self.engine.free_branch(h)
                req.live.clear()
            if req.prefix_blocks is not None:
                self.engine.release_prefix(req.prefix_blocks)
                req.prefix_blocks = None
            req.last_logits = None
            req.ssm_state = None
            req.had_fault = True
            if req not in self.request_queue:
                survivors.append(req)
        self.prefilling.clear()
        self.branch_queue.clear()
        self.suspended.clear()
        # survivors re-admit ahead of never-admitted arrivals, in id order
        for req in sorted(survivors, key=lambda r: r.request_id,
                          reverse=True):
            self.request_queue.appendleft(req)
        self.fault_counters["requeued"] += len(survivors)
        restart = getattr(self.engine, "restart", None)
        if restart is not None:
            restart()

    def _check_completions(self):
        for h in list(self.engine.slots):
            if h is None or h.done:
                continue  # freed earlier this pass (sibling's early stop)
            req = self.requests[h.request_id]
            eos = h.tokens[-1] == self.engine.cfg.eos_id
            full = len(h.tokens) >= self.cfg.max_tokens
            if eos or full:
                self._complete_branch(req, h, truncated=full and not eos)
                self._maybe_finalize(req)

    def _complete_branch(self, req: Request, h: BranchHandle,
                         truncated: bool = False):
        """Record a branch completion. ``truncated`` (force-eviction or
        max-token cap) rides the completion tuple and is excluded from the
        pruner's phase-2 α′ threshold — a cut-off branch's reward is not
        evidence a finished answer exists at that quality."""
        reward = self.prm.score(req, [h])[0]
        self.pruner.on_completion(req.meta, reward, truncated=truncated)
        req.completed.append((list(h.tokens), reward, truncated))
        del req.live[h.branch_id]
        self.engine.free_branch(h)

    # ----------------------------------------------------------- bookkeeping
    def _window_bookkeeping(self):
        """Pruning / early-stop checks at window granularity (lines 23-41)."""
        for req in list(self.requests.values()):
            if req.done or req.meta is None:
                continue
            if self.cfg.policy == "rebase":
                self._rebase_step(req)
            elif req.live and self.pruner.cfg.enabled:
                # suspended branches (slot == -1) hold no engine row; they
                # are scored/pruned once resumed
                handles = [h for h in req.live.values() if h.slot >= 0]
                if not handles:
                    continue
                rewards = self.prm.score(req, handles)
                by_id = {h.branch_id: r for h, r in zip(handles, rewards)}
                for h, r in zip(handles, rewards):
                    h.last_reward = r
                    h.scored = True
                for bid in self.pruner.select_prunes(req.meta, by_id):
                    h = req.live.pop(bid)
                    self.engine.free_branch(h)
            self._maybe_finalize(req)

    def _maybe_finalize(self, req: Request):
        if req.done or req.meta is None:
            return
        live_or_pending = len(req.live) + req.pending
        if req.meta.num_completed >= req.meta.m or live_or_pending == 0:
            self._finalize(req)

    def _finalize(self, req: Request):
        """Early stop: terminate remaining branches, pick the final answer."""
        for h in list(req.live.values()):
            self.engine.free_branch(h)
        req.live.clear()
        req.pending = 0
        if req.prefix_blocks is not None:
            self.engine.release_prefix(req.prefix_blocks)
            req.prefix_blocks = None
        if self.cfg.policy == "sc":
            req.final_answer = majority_vote(req.completed, self.answer_fn)
        else:
            req.final_answer = best_of_n(req.completed, self.answer_fn)
        req.finish = self.clock
        if req.had_fault:
            self.fault_counters["recovered"] += 1

    # ---------------------------------------------------------------- rebase
    def _rebase_step(self, req: Request):
        """Reward-guided tree search: cull weak leaves, fork strong ones."""
        if not req.live:
            return
        handles = list(req.live.values())
        rewards = np.asarray(self.prm.score(req, handles))
        for h, r in zip(handles, rewards):
            h.last_reward = float(r)
            h.scored = True
        # cull leaves far below the best (soft budget reallocation)
        if len(handles) > 1:
            weights = np.exp((rewards - rewards.max()) / self.cfg.rebase_temp)
            weights /= weights.sum()
            cut = weights < 0.5 / len(handles)
            for h, c in zip(handles, cut):
                if c and len(req.live) > 1:
                    req.meta.num_pruned += 1
                    del req.live[h.branch_id]
                    self.engine.free_branch(h)
        # expand best leaves while under budget and slots are free
        total = (len(req.live) + req.meta.num_completed
                 + req.pending)
        ranked = sorted(req.live.values(), key=lambda h: -h.last_reward)
        for h in ranked:
            if total >= self.cfg.n or not self.engine.free_slots:
                break
            child = self.engine.fork_branch(h)
            if child is None:
                break
            req.live[child.branch_id] = child
            total += 1

    # ----------------------------------------------------- checkpoint/restore
    def snapshot(self) -> Dict:
        """JSON-serializable checkpoint of *request-level* progress:
        completed branch tokens+rewards+truncated flags, pruner meta, the
        clock and fault counters, and each request's queue/terminal
        standing. Engine-resident state — KV pages, prefill chunk
        progress, in-flight branch tokens — is deliberately NOT
        checkpointed: after ``restore`` survivors re-admit from the
        queue, the prefix cache resurrects warm prompt (and generated)
        prefixes, and lost in-flight decode resumes as resampling.
        ``payload`` objects and the ``Timeline`` are also excluded
        (re-attach payloads after restore if graders need them)."""
        reqs = []
        for req in self.requests.values():
            reqs.append({
                "request_id": req.request_id,
                "prompt": list(req.prompt),
                "arrival": req.arrival,
                "deadline": req.deadline,
                "priority": req.priority,
                "passed_over": req.passed_over,
                "retries": req.retries,
                "not_before": req.not_before,
                "quarantined": req.quarantined,
                "quarantine_reason": req.quarantine_reason,
                "had_fault": req.had_fault,
                "first_service": req.first_service,
                "first_branch": req.first_branch,
                "finish": req.finish,
                "final_answer": req.final_answer,
                "cached_tokens": req.cached_tokens,
                "completed": [[list(t), float(r), bool(tr)]
                              for t, r, tr in req.completed],
                # branch budget still owed: live branches collapse to
                # pending spawns on restore (resampling)
                "outstanding": len(req.live) + req.pending,
                "meta": (dataclasses.asdict(req.meta)
                         if req.meta is not None else None),
            })
        return {"version": 1, "clock": self.clock,
                "next_request_id": self._next_request_id,
                "fault_counters": dict(self.fault_counters),
                "requests": reqs}

    @classmethod
    def restore(cls, snap: Dict, engine: Engine, prm: PRM,
                cfg: SchedulerConfig, answer_fn: Callable) -> "Scheduler":
        """Rebuild a scheduler from ``snapshot()`` output against a fresh
        engine. Finished and quarantined requests keep their terminal
        records; every other request is requeued for re-admission with
        its completed branches, pruner meta and remaining branch budget
        intact (``_harvest_prefill`` skips re-initializing meta)."""
        if snap.get("version") != 1:
            raise ValueError(f"unknown snapshot version {snap.get('version')!r}")
        sch = cls(engine, prm, cfg, answer_fn)
        sch.clock = snap["clock"]
        sch._next_request_id = snap["next_request_id"]
        sch.fault_counters.update(snap.get("fault_counters", {}))
        for rec in snap["requests"]:
            req = Request(rec["request_id"], list(rec["prompt"]),
                          rec["arrival"], None, deadline=rec["deadline"],
                          priority=rec["priority"])
            req.passed_over = rec["passed_over"]
            req.retries = rec["retries"]
            req.not_before = rec["not_before"]
            req.quarantined = rec["quarantined"]
            req.quarantine_reason = rec["quarantine_reason"]
            req.had_fault = rec["had_fault"]
            req.first_service = rec["first_service"]
            req.first_branch = rec["first_branch"]
            req.finish = rec["finish"]
            req.final_answer = rec["final_answer"]
            req.cached_tokens = rec["cached_tokens"]
            req.completed = [(list(t), float(r), bool(tr))
                             for t, r, tr in rec["completed"]]
            req.pending = rec["outstanding"]
            if rec["meta"] is not None:
                req.meta = RequestMeta(**rec["meta"])
            sch.requests[req.request_id] = req
            if not (req.done or req.quarantined):
                sch.request_queue.append(req)
        return sch

    # ---------------------------------------------------------------- metrics
    def metrics(self) -> Dict:
        """Per-request records + aggregates. Requests still live when the
        run stops (``max_steps`` overload) are emitted with
        ``finish=None`` and null latencies instead of being dropped —
        omitting them survivorship-biases every percentile optimistic
        exactly when the system is saturated. ``percentile_latency``
        skips the null fields explicitly."""
        recs = []
        for req in self.requests.values():
            done = req.done
            recs.append({
                "request_id": req.request_id,
                "arrival": req.arrival,
                "first_service": (req.first_service
                                  if req.first_service >= 0 else None),
                "finish": req.finish if done else None,
                "e2e": req.finish - req.arrival if done else None,
                "queue": (req.first_service - req.arrival
                          if req.first_service >= 0 else None),
                "ttfb": (req.first_branch - req.arrival
                         if req.first_branch >= 0 else None),
                "inference": (req.finish - req.first_service
                              if done and req.first_service >= 0 else None),
                "num_completed": req.meta.num_completed if req.meta else 0,
                "num_pruned": req.meta.num_pruned if req.meta else 0,
                "num_truncated": req.meta.num_truncated if req.meta else 0,
                "prompt_tokens": len(req.prompt),
                "cached_tokens": req.cached_tokens,
                "deadline": req.deadline,
                # None without a deadline; an unfinished deadline is a miss
                "deadline_met": (None if req.deadline is None
                                 else done and req.finish <= req.deadline),
                "answer": req.final_answer,
                "response_lengths": [len(t) for t, *_ in req.completed],
                "retries": req.retries,
                "quarantined": req.quarantined,
            })
        slo = [r for r in recs if r["deadline"] is not None]
        met = sum(1 for r in slo if r["deadline_met"])
        out = {"requests": recs, "timeline": self.timeline,
               "clock": self.clock,
               "decode_steps": self.engine.decode_steps_executed,
               "completed_requests": sum(1 for r in recs
                                         if r["finish"] is not None),
               "unfinished_requests": sum(1 for r in recs
                                          if r["finish"] is None),
               "admission_policy": self.admission.name,
               "slo": {
                   "with_deadline": len(slo),
                   "deadline_met": met,
                   "deadline_missed": len(slo) - met,
                   "attainment": met / len(slo) if slo else None,
               }}
        # radix prefix-cache counters (hit rate, evictions, ...) when the
        # engine serves admission through one — cached-prefix admission is
        # part of the scheduling story (warm hits skip chunk steps), so
        # the metrics dict carries it next to the latency percentiles
        stats = getattr(self.engine, "prefix_cache_stats", None)
        pc = stats() if callable(stats) else None
        if pc is not None:
            out["prefix_cache"] = pc
        # failure-domain counters (always present; all-zero on clean runs)
        # plus the injector's own tallies when a FaultInjector drives the
        # run — chaos benchmarks key on these (docs/robustness.md)
        out["faults"] = dict(self.fault_counters)
        out["faults"]["quarantined_requests"] = sum(
            1 for r in recs if r["quarantined"])
        inj = getattr(self.engine, "fault_stats", None)
        if callable(inj):
            out["faults"]["injected"] = inj()
        return out


def percentile_latency(metrics: Dict, q: float, key: str = "e2e") -> float:
    """Percentile over finished measurements only: unfinished requests
    carry ``None`` for every latency field (``metrics()`` emits them so
    overload runs are visible, not silently optimistic) and are skipped
    explicitly here. Check ``metrics["unfinished_requests"]`` before
    trusting a percentile from a saturated run."""
    vals = [r[key] for r in metrics["requests"] if r[key] is not None]
    if not vals:
        return float("nan")
    return float(np.percentile(vals, q))
