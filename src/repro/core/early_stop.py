"""Redundant sampling with early stopping — order-statistics analysis.

Paper §3, Lemma 1 (David & Nagaraja, *Order Statistics*): for N iid branch
lengths with CDF F, the M-th smallest length has CDF

    F_{X_(M)}(x; N) = Σ_{i=M}^{N} C(N, i) F(x)^i (1 − F(x))^{N−i}

which is increasing in N for fixed M — i.e. sampling more branches and
stopping at the M-th completion *stochastically shortens* the time to obtain
M responses. These utilities power the Lemma-1 validation benchmark and the
(N, M) planning helper used by the scheduler.
"""
from __future__ import annotations

import math
from typing import Callable, Sequence

import numpy as np


def order_statistic_cdf(f: np.ndarray, m: int, n: int) -> np.ndarray:
    """CDF of the m-th smallest of n iid draws, given parent CDF values f."""
    f = np.asarray(f, dtype=np.float64)
    assert 1 <= m <= n, (m, n)
    out = np.zeros_like(f)
    for i in range(m, n + 1):
        out += math.comb(n, i) * f ** i * (1.0 - f) ** (n - i)
    return out


def order_statistic_expectation(lengths: Sequence[float], m: int, n: int,
                                grid: int = 4096) -> float:
    """E[X_(m)] of n draws from the *empirical* distribution of `lengths`.

    E[X] = ∫ (1 − F_(m)(x)) dx over [0, max]; numeric on a grid.
    """
    xs = np.sort(np.asarray(lengths, dtype=np.float64))
    hi = xs[-1]
    grid_x = np.linspace(0.0, hi, grid)
    f_parent = np.searchsorted(xs, grid_x, side="right") / len(xs)
    f_m = order_statistic_cdf(f_parent, m, n)
    return float(np.trapezoid(1.0 - f_m, grid_x))


def empirical_mth_completion(lengths: np.ndarray, m: int, n: int,
                             trials: int, seed: int = 0) -> np.ndarray:
    """Monte-Carlo: sample n lengths per trial, return the m-th smallest."""
    rng = np.random.default_rng(seed)
    draws = rng.choice(np.asarray(lengths), size=(trials, n), replace=True)
    part = np.partition(draws, m - 1, axis=1)
    return part[:, m - 1]


def expected_speedup(lengths: Sequence[float], m: int, n: int) -> float:
    """E[max of m] / E[m-th of n] — the early-stopping win for equal yield.

    Baseline (Self-Consistency with m branches) waits for the slowest of m;
    SART with n>m redundant branches waits only for the m-th fastest of n.
    """
    base = order_statistic_expectation(lengths, m, m)
    ours = order_statistic_expectation(lengths, m, n)
    return base / max(ours, 1e-9)
