# SART's primary contribution: redundant sampling with early stopping
# (early_stop), two-phase dynamic pruning (pruning), PRM scoring (prm),
# branch-granularity continuous batching (scheduler, Algorithm 1), and
# final-answer ensembling (ensemble).
from .early_stop import (empirical_mth_completion, expected_speedup,
                         order_statistic_cdf, order_statistic_expectation)
from .ensemble import best_of_n, majority_vote, weighted_vote
from .policies import (ADMISSION_POLICIES, AdmissionPolicy, ComposedPolicy,
                       EdfPolicy, FifoPolicy, LpmPolicy, PriorityPolicy,
                       make_policy, select_next)
from .prm import (PRM, OraclePRM, RewardHeadPRM, init_prm_head,
                  reward_from_hidden)
from .pruning import PruningConfig, RequestMeta, TwoPhasePruner
from .scheduler import (POLICIES, EvictionStallError, Request, Scheduler,
                        SchedulerConfig, SchedulerFaultError,
                        percentile_latency)

__all__ = [
    "order_statistic_cdf", "order_statistic_expectation",
    "empirical_mth_completion", "expected_speedup",
    "best_of_n", "majority_vote", "weighted_vote",
    "PRM", "OraclePRM", "RewardHeadPRM", "init_prm_head",
    "reward_from_hidden",
    "PruningConfig", "RequestMeta", "TwoPhasePruner",
    "POLICIES", "EvictionStallError", "Request", "Scheduler",
    "SchedulerConfig", "SchedulerFaultError", "percentile_latency",
    "ADMISSION_POLICIES", "AdmissionPolicy", "ComposedPolicy",
    "EdfPolicy", "FifoPolicy", "LpmPolicy", "PriorityPolicy",
    "make_policy", "select_next",
]
