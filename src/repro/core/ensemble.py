"""Final-answer selection over completed branches."""
from __future__ import annotations

from collections import Counter
from typing import Callable, List, Optional, Sequence, Tuple

# (generated tokens, reward[, truncated]) — the scheduler appends a
# truncation flag (force-eviction / max-token cap); selection ignores
# trailing fields so older 2-tuples keep working
CompletedBranch = Tuple[List[int], float]


def best_of_n(completed: Sequence[CompletedBranch],
              answer_fn: Callable) -> Optional[object]:
    """SART's default: answer of the highest-reward completed branch."""
    best = None
    for tokens, reward, *_ in completed:
        ans = answer_fn(tokens)
        if ans is None:
            continue
        if best is None or reward > best[0]:
            best = (reward, ans)
    return best[1] if best else None


def majority_vote(completed: Sequence[CompletedBranch],
                  answer_fn: Callable) -> Optional[object]:
    """Self-Consistency: most frequent extracted answer; reward breaks ties."""
    votes = Counter()
    best_reward = {}
    for tokens, reward, *_ in completed:
        ans = answer_fn(tokens)
        if ans is None:
            continue
        votes[ans] += 1
        best_reward[ans] = max(best_reward.get(ans, 0.0), reward)
    if not votes:
        return None
    top = max(votes, key=lambda a: (votes[a], best_reward[a]))
    return top


def weighted_vote(completed: Sequence[CompletedBranch],
                  answer_fn: Callable) -> Optional[object]:
    """Reward-weighted voting (beyond-paper variant)."""
    mass = {}
    for tokens, reward, *_ in completed:
        ans = answer_fn(tokens)
        if ans is None:
            continue
        mass[ans] = mass.get(ans, 0.0) + reward
    if not mass:
        return None
    return max(mass, key=mass.get)
