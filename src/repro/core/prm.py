"""Process Reward Models.

The paper scores partial reasoning branches with Qwen2.5-Math-PRM-7B. In this
reproduction the PRM is pluggable behind one protocol — ``score(request,
handles) -> rewards in [0,1]`` — with two implementations:

  * ``RewardHeadPRM`` — a linear+sigmoid head over the serving model's own
    last hidden state (returned by every decode step for free). Trained on
    synthetic CoT data by ``repro.training``. This is the live end-to-end
    path; it adapts the paper's separate-PRM-server design to a co-located
    TPU-friendly head.
  * ``OraclePRM`` — task-aware reward for controlled experiments: fraction of
    correct reasoning steps in the branch so far, plus configurable noise.
    Lets experiments isolate scheduler behaviour from PRM quality.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------- reward head


def init_prm_head(key, d_model: int, hidden_dim: int = 64) -> dict:
    """Two-layer MLP reward head (a linear head underfits the step-
    correctness signal — measured BCE plateau near ln 2)."""
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (d_model, hidden_dim)) * (d_model ** -0.5),
        "b1": jnp.zeros((hidden_dim,)),
        "w2": jax.random.normal(k2, (hidden_dim,)) * (hidden_dim ** -0.5),
        "b2": jnp.zeros(()),
    }


def reward_logit(params: dict, hidden) -> jax.Array:
    """Pre-sigmoid reward head output for ``hidden [..., D] -> [...]``;
    dispatches on the param pytree shape (MLP vs legacy linear head)."""
    if "w1" in params:
        h = jax.nn.tanh(hidden @ params["w1"] + params["b1"])
        return h @ params["w2"] + params["b2"]
    return hidden @ params["w"] + params["b"]   # legacy linear head


@jax.jit
def reward_from_hidden(params: dict, hidden) -> jax.Array:
    """hidden [..., D] -> rewards [...] in (0, 1)."""
    return jax.nn.sigmoid(reward_logit(params, hidden))


def prm_head_loss(params: dict, hidden, labels) -> jax.Array:
    """Binary cross-entropy on per-step goodness labels."""
    logit = reward_logit(params, hidden)
    return jnp.mean(
        jnp.maximum(logit, 0) - logit * labels +
        jnp.log1p(jnp.exp(-jnp.abs(logit))))


# ------------------------------------------------------------------ protocols


class PRM:
    """Scores live branches of a request. Higher = more right-thinking."""

    def score(self, request, handles: Sequence) -> List[float]:
        """Reward in [0, 1] per handle, aligned with ``handles`` order."""
        raise NotImplementedError


class RewardHeadPRM(PRM):
    """Reads the engine's cached last-hidden rows for the handles' slots."""

    def __init__(self, engine):
        self.engine = engine

    def score(self, request, handles) -> List[float]:
        """Index the engine's per-slot reward vector by handle slot (one
        host sync per pruning round, not per handle)."""
        rewards = self.engine.score_slots()  # [max_slots]
        return [float(rewards[h.slot]) for h in handles]


class OraclePRM(PRM):
    """Deterministic task-aware reward with optional noise.

    ``grader(request, tokens) -> float in [0,1]`` judges the partial branch;
    the synthetic-task grader lives in ``repro.data.tasks``.
    """

    def __init__(self, grader: Callable, noise: float = 0.0, seed: int = 0):
        self.grader = grader
        self.noise = noise
        self._rng = np.random.default_rng(seed)

    def score(self, request, handles) -> List[float]:
        """Grade each handle's partial token stream, clipping the noised
        reward back into [0, 1]."""
        out = []
        for h in handles:
            r = float(self.grader(request, h.tokens))
            if self.noise:
                r = float(np.clip(r + self._rng.normal(0, self.noise), 0, 1))
            out.append(r)
        return out
