"""Pluggable cache- and SLO-aware admission policies for the scheduler.

The scheduler's admission loop used to be FIFO-with-a-starvation-bound
baked into ``Scheduler._arrived`` — worse, it peeked only the queue
*head*, so an already-arrived request parked behind a future-arrival head
was never admitted at all. This module replaces that with a policy object
that **orders the whole arrived set** at each admission opportunity:

  * ``fifo``      — submission order (bit-exact with the pre-policy
                    scheduler on in-order arrival workloads, and the
                    default).
  * ``lpm``       — longest-prefix-match, SGLang-style: probe the radix
                    prefix cache (``PrefixCache.match_tokens``, a
                    non-mutating lookup) for each queued prompt and admit
                    the hottest matches first, so warm pages are increfed
                    (and thereby pinned) before cold admissions evict
                    them.
  * ``edf``       — earliest-deadline-first over the optional absolute
                    ``Request.deadline`` clock; deadline-less requests
                    sort last.
  * ``priority``  — higher ``Request.priority`` tier first.

Policies **compose**: ``"priority+lpm"`` (or the equivalent
``"priority-then-lpm"``) orders by tier first and breaks ties by cache
hotness. Every ordering ends with the FIFO key, so selection is always
deterministic.

Starvation bound: any non-FIFO ordering can pass over an unlucky request
indefinitely (a cold prompt under ``lpm``, a deadline-less request under
``edf``). ``select_next`` therefore counts, per request, how many times a
*younger* request was admitted ahead of it; once that reaches the bound
(``SchedulerConfig.admission_starvation_bound``) the request is starved
and is admitted next — oldest starved request first — regardless of the
policy's preference. Under ``fifo`` the chosen request is always the
oldest arrived one, so the counters never move and behavior is exactly
the legacy order. The same skip-counting guarantee the chunk-lane packer
gives in-flight prefills (``pack_chunk_lanes``), applied one layer up.
"""
from __future__ import annotations

import math
from typing import (Any, List, Sequence, Tuple, Union, TYPE_CHECKING)

if TYPE_CHECKING:  # pragma: no cover - import cycle guard, types only
    from .scheduler import Request, Scheduler

ADMISSION_POLICIES = ("fifo", "lpm", "edf", "priority")

# spec separators, all equivalent: "priority+lpm" == "priority-then-lpm"
_SEPARATORS = ("-then-", "+", ",")


class AdmissionPolicy:
    """Orders the arrived-request set; lower ``key`` admits first."""

    name = "fifo"

    def key(self, req: "Request", sched: "Scheduler") -> Tuple[Any, ...]:
        """Sort key for ``req`` (lower = admitted earlier). ``sched`` is
        the driving ``Scheduler`` — policies read clock/cache through it
        so ``Engine`` and ``SimEngine`` go through one code path."""
        return ()

    def __repr__(self) -> str:
        return f"{type(self).__name__}({self.name!r})"


class FifoPolicy(AdmissionPolicy):
    """Submission order (the request_id tiebreak carries the ordering)."""
    name = "fifo"


class LpmPolicy(AdmissionPolicy):
    """Longest-prefix-match: most cached prompt tokens first. Engines
    without a prefix cache probe as 0 everywhere — pure FIFO."""
    name = "lpm"

    def key(self, req: "Request", sched: "Scheduler") -> Tuple[Any, ...]:
        """Negated cached-token count: hotter prompts sort earlier."""
        return (-sched.probe_cached_tokens(req),)


class EdfPolicy(AdmissionPolicy):
    """Earliest absolute deadline first; deadline-less requests last."""
    name = "edf"

    def key(self, req: "Request", sched: "Scheduler") -> Tuple[Any, ...]:
        """Absolute deadline clock; ``inf`` parks deadline-less last."""
        return (req.deadline if req.deadline is not None else math.inf,)


class PriorityPolicy(AdmissionPolicy):
    """Higher priority tier first (default tier 0)."""
    name = "priority"

    def key(self, req: "Request", sched: "Scheduler") -> Tuple[Any, ...]:
        """Negated tier: higher-priority requests sort earlier."""
        return (-req.priority,)


class ComposedPolicy(AdmissionPolicy):
    """Lexicographic composition: earlier parts dominate, later parts
    break their ties (e.g. priority-then-lpm)."""

    def __init__(self, parts: Sequence[AdmissionPolicy]) -> None:
        self.parts = tuple(parts)
        self.name = "+".join(p.name for p in self.parts)

    def key(self, req: "Request", sched: "Scheduler") -> Tuple[Any, ...]:
        """Concatenation of the parts' keys, in composition order."""
        out: Tuple[Any, ...] = ()
        for p in self.parts:
            out += p.key(req, sched)
        return out


_REGISTRY = {
    "fifo": FifoPolicy,
    "lpm": LpmPolicy,
    "edf": EdfPolicy,
    "priority": PriorityPolicy,
}


def make_policy(spec: Union[str, AdmissionPolicy]) -> AdmissionPolicy:
    """Build a policy from a config string (``"fifo"``, ``"lpm"``,
    ``"edf"``, ``"priority"``, or compositions like ``"priority+lpm"`` /
    ``"priority-then-lpm"``). Policy instances pass through unchanged."""
    if isinstance(spec, AdmissionPolicy):
        return spec
    s = str(spec).strip().lower()
    for sep in _SEPARATORS:
        s = s.replace(sep, " ")
    names = s.split()
    if not names:
        raise ValueError(f"empty admission policy spec {spec!r}")
    try:
        parts = [_REGISTRY[n]() for n in names]
    except KeyError as e:
        raise ValueError(
            f"unknown admission policy {e.args[0]!r} in {spec!r}; "
            f"known: {', '.join(sorted(_REGISTRY))}") from None
    return parts[0] if len(parts) == 1 else ComposedPolicy(parts)


def select_next(policy: AdmissionPolicy, arrived: List["Request"],
                sched: "Scheduler", starvation_bound: int) -> "Request":
    """Pick the next request to admit from the arrived set.

    Starved requests (passed over ``starvation_bound`` times by younger
    ones) preempt the policy ordering, oldest first, so no request is
    deferred unboundedly. Otherwise the policy's key orders the set, with
    submission order as the final tiebreak. Bookkeeping: every request
    older than the chosen one records one pass-over.
    """
    starved = [r for r in arrived if r.passed_over >= starvation_bound]
    if starved:
        chosen = min(starved, key=lambda r: r.request_id)
    else:
        chosen = min(arrived,
                     key=lambda r: policy.key(r, sched) + (r.request_id,))
    for r in arrived:
        if r is not chosen and r.request_id < chosen.request_id:
            r.passed_over += 1
    chosen.passed_over = 0
    return chosen
