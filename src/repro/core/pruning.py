"""Two-phase dynamic pruning (paper §3 Solution 2, Figure 4).

Phase 1 — *exploration*: prune only branches whose PRM reward falls below a
low static threshold α, and never prune more than β branches total, so the
search stays wide while nothing has finished.

Phase 2 — *exploitation*: entered the moment the request's first branch
completes. The threshold is raised to α′ = reward of that first completed
branch, and the prune cap is lifted to N−1 — any live branch scoring below
what a finished answer already achieved is released immediately.

The pruner is pure bookkeeping over ``RequestMeta`` — no engine coupling —
so its invariants are property-tested in isolation.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence


@dataclasses.dataclass
class RequestMeta:
    """Per-request scheduler metadata (Algorithm 1 line 16)."""
    n: int                            # branches sampled
    m: int                            # completions that trigger early stop
    phase: str = "explore"            # explore | exploit
    threshold: float = 0.0            # current pruning threshold
    max_num_pruned: int = 0           # β in phase 1, N-1 in phase 2
    num_completed: int = 0
    num_pruned: int = 0
    num_truncated: int = 0            # force-evicted / max-token cut-offs

    @property
    def terminal(self) -> bool:
        """All accounting done: early stop hit or nothing left running."""
        return (self.num_completed >= self.m
                or self.num_completed + self.num_pruned >= self.n)


@dataclasses.dataclass(frozen=True)
class PruningConfig:
    alpha: float = 0.5                # phase-1 threshold
    beta: int = 0                     # phase-1 prune cap (0 -> N//2 default)
    enabled: bool = True


class TwoPhasePruner:
    def __init__(self, cfg: PruningConfig):
        self.cfg = cfg

    def new_meta(self, n: int, m: int) -> RequestMeta:
        """Fresh per-request pruning state in the explore phase, with the
        phase-1 prune cap resolved (beta<=0 -> N//2, capped at n-1 so at
        least one branch always survives to completion)."""
        beta = self.cfg.beta if self.cfg.beta > 0 else max(n // 2, 1)
        return RequestMeta(n=n, m=m, phase="explore",
                           threshold=self.cfg.alpha,
                           max_num_pruned=min(beta, n - 1))

    def on_completion(self, meta: RequestMeta, reward: float,
                      truncated: bool = False) -> None:
        """Algorithm 1 lines 24-27: first completion flips to exploitation.

        ``truncated`` completions (force-evicted under memory pressure, or
        cut at the max-token cap) still count toward the early-stop M, but
        they must NOT flip the phase or set the α′ threshold: a cut-off
        branch's reward is not evidence that a *finished* answer at that
        quality exists, and letting it seed α′ would prune live branches
        against a phantom baseline."""
        meta.num_completed += 1
        if truncated:
            meta.num_truncated += 1
            return
        if meta.phase == "explore":
            meta.phase = "exploit"
            meta.threshold = reward       # α′
            meta.max_num_pruned = meta.n - 1

    def select_prunes(self, meta: RequestMeta,
                      rewards: Dict[int, float]) -> List[int]:
        """Algorithm 1 lines 32-37: pick branch ids to prune this window.

        ``rewards``: {branch_id: reward} for the request's *live* branches.
        Respects the phase cap; prunes lowest-reward first so the cap binds
        on the worst branches.
        """
        if not self.cfg.enabled:
            return []
        budget = meta.max_num_pruned - meta.num_pruned
        if budget <= 0:
            return []
        victims = sorted(
            (bid for bid, r in rewards.items() if r < meta.threshold),
            key=lambda bid: rewards[bid])
        victims = victims[:budget]
        meta.num_pruned += len(victims)
        return victims
