"""Tiny fixed vocabulary for the synthetic reasoning task."""
from __future__ import annotations

from typing import List

PAD, EOS, BOS = 0, 1, 2
DIGIT0 = 3                      # '0'..'9' -> 3..12
PLUS, MINUS, TIMES, EQUALS = 13, 14, 15, 16
STEP, SEP, ANSWER, RECHECK = 17, 18, 19, 20   # '>', ';', 'A', 'R'
VOCAB_SIZE = 32                 # padded to a power-of-two-ish tile

_CHARS = {PAD: "_", EOS: "$", BOS: "^", PLUS: "+", MINUS: "-", TIMES: "*",
          EQUALS: "=", STEP: ">", SEP: ";", ANSWER: "A", RECHECK: "R"}
OPS = {"+": PLUS, "-": MINUS, "*": TIMES}


def digit(d: int) -> int:
    assert 0 <= d <= 9
    return DIGIT0 + d


def is_digit(tok: int) -> bool:
    return DIGIT0 <= tok < DIGIT0 + 10


def digit_value(tok: int) -> int:
    assert is_digit(tok)
    return tok - DIGIT0


def decode(tokens: List[int]) -> str:
    out = []
    for t in tokens:
        if is_digit(t):
            out.append(str(digit_value(t)))
        else:
            out.append(_CHARS.get(t, "?"))
    return "".join(out)
