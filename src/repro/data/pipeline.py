"""Training data pipeline: trace generation, packing, batching.

Pure NumPy on the host feeding jit'd steps — the standard JAX input pattern.
Sequences are packed back-to-back with segment ids so attention stays within
a trace (the packed path uses the model's ``segment_ids`` support), or padded
per-row for the simple path.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import numpy as np

from . import tasks
from . import tokenizer as tk


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch_size: int = 32
    seq_len: int = 128
    min_terms: int = 3
    max_terms: int = 8
    recheck_p: float = 0.25
    overthink_p: float = 0.05
    seed: int = 0


def padded_batches(cfg: DataConfig) -> Iterator[Tuple[np.ndarray, ...]]:
    """Yields (tokens, labels, mask) of shape [B, S].

    labels[i] = tokens shifted left by one; mask is 1 on CoT positions only
    (the prompt is conditioning, not a training target).
    """
    rng = np.random.default_rng(cfg.seed)
    while True:
        toks = np.full((cfg.batch_size, cfg.seq_len), tk.PAD, np.int32)
        mask = np.zeros((cfg.batch_size, cfg.seq_len), np.float32)
        for b in range(cfg.batch_size):
            prob = tasks.gen_problem(rng, cfg.min_terms, cfg.max_terms)
            trace = tasks.render_trace(prob, rng, cfg.recheck_p,
                                       overthink_p=cfg.overthink_p)
            trace = trace[:cfg.seq_len]
            toks[b, :len(trace)] = trace
            plen = len(prob.prompt_tokens())
            mask[b, plen - 1:len(trace) - 1] = 1.0   # predict CoT tokens
        labels = np.roll(toks, -1, axis=1)
        labels[:, -1] = tk.PAD
        yield toks, labels, mask


def prm_batches(cfg: DataConfig, error_p: float = 0.3
                ) -> Iterator[Tuple[np.ndarray, ...]]:
    """Batches for PRM-head training: (tokens, step_labels, step_mask).

    Traces are rendered with per-step corruption probability ``error_p``;
    label 1 at a position iff every emission up to and including it is
    correct (matching how the PRM judges a *partial* branch).
    """
    rng = np.random.default_rng(cfg.seed + 7)
    while True:
        toks = np.full((cfg.batch_size, cfg.seq_len), tk.PAD, np.int32)
        labels = np.zeros((cfg.batch_size, cfg.seq_len), np.float32)
        mask = np.zeros((cfg.batch_size, cfg.seq_len), np.float32)
        for b in range(cfg.batch_size):
            prob = tasks.gen_problem(rng, cfg.min_terms, cfg.max_terms)
            corrupt = rng.random() < 0.5
            trace = tasks.render_trace(
                prob, rng, cfg.recheck_p, error_p=error_p if corrupt else 0.0)
            trace = trace[:cfg.seq_len]
            toks[b, :len(trace)] = trace
            plen = len(prob.prompt_tokens())
            # per-position prefix-correctness labels on emission digits
            correct_so_far = True
            i = plen
            while i < len(trace) - 1:
                t = trace[i]
                if t in (tk.STEP, tk.RECHECK, tk.ANSWER) \
                        and tk.is_digit(trace[i + 1]):
                    c, tot = tasks.grade_steps(prob, trace[plen:i + 2])
                    correct_so_far = (c == tot)
                    labels[b, i + 1] = 1.0 if correct_so_far else 0.0
                    mask[b, i + 1] = 1.0
                    i += 2
                else:
                    i += 1
        yield toks, labels, mask
