"""Synthetic verifiable reasoning tasks (modular arithmetic chains).

Design goals mirroring the paper's experimental conditions:
  * exact answer checking (stand-in for GPQA/GAOKAO graders);
  * CoT traces whose *length varies independently of correctness* — training
    traces include stochastic "recheck" steps (`R<d>;` re-emitting the
    current running value), and a geometric tail of rechecks reproduces the
    over-thinking dilemma: occasional branches run extremely long;
  * a step-level notion of partial correctness for the oracle PRM: every
    emitted step digit is checkable against the true running values.

Trace grammar (see ``repro.data.tokenizer``):
    ^ d1 op d2 op d3 ... =  ( >v; (Rv;)* )*  A a $
where v is the running value (mod 10) after folding each term and `a` the
final answer.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from . import tokenizer as tk


@dataclasses.dataclass(frozen=True)
class Problem:
    terms: Tuple[int, ...]        # digits
    ops: Tuple[str, ...]          # between terms, len = len(terms)-1
    running: Tuple[int, ...]      # running value (mod 10) after each fold
    answer: int                   # == running[-1]

    def prompt_tokens(self) -> List[int]:
        out = [tk.BOS, tk.digit(self.terms[0])]
        for op, t in zip(self.ops, self.terms[1:]):
            out += [tk.OPS[op], tk.digit(t)]
        out.append(tk.EQUALS)
        return out


def gen_problem(rng: np.random.Generator, min_terms: int = 3,
                max_terms: int = 8) -> Problem:
    k = int(rng.integers(min_terms, max_terms + 1))
    terms = [int(rng.integers(0, 10)) for _ in range(k)]
    ops = [str(rng.choice(["+", "-", "*"])) for _ in range(k - 1)]
    running = [terms[0] % 10]
    for op, t in zip(ops, terms[1:]):
        v = running[-1]
        if op == "+":
            v = (v + t) % 10
        elif op == "-":
            v = (v - t) % 10
        else:
            v = (v * t) % 10
        running.append(v)
    return Problem(tuple(terms), tuple(ops), tuple(running), running[-1])


def render_trace(problem: Problem, rng: np.random.Generator,
                 recheck_p: float = 0.25, error_p: float = 0.0,
                 overthink_p: float = 0.05,
                 overthink_geo: float = 0.15) -> List[int]:
    """Full training trace = prompt + CoT + answer + EOS.

    ``recheck_p``   — per-step probability of one redundant recheck.
    ``overthink_p`` — probability this trace falls into the over-thinking
                      dilemma: a geometric (p=overthink_geo) burst of extra
                      rechecks at a random step, producing the long tail of
                      response lengths the paper observes (§3, Fig. 2).
    ``error_p``     — per-step probability of a corrupted digit (used to
                      build PRM-head training data, not the LM data).
    """
    out = list(problem.prompt_tokens())
    overthink_at = (int(rng.integers(0, len(problem.running)))
                    if rng.random() < overthink_p else -1)

    def emit(head: int, value: int):
        v = value
        if error_p and rng.random() < error_p:
            v = (v + int(rng.integers(1, 10))) % 10
        out.extend([head, tk.digit(v), tk.SEP])
        return v == value

    ok = True
    for i, v in enumerate(problem.running):
        ok &= emit(tk.STEP, v)
        n_recheck = 1 if rng.random() < recheck_p else 0
        if i == overthink_at:
            n_recheck += int(rng.geometric(overthink_geo))
        for _ in range(n_recheck):
            ok &= emit(tk.RECHECK, v)
    final = problem.answer
    if error_p and rng.random() < error_p:
        final = (final + int(rng.integers(1, 10))) % 10
        ok = False
    out.extend([tk.ANSWER, tk.digit(final), tk.EOS])
    return out


# ----------------------------------------------------------- answer checking


def extract_answer(tokens: List[int]) -> Optional[int]:
    """Extract the final answer digit from generated tokens ('A' d)."""
    for i in range(len(tokens) - 1, -1, -1):
        if tokens[i] == tk.ANSWER and i + 1 < len(tokens) \
                and tk.is_digit(tokens[i + 1]):
            return tk.digit_value(tokens[i + 1])
    return None


def grade_steps(problem: Problem, generated: List[int]) -> Tuple[int, int]:
    """(correct_emissions, total_emissions) for a (partial) branch."""
    ptr = 0
    correct = total = 0
    i = 0
    n = len(generated)
    while i < n:
        t = generated[i]
        if t in (tk.STEP, tk.RECHECK, tk.ANSWER) and i + 1 < n \
                and tk.is_digit(generated[i + 1]):
            v = tk.digit_value(generated[i + 1])
            if t == tk.STEP:
                exp = (problem.running[ptr] if ptr < len(problem.running)
                       else None)
                ptr += 1
            elif t == tk.RECHECK:
                exp = (problem.running[ptr - 1]
                       if 0 < ptr <= len(problem.running) else None)
            else:
                exp = problem.answer
            total += 1
            if exp is not None and v == exp:
                correct += 1
            i += 2
        else:
            i += 1
    return correct, total


def oracle_grader(request, generated: List[int]) -> float:
    """PRM protocol grader: fraction of correct emissions so far.

    ``request.payload`` must be the Problem. Neutral 0.5 before any step.
    """
    problem: Problem = request.payload
    correct, total = grade_steps(problem, generated)
    if total == 0:
        return 0.5
    return correct / total


def is_correct(problem: Problem, answer) -> bool:
    return answer is not None and int(answer) == problem.answer
