from . import tasks, tokenizer
from .pipeline import DataConfig, padded_batches, prm_batches

__all__ = ["tasks", "tokenizer", "DataConfig", "padded_batches",
           "prm_batches"]
