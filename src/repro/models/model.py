"""Unified decoder-only model covering all six assigned arch families.

Layer params are stacked along a leading ``[L, ...]`` axis and the trunk is a
``jax.lax.scan`` over layers — the lowered HLO is O(1) in depth, which keeps
the 94-layer dry-run compiles tractable and is also the idiomatic TPU pattern
(weights streamed HBM->VMEM per layer).

Three entry points per model:
  * ``forward``      — full-sequence training/eval forward, returns logits.
  * ``prefill``      — forward that also materializes the decode cache.
  * ``decode_step``  — one token against the cache (attention KV and/or SSM
                       state depending on family). This is what ``serve_step``
                       lowers for the decode_32k / long_500k dry-run shapes.

VLM/audio backbones accept ``embeds`` (precomputed frontend embeddings) in
place of token ids for the prompt — the modality frontend is stubbed per the
assignment.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.logical import constrain, scan_unroll
from .attention import (attention_decode, attention_prefill, attention_train,
                        init_attention)
from .config import ModelConfig
from .layers import (apply_mlp, apply_norm, embed_tokens, init_embedding,
                     init_mlp, init_norm, sinusoidal_embedding, unembed)
from .mamba2 import (init_mamba2, init_mamba2_state, mamba2_decode,
                     mamba2_forward, _conv_dim)
from .moe import apply_moe, init_moe

Cache = Dict[str, jax.Array]
Params = Dict[str, Any]


def _init_layer(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm1": init_norm(cfg, cfg.d_model)}
    if cfg.uses_attention:
        p["attn"] = init_attention(ks[0], cfg, dtype)
    if cfg.uses_ssm:
        p["mamba"] = init_mamba2(ks[1], cfg, dtype)
    if cfg.d_ff:
        p["norm2"] = init_norm(cfg, cfg.d_model)
        if cfg.uses_moe:
            p["moe"] = init_moe(ks[2], cfg, dtype)
        else:
            p["mlp"] = init_mlp(ks[3], cfg, dtype)
    return p


class Model:
    """Functional model: params/caches are plain pytrees."""

    def __init__(self, cfg: ModelConfig, dtype=jnp.float32):
        self.cfg = cfg
        self.dtype = dtype

    # ------------------------------------------------------------------ init
    def init_params(self, rng) -> Params:
        cfg = self.cfg
        k_embed, k_layers = jax.random.split(rng)
        layer_keys = jax.random.split(k_layers, cfg.num_layers)
        layers = jax.vmap(lambda k: _init_layer(k, cfg, self.dtype))(layer_keys)
        return {
            "embed": init_embedding(k_embed, cfg, self.dtype),
            "layers": layers,
            "final_norm": init_norm(cfg, cfg.d_model),
        }

    def init_cache(self, batch: int, max_len: int) -> Cache:
        """Decode cache sized for `max_len` context.

        With a sliding-window config the attention cache is a ring buffer of
        size ``min(max_len, window)`` — this is the sub-quadratic carve-out
        that lets dense archs lower long_500k with O(window) state.
        """
        cfg = self.cfg
        cache: Cache = {}
        if cfg.uses_attention:
            klen = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
            shape = (cfg.num_layers, batch, klen, cfg.num_kv_heads,
                     cfg.resolved_head_dim)
            cache["k"] = jnp.zeros(shape, self.dtype)
            cache["v"] = jnp.zeros(shape, self.dtype)
        if cfg.uses_ssm:
            conv, ssd = init_mamba2_state(cfg, batch, self.dtype)
            cache["conv"] = jnp.broadcast_to(
                conv[None], (cfg.num_layers,) + conv.shape).copy()
            cache["ssd"] = jnp.broadcast_to(
                ssd[None], (cfg.num_layers,) + ssd.shape).copy()
        return cache

    # ------------------------------------------------------------- embedding
    def _embed_inputs(self, params, tokens, embeds):
        cfg = self.cfg
        if embeds is not None:
            x = embeds.astype(self.dtype)
        else:
            x = embed_tokens(cfg, params["embed"], tokens)
        if cfg.pos_embedding == "sinusoidal":
            s = x.shape[1]
            pos = jnp.arange(s)
            x = x + sinusoidal_embedding(pos, cfg.d_model)[None].astype(x.dtype)
        return x

    # ----------------------------------------------------------------- train
    def forward(self, params: Params, tokens=None, embeds=None,
                positions=None) -> Tuple[jax.Array, jax.Array]:
        """Full-sequence forward. Returns (logits [B,S,V], aux_loss)."""
        cfg = self.cfg
        x = constrain(self._embed_inputs(params, tokens, embeds), "btd")
        b, s, _ = x.shape
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        if cfg.pos_embedding == "mrope" and positions.ndim == 2:
            positions = jnp.broadcast_to(positions[..., None], (b, s, 3))

        def body(carry, layer_p):
            x, aux = carry
            x, aux_l = self._layer_train(layer_p, x, positions)
            return (constrain(x, "btd"), aux + aux_l), None

        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   params["layers"], unroll=scan_unroll())
        x = apply_norm(cfg, params["final_norm"], x)
        logits = constrain(unembed(cfg, params["embed"], x), "btv")
        return logits, aux

    def _layer_train(self, p, x, positions):
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        h = apply_norm(cfg, p["norm1"], x)
        mix = jnp.zeros_like(x)
        if cfg.uses_attention:
            mix = mix + attention_train(cfg, p["attn"], h, positions)
        if cfg.uses_ssm:
            y, _ = mamba2_forward(cfg, p["mamba"], h)
            mix = mix + y
        if cfg.arch_type == "hybrid":  # parallel heads are averaged (Hymba)
            mix = mix * 0.5
        x = x + mix
        if cfg.d_ff:
            h2 = apply_norm(cfg, p["norm2"], x)
            if cfg.uses_moe:
                y, aux = apply_moe(cfg, p["moe"], h2)
            else:
                y = apply_mlp(cfg, p["mlp"], h2)
            x = x + y
        return x, aux

    # --------------------------------------------------------------- prefill
    def prefill(self, params: Params, tokens=None, embeds=None,
                positions=None, cache: Optional[Cache] = None,
                max_len: Optional[int] = None):
        """Process the prompt, seed the cache. Returns (logits_last, cache)."""
        cfg = self.cfg
        x = self._embed_inputs(params, tokens, embeds)
        b, s, _ = x.shape
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        if cfg.pos_embedding == "mrope" and positions.ndim == 2:
            positions = jnp.broadcast_to(positions[..., None], (b, s, 3))
        if cache is None:
            cache = self.init_cache(b, max_len or cfg.max_seq_len)

        def body(x, scanned):
            layer_p, layer_cache = scanned
            x, new_cache = self._layer_prefill(layer_p, layer_cache, x,
                                               positions, s)
            return constrain(x, "btd"), new_cache

        x = constrain(x, "btd")
        x, new_caches = jax.lax.scan(body, x, (params["layers"], cache),
                                     unroll=scan_unroll())
        x = apply_norm(cfg, params["final_norm"], x[:, -1:])
        logits = constrain(unembed(cfg, params["embed"], x)[:, 0], "bv")
        return logits, new_caches

    def _layer_prefill(self, p, layer_cache, x, positions, s):
        cfg = self.cfg
        new_cache = dict(layer_cache)
        h = apply_norm(cfg, p["norm1"], x)
        mix = jnp.zeros_like(x)
        if cfg.uses_attention:
            y, (k, v) = attention_prefill(cfg, p["attn"], h, positions)
            mix = mix + y
            klen = layer_cache["k"].shape[1]
            if s <= klen:
                new_cache["k"] = jax.lax.dynamic_update_slice_in_dim(
                    layer_cache["k"], k.astype(layer_cache["k"].dtype), 0, 1)
                new_cache["v"] = jax.lax.dynamic_update_slice_in_dim(
                    layer_cache["v"], v.astype(layer_cache["v"].dtype), 0, 1)
            else:  # ring cache smaller than prompt: keep the tail, at p%klen
                shift = s % klen
                new_cache["k"] = jnp.roll(
                    k[:, -klen:].astype(layer_cache["k"].dtype), shift, axis=1)
                new_cache["v"] = jnp.roll(
                    v[:, -klen:].astype(layer_cache["v"].dtype), shift, axis=1)
        if cfg.uses_ssm:
            y, (conv, ssd) = mamba2_forward(cfg, p["mamba"], h)
            mix = mix + y
            new_cache["conv"] = conv.astype(layer_cache["conv"].dtype)
            new_cache["ssd"] = ssd.astype(layer_cache["ssd"].dtype)
        if cfg.arch_type == "hybrid":
            mix = mix * 0.5
        x = x + mix
        if cfg.d_ff:
            h2 = apply_norm(cfg, p["norm2"], x)
            if cfg.uses_moe:
                y, _ = apply_moe(cfg, p["moe"], h2)
            else:
                y = apply_mlp(cfg, p["mlp"], h2)
            x = x + y
        return x, new_cache

    # ----------------------------------------------------------- decode step
    def decode_step(self, params: Params, tokens, cache: Cache, positions):
        """tokens: [B] int32; positions: [B] absolute positions.

        Returns (logits [B,V], new_cache, hidden [B,D]) — `hidden` feeds the
        PRM reward head without a second forward.
        """
        cfg = self.cfg
        x = embed_tokens(cfg, params["embed"], tokens[:, None])
        if cfg.pos_embedding == "sinusoidal":
            x = x + sinusoidal_embedding(positions, cfg.d_model)[:, None].astype(x.dtype)

        def body(x, scanned):
            layer_p, layer_cache = scanned
            x, new_cache = self._layer_decode(layer_p, layer_cache, x,
                                              positions)
            return constrain(x, "btd"), new_cache

        x = constrain(x, "btd")
        x, new_caches = jax.lax.scan(body, x, (params["layers"], cache),
                                     unroll=scan_unroll())
        x = apply_norm(cfg, params["final_norm"], x)
        hidden = x[:, 0]
        logits = constrain(unembed(cfg, params["embed"], hidden), "bv")
        return logits, new_caches, hidden

    def _layer_decode(self, p, layer_cache, x, positions):
        cfg = self.cfg
        new_cache = dict(layer_cache)
        h = apply_norm(cfg, p["norm1"], x)
        mix = jnp.zeros_like(x)
        if cfg.uses_attention:
            y, ck, cv = attention_decode(cfg, p["attn"], h, layer_cache["k"],
                                         layer_cache["v"], positions)
            mix = mix + y
            new_cache["k"], new_cache["v"] = ck, cv
        if cfg.uses_ssm:
            y, conv, ssd = mamba2_decode(cfg, p["mamba"], h,
                                         layer_cache["conv"],
                                         layer_cache["ssd"])
            mix = mix + y
            new_cache["conv"] = conv.astype(layer_cache["conv"].dtype)
            new_cache["ssd"] = ssd.astype(layer_cache["ssd"].dtype)
        if cfg.arch_type == "hybrid":
            mix = mix * 0.5
        x = x + mix
        if cfg.d_ff:
            h2 = apply_norm(cfg, p["norm2"], x)
            if cfg.uses_moe:
                y, _ = apply_moe(cfg, p["moe"], h2)
            else:
                y = apply_mlp(cfg, p["mlp"], h2)
            x = x + y
        return x, new_cache


def cross_entropy_loss(logits, labels, mask=None):
    """logits [B,S,V], labels [B,S] -> mean token NLL (mask: [B,S] 0/1)."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
