from .config import ModelConfig, smoke_variant
from .model import Model, cross_entropy_loss

__all__ = ["ModelConfig", "smoke_variant", "Model", "cross_entropy_loss"]
