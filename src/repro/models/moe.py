"""Mixture-of-Experts FFN with capacity-based grouped matmul dispatch.

TPU-native formulation (GShard/Switch lineage, as used by MaxText-style
frameworks): tokens are routed top-k, sorted by expert id, scattered into a
dense `[E, C, D]` buffer (capacity C with overflow drop), processed with a
single batched einsum against `[E, D, F]` expert weights (MXU-friendly), and
combined back with the router gates. Experts are sharded on the 'model' mesh
axis (expert parallelism) — the scatter/gather lowers to all-to-all style
collectives under the SPMD partitioner.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..distributed.logical import constrain, moe_dp_chunks
from .config import ModelConfig
from .layers import _act, dense_init


def init_moe(key, cfg: ModelConfig, dtype=jnp.float32):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    p = {
        "router": dense_init(ks[0], (d, e), 0, dtype),
        "w_up": dense_init(ks[1], (e, d, f), 1, dtype),
        "w_down": dense_init(ks[2], (e, f, d), 1, dtype),
    }
    if cfg.mlp_gated:
        p["w_gate"] = dense_init(ks[3], (e, d, f), 1, dtype)
    return p


def router_probs(cfg: ModelConfig, p, x_flat):
    """x_flat: [T, D] -> (gates [T,k], expert_ids [T,k], aux_loss scalar)."""
    logits = (x_flat @ p["router"]).astype(jnp.float32)        # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, expert_ids = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    if cfg.norm_topk_prob:
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance auxiliary loss
    e = cfg.num_experts
    density = jnp.mean(
        jax.nn.one_hot(expert_ids[:, 0], e, dtype=jnp.float32), axis=0)
    density_proxy = jnp.mean(probs, axis=0)
    aux = jnp.sum(density * density_proxy) * e * cfg.router_aux_coef
    return gates, expert_ids, aux


def _capacity(cfg: ModelConfig, t: int) -> int:
    c = int(t * cfg.num_experts_per_tok * cfg.moe_capacity_factor
            / cfg.num_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8 for tiling


def _dispatch(cfg: ModelConfig, x_flat, gates, expert_ids, cap: int):
    """Sort-based capacity dispatch of [T, D] tokens into [E, C, D].

    Returns (buf, indices) where `indices` carries everything `_combine`
    needs to route expert outputs back to token order.
    """
    t, d = x_flat.shape
    k = cfg.num_experts_per_tok
    e = cfg.num_experts

    flat_expert = expert_ids.reshape(t * k)                     # [T*k]
    flat_token = jnp.repeat(jnp.arange(t), k)                   # [T*k]
    flat_gate = gates.reshape(t * k)

    order = jnp.argsort(flat_expert, stable=True)
    sorted_expert = flat_expert[order]
    # rank within each expert group of the sorted stream
    idx = jnp.arange(t * k)
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_expert[1:] != sorted_expert[:-1]])
    group_start = jax.lax.associative_scan(
        jnp.maximum, jnp.where(is_start, idx, 0))
    pos_in_expert = idx - group_start                           # [T*k]
    keep = pos_in_expert < cap

    src = x_flat[flat_token[order]]                             # [T*k, D]
    buf = jnp.zeros((e, cap, d), x_flat.dtype)
    # dropped tokens get an out-of-bounds position -> mode="drop" discards
    scatter_pos = jnp.where(keep, pos_in_expert, cap)
    buf = buf.at[sorted_expert, scatter_pos].set(src, mode="drop")
    indices = (sorted_expert, pos_in_expert, keep, flat_token[order],
               flat_gate[order])
    return buf, indices


def _combine(out_buf, indices, t: int, dtype):
    """Route [E, C, D] expert outputs back to [T, D] token order."""
    sorted_expert, pos_in_expert, keep, token_order, gate_order = indices
    d = out_buf.shape[-1]
    gather_pos = jnp.where(keep, pos_in_expert, 0)
    gathered = out_buf[sorted_expert, gather_pos]               # [T*k, D]
    gathered = jnp.where(keep[:, None], gathered, 0)
    contrib = gathered * gate_order[:, None]
    return jnp.zeros((t, d), dtype).at[token_order].add(
        contrib.astype(dtype))


def _expert_ffn(cfg: ModelConfig, p, buf):
    """buf: [..., E, C, D] -> [..., E, C, D] through the expert MLPs."""
    up = jnp.einsum("...ecd,edf->...ecf", buf, p["w_up"])
    if cfg.mlp_gated:
        h = _act(cfg.mlp_activation,
                 jnp.einsum("...ecd,edf->...ecf", buf, p["w_gate"])) * up
    else:
        h = _act(cfg.mlp_activation, up)
    return jnp.einsum("...ecf,efd->...ecd", h, p["w_down"])


def apply_moe(cfg: ModelConfig, p, x):
    """x: [B, S, D] -> (y [B,S,D], aux_loss).

    Two dispatch strategies:
      * global (baseline): one sort over all T tokens, buffer [E, C, D].
      * shard-local (perf lever, active when ``moe_dp_chunks() > 1``):
        tokens regrouped [G, T/G, D] with G = number of data shards; each
        shard sorts/scatters its own tokens into [G, E, C/G, D]. The sort
        and scatter become shard-local (no cross-'data' collectives); only
        the expert einsum communicates, as a clean buffer reshard along
        'model' — the GShard all-to-all pattern. See EXPERIMENTS.md §Perf.
    """
    b, s, d = x.shape
    t = b * s
    x_flat = x.reshape(t, d)
    gates, expert_ids, aux = router_probs(cfg, p, x_flat)      # [T,k]

    g = moe_dp_chunks()
    if g > 1 and t % g == 0:
        tl = t // g
        cap = _capacity(cfg, tl)
        xg = constrain(x_flat.reshape(g, tl, d), "gtd")
        gg = gates.reshape(g, tl, -1)
        ig = expert_ids.reshape(g, tl, -1)
        buf, indices = jax.vmap(
            lambda xx, ga, ii: _dispatch(cfg, xx, ga, ii, cap))(xg, gg, ig)
        buf = constrain(buf, "gecd")                            # [G,E,C,D]
        out_buf = constrain(_expert_ffn(cfg, p, buf), "gecd")
        y = jax.vmap(lambda ob, ind: _combine(ob, ind, tl, x.dtype))(
            out_buf, indices)
        return constrain(y, "gtd").reshape(b, s, d), aux

    cap = _capacity(cfg, t)
    buf, indices = _dispatch(cfg, x_flat, gates, expert_ids, cap)
    buf = constrain(buf, "ecd")
    out_buf = constrain(_expert_ffn(cfg, p, buf), "ecd")
    y_flat = _combine(out_buf, indices, t, x.dtype)
    return y_flat.reshape(b, s, d), aux


def apply_moe_dense_eval(cfg: ModelConfig, p, x):
    """Reference: compute every expert densely, combine with gates.

    O(E × full FFN) — only for small-shape correctness tests of the
    capacity-dispatch path.
    """
    b, s, d = x.shape
    x_flat = x.reshape(b * s, d)
    gates, expert_ids, _ = router_probs(cfg, p, x_flat)
    up = jnp.einsum("td,edf->tef", x_flat, p["w_up"])
    if cfg.mlp_gated:
        h = _act(cfg.mlp_activation,
                 jnp.einsum("td,edf->tef", x_flat, p["w_gate"])) * up
    else:
        h = _act(cfg.mlp_activation, up)
    all_out = jnp.einsum("tef,efd->ted", h, p["w_down"])        # [T, E, D]
    mask = jax.nn.one_hot(expert_ids, cfg.num_experts, dtype=gates.dtype)
    weights = jnp.einsum("tk,tke->te", gates, mask)             # [T, E]
    y = jnp.einsum("te,ted->td", weights, all_out.astype(weights.dtype))
    return y.reshape(b, s, d).astype(x.dtype)
