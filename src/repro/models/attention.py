"""GQA attention: training (full/sliding-window causal), prefill and decode.

Decode path operates against a dense KV cache `[B, S_max, KV, hd]` (the
serving engine's paged variant lives in `repro.kernels.paged_attention`; the
dense variant here is what the multi-pod dry-run lowers, with batch sharded on
'data', heads on 'model', and — for long_500k — sequence on 'data').
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.logical import constrain, scan_unroll
from .config import ModelConfig
from .layers import apply_mrope, apply_rope, dense_init

NEG_INF = -1e30

# Full-sequence attention materializes [Sq, Sk] scores; beyond this length
# the train/prefill paths switch to the chunked (flash-style) formulation,
# which keeps the transient at [q_chunk, Sk] per head. TPU-native: XLA does
# not auto-flash, so the blocking is done at the JAX level.
CHUNKED_THRESHOLD = 2048
Q_CHUNK = 512


def init_attention(key, cfg: ModelConfig, dtype=jnp.float32):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h * hd), 0, dtype),
        "wk": dense_init(ks[1], (d, kv * hd), 0, dtype),
        "wv": dense_init(ks[2], (d, kv * hd), 0, dtype),
        "wo": dense_init(ks[3], (h * hd, d), 0, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kv * hd,), dtype)
        p["bv"] = jnp.zeros((kv * hd,), dtype)
    return p


def _project_qkv(cfg: ModelConfig, p, x):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, cfg.num_heads, hd)
    k = k.reshape(b, s, cfg.num_kv_heads, hd)
    v = v.reshape(b, s, cfg.num_kv_heads, hd)
    return q, k, v


def _rotate(cfg: ModelConfig, q, k, positions):
    if cfg.pos_embedding == "rope":
        q = apply_rope(cfg, q, positions)
        k = apply_rope(cfg, k, positions)
    elif cfg.pos_embedding == "mrope":
        q = apply_mrope(cfg, q, positions)
        k = apply_mrope(cfg, k, positions)
    return q, k


def _attend(cfg: ModelConfig, q, k, v, mask):
    """q: [B,Sq,H,hd]; k,v: [B,Sk,KV,hd]; mask: [B,1,Sq,Sk] bool (True=keep)."""
    hd = q.shape[-1]
    groups = cfg.gqa_groups
    b, sq, h, _ = q.shape
    sk = k.shape[1]
    q = q.reshape(b, sq, cfg.num_kv_heads, groups, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    if cfg.attn_logit_softcap:
        c = cfg.attn_logit_softcap
        scores = jnp.tanh(scores / c) * c
    scores = jnp.where(mask[:, :, None] if mask.ndim == 4 else mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v)
    return out.reshape(b, sq, h * hd)


def _attend_chunked(cfg: ModelConfig, q, k, v):
    """Causal attention in query chunks — O(chunk·Sk) transient scores.

    q: [B,S,H,hd]; k,v: [B,S,KV,hd]. KV is head-repeated up front so every
    einsum has a single clean head axis (GQA kv_heads rarely divide the
    'model' mesh axis; q heads shard far better). Each chunk is
    ``jax.checkpoint``ed: the backward pass recomputes its scores instead of
    saving [S,S,H] tensors.
    """
    b, s, h, hd = q.shape
    groups = cfg.gqa_groups
    k = jnp.repeat(k, groups, axis=2)
    v = jnp.repeat(v, groups, axis=2)
    q = constrain(q, "bshd")
    k = constrain(k, "bshd")
    v = constrain(v, "bshd")

    chunk = min(Q_CHUNK, s)
    pad = (-s) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nq = q.shape[1] // chunk
    qc = q.reshape(b, nq, chunk, h, hd)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    kpos = jnp.arange(s)[None, :]

    @jax.checkpoint
    def one_chunk(qi, ci):
        qpos = ci * chunk + jnp.arange(chunk)[:, None]
        m = kpos <= qpos
        if cfg.sliding_window:
            m &= kpos > qpos - cfg.sliding_window
        scores = jnp.einsum("bqhd,bshd->bhqs", qi, k).astype(jnp.float32)
        scores = scores * scale
        if cfg.attn_logit_softcap:
            c = cfg.attn_logit_softcap
            scores = jnp.tanh(scores / c) * c
        scores = jnp.where(m[None, None], scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        out = jnp.einsum("bhqs,bshd->bqhd", w, v)
        return constrain(out, "bshd")

    def body(_, xs):
        qi, ci = xs
        return None, one_chunk(qi, ci)

    _, outs = jax.lax.scan(body, None,
                           (jnp.moveaxis(qc, 1, 0), jnp.arange(nq)),
                           unroll=scan_unroll())
    out = jnp.moveaxis(outs, 0, 1).reshape(b, nq * chunk, h * hd)
    return out[:, :s]


def causal_mask(cfg: ModelConfig, sq: int, sk: int, q_offset=0):
    """[1,1,Sq,Sk] causal (+sliding window) mask."""
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(sk)[None, :]
    m = kpos <= qpos
    if cfg.sliding_window:
        m &= kpos > qpos - cfg.sliding_window
    return m[None, None]


def attention_train(cfg: ModelConfig, p, x, positions,
                    segment_ids: Optional[jax.Array] = None):
    """Full-sequence causal attention. Returns [B,S,D]."""
    q, k, v = _project_qkv(cfg, p, x)
    q, k = _rotate(cfg, q, k, positions)
    s = x.shape[1]
    if segment_ids is None and s > CHUNKED_THRESHOLD:
        return _attend_chunked(cfg, q, k, v) @ p["wo"]
    mask = causal_mask(cfg, s, s)
    if segment_ids is not None:  # packed sequences
        seg = segment_ids[:, :, None] == segment_ids[:, None, :]
        mask = mask & seg[:, None]
    out = _attend(cfg, q, k, v, mask)
    return out @ p["wo"]


def attention_prefill(cfg: ModelConfig, p, x, positions):
    """Causal attention that also returns the (k, v) to seed a cache."""
    q, k, v = _project_qkv(cfg, p, x)
    q, k = _rotate(cfg, q, k, positions)
    s = x.shape[1]
    if s > CHUNKED_THRESHOLD:
        return _attend_chunked(cfg, q, k, v) @ p["wo"], (k, v)
    mask = causal_mask(cfg, s, s)
    out = _attend(cfg, q, k, v, mask)
    return out @ p["wo"], (k, v)


def attention_decode(cfg: ModelConfig, p, x, cache_k, cache_v, positions):
    """One decode step against a dense KV cache.

    x: [B,1,D]; cache_k/v: [B,Smax,KV,hd]; positions: [B] absolute position
    of the new token (== number of tokens already processed).

    The cache may be a *ring buffer*: when ``cfg.sliding_window > 0`` and the
    cache is sized to the window, the write index wraps (`pos % Smax`) and all
    slots holding the last `min(pos+1, Smax)` tokens are attended. RoPE is
    applied at write time with the absolute position, so relative offsets stay
    correct after wraparound. This is what makes ``long_500k`` O(window) for
    dense architectures.

    Returns (out [B,1,D], new_cache_k, new_cache_v).
    """
    b = x.shape[0]
    q, k, v = _project_qkv(cfg, p, x)          # q,k,v: [B,1,·,hd]
    pos2d = positions[:, None]                  # [B,1]
    if cfg.pos_embedding == "mrope":
        pos_in = jnp.broadcast_to(pos2d[..., None], (b, 1, 3))
    else:
        pos_in = pos2d
    q, k = _rotate(cfg, q, k, pos_in)

    smax = cache_k.shape[1]
    write_idx = positions % smax                # ring when Smax == window
    bidx = jnp.arange(b)
    cache_k = cache_k.at[bidx, write_idx].set(k[:, 0])
    cache_v = cache_v.at[bidx, write_idx].set(v[:, 0])

    ctx = positions[:, None] + 1                # tokens now in context
    slot = jnp.arange(smax)[None, :]            # [1,Smax]
    if cfg.sliding_window and cfg.sliding_window < 0x7FFFFFFF:
        window = min(cfg.sliding_window, smax)
    else:
        window = smax
    mask = slot < jnp.minimum(ctx, window)      # valid slots
    out = _attend(cfg, q, cache_k, cache_v, mask[:, None, None, :])
    return out @ p["wo"], cache_k, cache_v
