"""Model configuration covering all six assigned architecture families.

A single ``ModelConfig`` describes dense GQA transformers, MoE transformers,
pure SSM (Mamba2/SSD) stacks, hybrid (parallel attention+SSM) blocks, and the
VLM/audio decoder backbones (whose modality frontends are stubbed per the
assignment; the config only describes the decoder that consumes embeddings).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

ARCH_TYPES = ("dense", "moe", "ssm", "hybrid", "vlm", "audio")
ACTIVATIONS = ("silu", "gelu", "relu2")
POS_EMBEDDINGS = ("rope", "mrope", "sinusoidal", "none")
NORM_TYPES = ("rmsnorm", "layernorm")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # --- identity -----------------------------------------------------------
    name: str = "unnamed"
    arch_type: str = "dense"          # one of ARCH_TYPES
    source: str = ""                  # citation for the architecture

    # --- trunk dimensions ---------------------------------------------------
    num_layers: int = 2
    d_model: int = 256
    vocab_size: int = 512

    # --- attention ----------------------------------------------------------
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0                 # 0 -> d_model // num_heads
    qkv_bias: bool = False
    pos_embedding: str = "rope"
    rope_theta: float = 10000.0
    rope_pct: float = 1.0             # partial rotary (stablelm-2: 0.25)
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # qwen2-vl M-RoPE
    sliding_window: int = 0           # 0 -> full causal attention
    attn_logit_softcap: float = 0.0   # 0 -> disabled

    # --- MLP ----------------------------------------------------------------
    d_ff: int = 0                     # 0 -> no MLP (pure mamba2 stack)
    mlp_activation: str = "silu"
    mlp_gated: bool = True            # SwiGLU / GeGLU when True
    mlp_bias: bool = False

    # --- MoE ----------------------------------------------------------------
    num_experts: int = 0              # 0 -> dense FFN
    num_experts_per_tok: int = 0
    moe_capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    norm_topk_prob: bool = True

    # --- SSM (Mamba2 / SSD) -------------------------------------------------
    ssm_state: int = 0                # N, the SSD state dimension
    ssm_expand: int = 2               # d_inner = ssm_expand * d_model
    ssm_head_dim: int = 64            # P
    ssm_groups: int = 1               # G (B/C groups)
    ssm_conv_width: int = 4
    ssm_chunk: int = 64               # SSD chunk length

    # --- norms / embeddings ------------------------------------------------
    norm_type: str = "rmsnorm"
    norm_eps: float = 1e-6
    embedding_scale: bool = False     # gemma: scale embeds by sqrt(d_model)
    tie_embeddings: bool = False
    logit_softcap: float = 0.0

    # --- serving / context --------------------------------------------------
    max_seq_len: int = 32768

    def __post_init__(self):
        assert self.arch_type in ARCH_TYPES, self.arch_type
        assert self.mlp_activation in ACTIVATIONS, self.mlp_activation
        assert self.pos_embedding in POS_EMBEDDINGS, self.pos_embedding
        assert self.norm_type in NORM_TYPES, self.norm_type
        if self.uses_attention:
            assert self.num_heads % max(self.num_kv_heads, 1) == 0, (
                self.num_heads, self.num_kv_heads)
        if self.num_experts:
            assert 0 < self.num_experts_per_tok <= self.num_experts
        if self.arch_type in ("ssm", "hybrid"):
            assert self.ssm_state > 0

    # --- derived ------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def gqa_groups(self) -> int:
        """Query heads per KV head — the GQA group size the attention
        kernels tile along the sublane dimension."""
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def uses_attention(self) -> bool:
        return self.arch_type != "ssm"

    @property
    def uses_ssm(self) -> bool:
        return self.arch_type in ("ssm", "hybrid")

    @property
    def uses_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def multimodal(self) -> bool:
        """VLM/audio backbones consume precomputed frontend embeddings."""
        return self.arch_type in ("vlm", "audio")

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND roofline maths)."""
        d, hd = self.d_model, self.resolved_head_dim
        n = self.vocab_size * d  # embedding
        if not self.tie_embeddings:
            n += self.vocab_size * d  # lm head
        per_layer = 0
        if self.uses_attention:
            q = d * self.num_heads * hd
            kv = 2 * d * self.num_kv_heads * hd
            o = self.num_heads * hd * d
            per_layer += q + kv + o
            if self.qkv_bias:
                per_layer += (self.num_heads + 2 * self.num_kv_heads) * hd
        if self.uses_ssm:
            di, g, ns, hh = self.d_inner, self.ssm_groups, self.ssm_state, self.ssm_heads
            in_proj = d * (2 * di + 2 * g * ns + hh)
            conv = (di + 2 * g * ns) * self.ssm_conv_width
            per_layer += in_proj + conv + hh * 3 + di + di * d  # A,D,dt_bias,norm,out
        if self.d_ff:
            mults = 3 if self.mlp_gated else 2
            ff = mults * d * self.d_ff
            if self.uses_moe:
                per_layer += self.num_experts * ff + d * self.num_experts
            else:
                per_layer += ff
        per_layer += 2 * d  # norms
        return n + self.num_layers * per_layer

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if not self.uses_moe:
            return self.param_count()
        full = self.param_count()
        mults = 3 if self.mlp_gated else 2
        ff = mults * self.d_model * self.d_ff
        inactive = (self.num_experts - self.num_experts_per_tok) * ff
        return full - self.num_layers * inactive

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced variant of the same family for CPU smoke tests.

    2 layers, d_model<=512, <=4 experts, small vocab/context — preserves the
    family-defining structure (GQA ratio, gating, SSM dims, MoE top-k).
    """
    d = min(cfg.d_model, 256)
    heads = max(2, min(cfg.num_heads, 4))
    kv_ratio = max(1, cfg.num_heads // max(cfg.num_kv_heads, 1))
    kv = max(1, heads // min(kv_ratio, heads))
    experts = min(cfg.num_experts, 4)
    topk = min(cfg.num_experts_per_tok, 2) if experts else 0
    return cfg.replace(
        name=cfg.name + "-smoke",
        num_layers=2,
        d_model=d,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=64 if cfg.head_dim else 0,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        num_experts=experts,
        num_experts_per_tok=topk,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head_dim=min(cfg.ssm_head_dim, 32),
        ssm_chunk=16,
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else 0,
        max_seq_len=256,
    )
