"""Core layers shared by all architecture families.

Functional style: each layer is (init_fn, apply_fn) over plain dict pytrees so
that parameters can be stacked along a leading layer axis and scanned
(`jax.lax.scan`) — this keeps the lowered HLO O(1) in depth, which matters for
the 94-layer dry-run compiles.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig

# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    fan_in = shape[in_axis]
    scale = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def init_norm(cfg: ModelConfig, dim: int):
    p = {"scale": jnp.ones((dim,))}
    if cfg.norm_type == "layernorm":
        p["bias"] = jnp.zeros((dim,))
    return p


def apply_norm(cfg: ModelConfig, p, x):
    dt = x.dtype
    x = x.astype(jnp.float32)
    if cfg.norm_type == "rmsnorm":
        var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
        y = x * jax.lax.rsqrt(var + cfg.norm_eps) * p["scale"]
    else:
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
        y = (x - mu) * jax.lax.rsqrt(var + cfg.norm_eps) * p["scale"] + p["bias"]
    return y.astype(dt)


# ---------------------------------------------------------------------------
# MLP (dense FFN): SwiGLU / GeGLU / gelu / squared-ReLU
# ---------------------------------------------------------------------------


def _act(name: str, x):
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if name == "relu2":  # nemotron-4 squared ReLU
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(name)


def init_mlp(key, cfg: ModelConfig, dtype=jnp.float32):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "w_up": dense_init(ks[0], (d, f), 0, dtype),
        "w_down": dense_init(ks[1], (f, d), 0, dtype),
    }
    if cfg.mlp_gated:
        p["w_gate"] = dense_init(ks[2], (d, f), 0, dtype)
    if cfg.mlp_bias:
        p["b_up"] = jnp.zeros((f,), dtype)
        p["b_down"] = jnp.zeros((d,), dtype)
    return p


def apply_mlp(cfg: ModelConfig, p, x):
    up = x @ p["w_up"]
    if cfg.mlp_bias:
        up = up + p["b_up"]
    if cfg.mlp_gated:
        h = _act(cfg.mlp_activation, x @ p["w_gate"]) * up
    else:
        h = _act(cfg.mlp_activation, up)
    y = h @ p["w_down"]
    if cfg.mlp_bias:
        y = y + p["b_down"]
    return y


# ---------------------------------------------------------------------------
# positions: RoPE, M-RoPE (qwen2-vl), sinusoidal (musicgen)
# ---------------------------------------------------------------------------


def rope_freqs(cfg: ModelConfig, rot_dim: int):
    exponent = jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim
    return 1.0 / (cfg.rope_theta ** exponent)  # [rot_dim//2]


def apply_rope(cfg: ModelConfig, x, positions):
    """x: [B, S, H, hd]; positions: [B, S] int32. Partial rotary supported."""
    hd = x.shape[-1]
    rot_dim = int(hd * cfg.rope_pct) // 2 * 2
    inv = rope_freqs(cfg, rot_dim)
    ang = positions.astype(jnp.float32)[..., None] * inv  # [B,S,rot/2]
    sin = jnp.sin(ang)[:, :, None, :]
    cos = jnp.cos(ang)[:, :, None, :]
    x_rot, x_pass = x[..., :rot_dim], x[..., rot_dim:]
    x1, x2 = jnp.split(x_rot, 2, axis=-1)
    y = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([y, x_pass], axis=-1).astype(x.dtype)


def apply_mrope(cfg: ModelConfig, x, positions3):
    """Qwen2-VL multimodal RoPE.

    positions3: [B, S, 3] — (temporal, height, width) position ids. The
    head_dim/2 frequency slots are split into three sections; each section
    uses its own position stream. For pure text all three streams are equal
    and M-RoPE degenerates to 1-D RoPE.
    """
    hd = x.shape[-1]
    half = hd // 2
    sec = cfg.mrope_sections
    total = sum(sec)
    # scale sections to this head_dim
    sizes = [s * half // total for s in sec]
    sizes[-1] = half - sizes[0] - sizes[1]
    inv = rope_freqs(cfg, hd)  # [half]
    pos = positions3.astype(jnp.float32)  # [B,S,3]
    ang_parts = []
    start = 0
    for i, sz in enumerate(sizes):
        ang_parts.append(pos[..., i:i + 1] * inv[start:start + sz])
        start += sz
    ang = jnp.concatenate(ang_parts, axis=-1)  # [B,S,half]
    sin = jnp.sin(ang)[:, :, None, :]
    cos = jnp.cos(ang)[:, :, None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    y = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return y.astype(x.dtype)


def sinusoidal_embedding(positions, dim: int):
    """[..., ] int positions -> [..., dim] sinusoidal embeddings."""
    half = dim // 2
    freq = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def positions_for(cfg: ModelConfig, positions):
    """Normalize a [B,S] position tensor to what the rope variant needs."""
    if cfg.pos_embedding == "mrope" and positions.ndim == 2:
        return jnp.broadcast_to(positions[..., None], positions.shape + (3,))
    return positions


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------


def init_embedding(key, cfg: ModelConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 2)
    p = {"embedding": embed_init(ks[0], (cfg.vocab_size, cfg.d_model), dtype)}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[1], (cfg.d_model, cfg.vocab_size), 0, dtype)
    return p


def embed_tokens(cfg: ModelConfig, p, tokens):
    x = jnp.take(p["embedding"], tokens, axis=0)
    if cfg.embedding_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def unembed(cfg: ModelConfig, p, x):
    if cfg.tie_embeddings:
        logits = x @ p["embedding"].T
    else:
        logits = x @ p["lm_head"]
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = jnp.tanh(logits / c) * c
    return logits
