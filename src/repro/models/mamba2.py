"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) mixer.

Chunked SSD formulation: within-chunk attention-like quadratic term +
inter-chunk linear recurrence over chunk states. Used by the pure-SSM config
(mamba2-130m) and the hybrid config (hymba-1.5b, parallel attention+SSM
heads). Decode is a constant-size state update — this is why ssm/hybrid archs
run the long_500k shape natively.

A Pallas kernel for the chunked scan lives in `repro.kernels.ssd_scan`; this
module is the pure-jnp reference implementation used for training and the
dry-run lowering.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.logical import scan_unroll
from .config import ModelConfig
from .layers import dense_init, init_norm, apply_norm


# ---------------------------------------------------------------------------
# core SSD scan (head-broadcast B/C, chunked)
# ---------------------------------------------------------------------------


def _segsum(x):
    """x: [..., Q] log-decays -> [..., Q, Q] lower-triangular segment sums.

    out[i, j] = sum_{k=j+1..i} x[k]  (i >= j), -inf above the diagonal.
    """
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, a, b, c, chunk: int,
                initial_state: Optional[jax.Array] = None,
                valid: Optional[jax.Array] = None):
    """Chunked SSD scan.

    x:  [B, S, H, P]   head inputs
    dt: [B, S, H]      discretization steps (post-softplus, >0)
    a:  [H]            negative state decay rates
    b:  [B, S, H, N]   input projections (already head-broadcast)
    c:  [B, S, H, N]   output projections (already head-broadcast)
    initial_state: [B, H, P, N] or None
    valid: [B, S] bool or None — positions marked False get dt forced to 0,
        which makes their state transition an exact identity (decay
        exp(0·a)=1, update dt·B·x=0) and removes them from every other
        position's output. This is what lets right-padded chunk rows ride
        the serving mixed step without polluting the recurrence.

    Returns (y [B, S, H, P], final_state [B, H, P, N]). Outputs at invalid
    positions are unspecified (callers discard them).
    """
    bs, s, h, p = x.shape
    n = b.shape[-1]
    q = chunk
    if valid is not None:
        dt = jnp.where(valid[..., None], dt, 0.0)
    pad = (-s) % q
    if pad:
        zpad = lambda t: jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
        x, dt, b, c = map(zpad, (x, dt, b, c))
    nc = x.shape[1] // q

    xc = x.reshape(bs, nc, q, h, p)
    dtc = dt.reshape(bs, nc, q, h)
    bc = b.reshape(bs, nc, q, h, n)
    cc = c.reshape(bs, nc, q, h, n)

    da = dtc * a  # [B,nc,Q,H] log-decay per step (a < 0)
    da_cs = jnp.cumsum(da, axis=2)                        # [B,nc,Q,H]

    # ---- intra-chunk (quadratic, attention-like) ---------------------------
    lmat = jnp.exp(_segsum(jnp.moveaxis(da, 2, 3)))       # [B,nc,H,Q,Q]
    cb = jnp.einsum("bzihn,bzjhn->bzhij", cc, bc)         # [B,nc,H,Q,Q]
    gate = cb * lmat * jnp.moveaxis(dtc, 2, 3)[..., None, :]
    y_diag = jnp.einsum("bzhij,bzjhp->bzihp", gate.astype(x.dtype), xc)

    # ---- chunk states -------------------------------------------------------
    decay_to_end = jnp.exp(da_cs[:, :, -1:, :] - da_cs)   # [B,nc,Q,H]
    states = jnp.einsum("bzqh,bzqhn,bzqhp->bzhpn",
                        (dtc * decay_to_end).astype(x.dtype), bc, xc)

    # ---- inter-chunk recurrence (scan over chunks) --------------------------
    chunk_decay = jnp.exp(da_cs[:, :, -1, :])             # [B,nc,H]
    if initial_state is None:
        initial_state = jnp.zeros((bs, h, p, n), x.dtype)

    def step(carry, inp):
        dec, st = inp                                      # [B,H], [B,H,P,N]
        carry = carry * dec[:, :, None, None].astype(carry.dtype) + st
        return carry, carry

    _, prev_states = jax.lax.scan(
        step, initial_state,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(states, 1, 0)),
        unroll=scan_unroll())
    # prev_states[z] = state at END of chunk z; we need state BEFORE chunk z
    final_state = prev_states[-1]
    before = jnp.concatenate(
        [initial_state[None], prev_states[:-1]], axis=0)   # [nc,B,H,P,N]
    before = jnp.moveaxis(before, 0, 1)                    # [B,nc,H,P,N]

    # ---- inter-chunk output contribution ------------------------------------
    in_decay = jnp.exp(da_cs)                              # [B,nc,Q,H]
    y_off = jnp.einsum("bzqhn,bzhpn,bzqh->bzqhp",
                       cc, before, in_decay.astype(x.dtype))

    y = (y_diag + y_off).reshape(bs, nc * q, h, p)
    return y[:, :s], final_state


def ssd_decode_step(state, x, dt, a, b, c, valid=None):
    """Single-token SSD recurrence.

    state: [B, H, P, N]; x: [B, H, P]; dt: [B, H]; a: [H];
    b, c: [B, H, N]; valid: [B] bool or None — rows marked False get dt
    forced to 0, so their state update is an exact identity (inert rows in
    the serving mixed step). Returns (y [B,H,P], new_state).
    """
    if valid is not None:
        dt = jnp.where(valid[:, None], dt, 0.0)
    da = jnp.exp(dt * a)                                   # [B,H]
    upd = jnp.einsum("bh,bhn,bhp->bhpn", dt, b, x)
    new_state = state * da[:, :, None, None].astype(state.dtype) + upd.astype(state.dtype)
    y = jnp.einsum("bhpn,bhn->bhp", new_state, c.astype(state.dtype))
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# full Mamba2 mixer block (in_proj -> conv -> SSD -> gated norm -> out_proj)
# ---------------------------------------------------------------------------


def _conv_dim(cfg: ModelConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state


def init_mamba2(key, cfg: ModelConfig, dtype=jnp.float32):
    d = cfg.d_model
    di = cfg.d_inner
    g, n, h = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    proj_out = 2 * di + 2 * g * n + h  # z, x, B, C, dt
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], (d, proj_out), 0, dtype),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv_width, _conv_dim(cfg)), 0, dtype),
        "conv_b": jnp.zeros((_conv_dim(cfg),), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "d_skip": jnp.ones((h,), dtype),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[2], (h,)) * 2.3 - 4.6))).astype(jnp.float32),
        "norm": init_norm(cfg, di),
        "out_proj": dense_init(ks[3], (di, d), 0, dtype),
    }


def _split_proj(cfg: ModelConfig, proj):
    di, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xbc = proj[..., di:di + _conv_dim(cfg)]
    dt = proj[..., di + _conv_dim(cfg):]
    assert dt.shape[-1] == h
    return z, xbc, dt


def _split_xbc(cfg: ModelConfig, xbc):
    di, g, n = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state
    x = xbc[..., :di]
    b = xbc[..., di:di + g * n]
    c = xbc[..., di + g * n:]
    return x, b, c


def _causal_conv(p, xbc, conv_state=None, valid_len=None):
    """Depthwise causal conv. xbc: [B, S, C]. conv_state: [B, W-1, C] tail.

    ``valid_len`` (scalar or [B], <= S) marks only the first ``valid_len``
    positions as real input: the returned state is the W-1 tail of the
    *valid* stream (prev state ++ xbc[:valid_len]), so right-padded rows
    never leak into the next segment's receptive field. Conv outputs at
    padded positions are unspecified (callers discard them)."""
    w = p["conv_w"].shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], w - 1, xbc.shape[-1]), xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)               # [B, S+W-1, C]
    out = sum(xp[:, i:i + xbc.shape[1]] * p["conv_w"][i] for i in range(w))
    out = out + p["conv_b"]
    if w == 1:
        new_state = pad
    elif valid_len is None:
        new_state = xp[:, -(w - 1):]
    else:
        # tail of the valid stream: xp[b, vl : vl + W-1] (vl == S reproduces
        # the unmasked slice above)
        vl = jnp.broadcast_to(jnp.asarray(valid_len), (xbc.shape[0],))
        new_state = jax.vmap(
            lambda row, n: jax.lax.dynamic_slice_in_dim(row, n, w - 1, 0)
        )(xp, vl)
    return jax.nn.silu(out), new_state


def _head_broadcast(cfg: ModelConfig, bc):
    """[B, S, G*N] -> [B, S, H, N] broadcasting groups to heads."""
    bs, s, _ = bc.shape
    g, n, h = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    bc = bc.reshape(bs, s, g, n)
    return jnp.repeat(bc, h // g, axis=2)


def mamba2_forward(cfg: ModelConfig, p, x_in, initial=None, valid_len=None):
    """x_in: [B, S, D] -> (y [B,S,D], (conv_state, ssd_state)).

    ``valid_len`` (scalar or [B]) treats only the first ``valid_len``
    positions as real tokens: padded tail positions get dt masked to zero
    (identity SSD transition) and are excluded from the conv state, so the
    returned states equal those of a scan over the unpadded sequence.
    Outputs at padded positions are unspecified."""
    bs, s, _ = x_in.shape
    h, pp = cfg.ssm_heads, cfg.ssm_head_dim
    proj = x_in @ p["in_proj"]
    z, xbc, dt_raw = _split_proj(cfg, proj)
    conv_state_in = initial[0] if initial is not None else None
    ssd_state_in = initial[1] if initial is not None else None
    xbc, conv_state = _causal_conv(p, xbc, conv_state_in, valid_len)
    xs, b, c = _split_xbc(cfg, xbc)
    xs = xs.reshape(bs, s, h, pp)
    bh = _head_broadcast(cfg, b)
    ch = _head_broadcast(cfg, c)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    valid = None
    if valid_len is not None:
        vl = jnp.broadcast_to(jnp.asarray(valid_len), (bs,))
        valid = jnp.arange(s)[None, :] < vl[:, None]
    y, ssd_state = ssd_chunked(xs, dt, a, bh, ch, cfg.ssm_chunk, ssd_state_in,
                               valid=valid)
    y = y + xs * p["d_skip"][None, None, :, None].astype(y.dtype)
    y = y.reshape(bs, s, cfg.d_inner)
    y = apply_norm(cfg, p["norm"], y * jax.nn.silu(z))
    return y @ p["out_proj"], (conv_state, ssd_state)


def mamba2_decode(cfg: ModelConfig, p, x_in, conv_state, ssd_state,
                  valid=None):
    """One-token decode. x_in: [B, 1, D]; conv_state: [B, W-1, C];
    ssd_state: [B, H, P, N]; valid: [B] bool or None — rows marked False
    keep BOTH states bit-identical (inert rows in the serving mixed step).
    Returns (y [B,1,D], conv_state, ssd_state)."""
    bs = x_in.shape[0]
    h, pp = cfg.ssm_heads, cfg.ssm_head_dim
    proj = x_in @ p["in_proj"]                              # [B,1,·]
    z, xbc, dt_raw = _split_proj(cfg, proj)
    conv_state_in = conv_state
    xbc, conv_state = _causal_conv(p, xbc, conv_state)
    if valid is not None:
        conv_state = jnp.where(valid[:, None, None], conv_state,
                               conv_state_in.astype(conv_state.dtype))
    xs, b, c = _split_xbc(cfg, xbc)
    xs1 = xs[:, 0].reshape(bs, h, pp)
    bh = _head_broadcast(cfg, b)[:, 0]                      # [B,H,N]
    ch = _head_broadcast(cfg, c)[:, 0]
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    y, ssd_state = ssd_decode_step(ssd_state, xs1, dt, a, bh, ch, valid=valid)
    y = y + xs1 * p["d_skip"][None, :, None].astype(y.dtype)
    y = y.reshape(bs, 1, cfg.d_inner)
    y = apply_norm(cfg, p["norm"], y * jax.nn.silu(z))
    return y @ p["out_proj"], conv_state, ssd_state


def init_mamba2_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    conv = jnp.zeros((batch, cfg.ssm_conv_width - 1, _conv_dim(cfg)), dtype)
    ssd = jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                    dtype)
    return conv, ssd
