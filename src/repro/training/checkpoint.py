"""Checkpointing: pytree <-> flat .npz with path-keyed arrays."""
from __future__ import annotations

import os
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree, prefix="") -> Dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix.rstrip("/")] = np.asarray(tree)
    return out


def save_checkpoint(path: str, tree: Any) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **_flatten(tree))


def load_checkpoint(path: str, like: Any = None) -> Any:
    """Load. With ``like`` given, restores that pytree's exact structure."""
    data = dict(np.load(path))
    if like is None:
        # rebuild nested dicts from slash paths
        root: Dict[str, Any] = {}
        for key, arr in data.items():
            parts = key.split("/")
            node = root
            for p in parts[:-1]:
                node = node.setdefault(p, {})
            node[parts[-1]] = jnp.asarray(arr)
        return root
    flat_like = _flatten(like)
    assert set(flat_like) == set(data), (
        "checkpoint keys mismatch: "
        f"missing={set(flat_like) - set(data)} "
        f"extra={set(data) - set(flat_like)}")
    leaves, treedef = jax.tree.flatten(like)
    keys = list(_flatten_keys(like))
    assert len(keys) == len(leaves)
    restored = [jnp.asarray(data[k]) for k in keys]
    return treedef.unflatten(restored)


def _flatten_keys(tree, prefix=""):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _flatten_keys(tree[k], f"{prefix}{k}/")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _flatten_keys(v, f"{prefix}{i}/")
    else:
        yield prefix.rstrip("/")
