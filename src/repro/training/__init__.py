from .checkpoint import load_checkpoint, save_checkpoint
from .optimizer import AdamWConfig, adamw_update, init_opt_state
from .train_loop import (hidden_states, make_train_step, train_lm,
                         train_prm_head)

__all__ = ["load_checkpoint", "save_checkpoint", "AdamWConfig",
           "adamw_update", "init_opt_state", "hidden_states",
           "make_train_step", "train_lm", "train_prm_head"]
