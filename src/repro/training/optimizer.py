"""AdamW + cosine/warmup schedules — pure JAX, no optax dependency."""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    grad_clip: float = 1.0


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init_opt_state(params) -> dict:
    zeros = lambda p: jax.tree.map(jnp.zeros_like, p)
    return {"mu": zeros(params), "nu": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))


def adamw_update(cfg: AdamWConfig, params, grads, state
                 ) -> Tuple[Any, dict, jax.Array]:
    """Returns (new_params, new_state, grad_norm)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g * scale, grads)
    step = state["step"] + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * jnp.square(g32)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:   # decay matrices only (norms/bias exempt)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["mu"])
    flat_v = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_m, "nu": new_v, "step": step}, gnorm
