"""Training loop: LM training for the reasoner + PRM-head training.

``make_train_step`` builds the jit'd (loss, grads, AdamW) step used both by
the CPU examples (tiny reasoner) and by the multi-pod dry-run (where it is
pjit-sharded by ``repro.launch``).
"""
from __future__ import annotations

import functools
import time
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.prm import init_prm_head, prm_head_loss
from ..models import Model, cross_entropy_loss
from .optimizer import AdamWConfig, adamw_update, init_opt_state


def lm_loss_fn(model: Model, params, batch) -> Tuple[jax.Array, Dict]:
    labels, mask = batch["labels"], batch["mask"]
    logits, aux = model.forward(params, tokens=batch.get("tokens"),
                                embeds=batch.get("embeds"))
    loss = cross_entropy_loss(logits, labels, mask)
    total = loss + aux
    return total, {"loss": loss, "aux_loss": aux}


def make_train_step(model: Model, opt_cfg: AdamWConfig,
                    loss_fn: Optional[Callable] = None):
    loss_fn = loss_fn or lm_loss_fn

    def train_step(params, opt_state, batch):
        (_, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(model, p, batch), has_aux=True)(params)
        params, opt_state, gnorm = adamw_update(opt_cfg, params, grads,
                                                opt_state)
        metrics["grad_norm"] = gnorm
        return params, opt_state, metrics

    return train_step


def train_lm(model: Model, data_iter, steps: int,
             opt_cfg: Optional[AdamWConfig] = None, seed: int = 0,
             log_every: int = 50, params=None,
             logger: Optional[Callable] = None):
    """Train the reasoner LM. Returns (params, history)."""
    opt_cfg = opt_cfg or AdamWConfig(total_steps=steps)
    if params is None:
        params = model.init_params(jax.random.PRNGKey(seed))
    opt_state = init_opt_state(params)
    step_fn = jax.jit(make_train_step(model, opt_cfg))
    history = []
    for i in range(steps):
        toks, labels, mask = next(data_iter)
        batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels),
                 "mask": jnp.asarray(mask)}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if i % log_every == 0 or i == steps - 1:
            rec = {k: float(v) for k, v in metrics.items()}
            rec["step"] = i
            history.append(rec)
            if logger:
                logger(rec)
    return params, history


# ------------------------------------------------------------- PRM head


def hidden_states(model: Model, params, tokens) -> jax.Array:
    """Final-norm hidden states [B, S, D] (the decode path's PRM input)."""
    from ..models.layers import apply_norm
    mc = model.cfg
    x = model._embed_inputs(params, tokens, None)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    if mc.pos_embedding == "mrope":
        positions = jnp.broadcast_to(positions[..., None], (b, s, 3))

    def body(x, layer_p):
        x, _ = model._layer_train(layer_p, x, positions)
        return x, None

    x, _ = jax.lax.scan(body, x, params["layers"])
    return apply_norm(mc, params["final_norm"], x)


def train_prm_head(model: Model, lm_params, data_iter, steps: int,
                   lr: float = 1e-2, seed: int = 0,
                   logger: Optional[Callable] = None):
    """Fit the reward head on frozen LM hidden states (BCE)."""
    from ..core.prm import reward_logit
    head = init_prm_head(jax.random.PRNGKey(seed), model.cfg.d_model)

    @jax.jit
    def step(head, tokens, labels, mask):
        h = hidden_states(model, lm_params, tokens)

        def loss(hp):
            logit = reward_logit(hp, h.astype(jnp.float32))
            bce = (jnp.maximum(logit, 0) - logit * labels
                   + jnp.log1p(jnp.exp(-jnp.abs(logit))))
            return jnp.sum(bce * mask) / jnp.maximum(jnp.sum(mask), 1.0)

        l, g = jax.value_and_grad(loss)(head)
        head = jax.tree.map(lambda p, gg: p - lr * gg, head, g)
        return head, l

    history = []
    for i in range(steps):
        toks, labels, mask = next(data_iter)
        head, l = step(head, jnp.asarray(toks), jnp.asarray(labels),
                       jnp.asarray(mask))
        if i % 50 == 0 or i == steps - 1:
            rec = {"step": i, "prm_loss": float(l)}
            history.append(rec)
            if logger:
                logger(rec)
    return head, history
