"""Pallas TPU flash-attention (forward) for the prefill phase.

SART's prefill cost is paid once per *request* (the N branches fork off the
shared prefix KV), so prefill latency directly gates queuing delay when the
branch queue runs dry (Algorithm 1 line 7). This kernel computes causal
attention without materializing [Sq, Sk] scores:

  grid = (batch, q_heads, q_blocks, kv_blocks)   — kv minor, sequential
  VMEM scratch (m, l, acc) carries the online softmax across kv blocks;
  causal block skipping via pl.when (a kv block strictly above the diagonal
  contributes nothing and is not computed).

KV is expected head-repeated to q_heads (GQA groups expanded), matching the
jnp chunked path in `repro.models.attention`. MXU alignment: block sizes
default to 256/512 with head_dim padded to 128 multiples in production
configs. Validated against ref.py in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, out_ref, m_ref, l_ref, acc_ref, *,
                  bq: int, bk: int, scale: float, causal: bool):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * bq
    k_start = ki * bk
    # causal: skip kv blocks strictly above the diagonal
    live = (not causal) or (k_start <= q_start + bq - 1)

    @pl.when(live)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale   # [bq, hd]
        k = k_ref[0, :, 0, :].astype(jnp.float32)           # [bk, hd]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [bq, bk]
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        out_ref[0, :, 0, :] = (acc_ref[...] / denom).astype(out_ref.dtype)


def flash_prefill(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, block_q: int = 256,
                  block_k: int = 256, interpret: bool = False) -> jax.Array:
    """q, k, v: [B, S, H, hd] (KV already head-repeated). Returns [B,S,H,hd].

    S must divide by the block sizes (callers pad; production shapes are
    powers of two)."""
    b, s, h, hd = q.shape
    bq = min(block_q, s)
    bk = min(block_k, s)
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    scale = 1.0 / (hd ** 0.5)
    grid = (b, h, s // bq, s // bk)

    q_spec = pl.BlockSpec((1, bq, 1, hd), lambda bi, hi, qi, ki: (bi, qi, hi, 0))
    k_spec = pl.BlockSpec((1, bk, 1, hd), lambda bi, hi, qi, ki: (bi, ki, hi, 0))

    kernel = pl.pallas_call(
        functools.partial(_flash_kernel, bq=bq, bk=bk, scale=scale,
                          causal=causal),
        grid=grid,
        in_specs=[q_spec, k_spec, k_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )
    return kernel(q, k, v)
