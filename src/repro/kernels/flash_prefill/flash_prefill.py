"""Pallas TPU flash-attention (forward) for the prefill phase.

SART's prefill cost is paid once per *request* (the N branches fork off the
shared prefix KV), so prefill latency directly gates queuing delay when the
branch queue runs dry (Algorithm 1 line 7). This kernel computes causal
attention without materializing [Sq, Sk] scores:

  grid = (batch, q_heads, q_blocks, kv_blocks)   — kv minor, sequential
  VMEM scratch (m, l, acc) carries the online softmax across kv blocks;
  causal block skipping via pl.when (a kv block strictly above the diagonal
  contributes nothing and is not computed).

KV is GQA-native: ``[B, S, Hkv, hd]`` with ``Hkv`` dividing the query head
count — the BlockSpec index map picks the head group (``hi // group``), so
callers never pre-repeat KV heads (which would double KV HBM traffic).
Sequences that don't divide the block sizes are padded internally; padded
query rows carry an explicit validity mask and emit exact zeros, and padded
key columns are masked out of every softmax (a fully-masked row would
otherwise normalize garbage — exp(-inf - -inf) = 1 — into its output).
MXU alignment: block sizes default to 256/512 with head_dim padded to 128
multiples in production configs. Validated against ref.py in interpret mode.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..introspect import BlockMapping, KernelGrid, block_specs

NEG_INF = -1e30


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def flash_prefill_grid(
    b: int,
    s: int,
    h: int,
    hd: int,
    hkv: int,
    *,
    block_q: int = 256,
    block_k: int = 256,
) -> KernelGrid:
    """Launch geometry for :func:`flash_prefill`.

    Array shapes are the *padded* shapes (``s`` rounded up to the chosen
    block sizes) — :func:`flash_prefill` pads its operands to match before
    launching. The kv index map selects the GQA head group (``hi //
    group``) so callers never pre-repeat KV heads.
    """
    assert h % hkv == 0, (h, hkv)
    group = h // hkv
    bq = min(block_q, _round_up(s, 8))
    bk = min(block_k, _round_up(s, 8))
    sq_p = _round_up(s, bq)
    sk_p = _round_up(s, bk)

    def q_index(bi: int, hi: int, qi: int, ki: int) -> Tuple[int, ...]:
        return (bi, qi, hi, 0)

    def kv_index(bi: int, hi: int, qi: int, ki: int) -> Tuple[int, ...]:
        return (bi, ki, hi // group, 0)

    q_map = BlockMapping("q", (b, sq_p, h, hd), (1, bq, 1, hd), q_index)
    kv_shape = (b, sk_p, hkv, hd)
    kv_block = (1, bk, 1, hd)
    return KernelGrid(
        kernel="flash_prefill",
        grid=(b, h, sq_p // bq, sk_p // bk),
        in_mappings=(
            q_map,
            BlockMapping("k", kv_shape, kv_block, kv_index),
            BlockMapping("v", kv_shape, kv_block, kv_index),
        ),
        out_mappings=(dataclasses.replace(q_map, name="out"),),
    )


def _flash_kernel(q_ref: Any, k_ref: Any, v_ref: Any, out_ref: Any,
                  m_ref: Any, l_ref: Any, acc_ref: Any, *,
                  bq: int, bk: int, scale: float, causal: bool,
                  s_q: int, s_k: int) -> None:
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init() -> None:
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * bq
    k_start = ki * bk
    # skip kv blocks with no visible keys: strictly above the causal
    # diagonal, or entirely inside the kv padding; whole-pad q blocks are
    # skipped too (their rows are zeroed in _finalize regardless)
    live = (k_start < s_k) & (q_start < s_q)
    if causal:
        live &= k_start <= q_start + bq - 1

    @pl.when(live)
    def _compute() -> None:
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale   # [bq, hd]
        k = k_ref[0, :, 0, :].astype(jnp.float32)           # [bk, hd]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [bq, bk]
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < s_k
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            mask &= kpos <= qpos
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize() -> None:
        denom = jnp.maximum(l_ref[...], 1e-30)
        # row validity: pad rows (>= s_q) hold either attention over garbage
        # query values or — when fully masked — the exp(-inf - -inf) = 1
        # mis-normalized residue; emit exact zeros for them instead
        rows = q_start + jax.lax.broadcasted_iota(
            jnp.int32, (bq, 1), 0)
        out = jnp.where(rows < s_q, acc_ref[...] / denom, 0.0)
        out_ref[0, :, 0, :] = out.astype(out_ref.dtype)


def flash_prefill(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, block_q: int = 256,
                  block_k: int = 256, interpret: bool = False,
                  true_len: int | None = None) -> jax.Array:
    """q: [B, S, H, hd]; k, v: [B, S, Hkv, hd] with Hkv | H (GQA-native,
    no pre-repeat). Returns [B, S, H, hd].

    S need not divide the block sizes — inputs are padded internally and
    the pad region is masked (keys) / zeroed (query rows). ``true_len``
    optionally marks a caller-padded sequence: rows at positions >=
    ``true_len`` return exact zeros and keys there are never attended."""
    b, s, h, hd = q.shape
    kb, sk, hkv, khd = k.shape
    assert (kb, sk, khd) == (b, s, hd), (q.shape, k.shape)
    assert k.shape == v.shape, (k.shape, v.shape)
    assert h % hkv == 0, (h, hkv)
    s_true = s if true_len is None else true_len
    assert 0 < s_true <= s, (s_true, s)

    kg = flash_prefill_grid(b, s, h, hd, hkv,
                            block_q=block_q, block_k=block_k)
    bq = kg.in_mappings[0].block_shape[1]
    bk = kg.in_mappings[1].block_shape[1]
    sq_p = kg.in_mappings[0].array_shape[1]
    sk_p = kg.in_mappings[1].array_shape[1]
    pad_q = sq_p - s
    pad_k = sk_p - s
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    scale = 1.0 / (hd ** 0.5)

    kernel = pl.pallas_call(
        functools.partial(_flash_kernel, bq=bq, bk=bk, scale=scale,
                          causal=causal, s_q=s_true, s_k=s_true),
        grid=kg.grid,
        in_specs=block_specs(kg.in_mappings),
        out_specs=block_specs(kg.out_mappings)[0],
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )
    out = kernel(q, k, v)
    return out[:, :s]
