"""Jit'd public wrappers for the flash prefill kernels (dense and paged).

On CPU (this container) the Pallas kernel bodies execute via
``interpret=True`` (or the pure-jnp refs with ``use_kernel=False``, which is
what the live engine runs); on TPU the same ``pallas_call``s compile to
Mosaic.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from .flash_prefill import flash_prefill
from .paged_prefill import paged_flash_prefill_fwd
from .ref import flash_prefill_ref, paged_flash_prefill_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "use_kernel"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, block_q: int = 256,
                    block_k: int = 256,
                    use_kernel: bool = True) -> jax.Array:
    """Flash prefill attention. q: [B,S,H,hd]; k, v: [B,S,Hkv,hd] with
    Hkv | H (GQA heads are indexed inside the kernel — never pre-repeat).
    Non-divisible S is padded inside the kernel wrapper for causal and
    non-causal alike."""
    if not use_kernel:
        return flash_prefill_ref(q, k, v, causal=causal)
    return flash_prefill(q, k, v, causal=causal, block_q=block_q,
                         block_k=block_k, interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("block_q", "use_kernel"))
def paged_flash_prefill(q: jax.Array, k_pages: jax.Array,
                        v_pages: jax.Array, block_table: jax.Array,
                        pos0: jax.Array, valid_len: jax.Array,
                        block_q: int = 128,
                        use_kernel: bool = True) -> jax.Array:
    """Fused mixed-step chunk attention: one flash pass of the chunk's query
    rows [T, H, hd] over a request's paged KV (see ``paged_prefill``).

    ``pos0`` is the absolute position of chunk row 0, ``valid_len`` the
    number of non-pad rows (rows past it return exact zeros). T is padded
    to the q-block size internally.
    """
    if not use_kernel:
        return paged_flash_prefill_ref(q, k_pages, v_pages, block_table,
                                       pos0, valid_len)
    t = q.shape[0]
    bq = min(block_q, t)
    pad = (-t) % bq
    if pad:
        # appended rows sit past valid_len, so the kernel zeroes them
        q = jnp.pad(q, ((0, pad), (0, 0), (0, 0)))
    out = paged_flash_prefill_fwd(q, k_pages, v_pages, block_table, pos0,
                                  valid_len, block_q=bq,
                                  interpret=not _on_tpu())
    return out[:t]


def mixed_step_bytes_read(chunk: int, pos0: int, page_size: int,
                          kv_heads: int, head_dim: int, *, path: str,
                          block_q: int = 128, itemsize: int = 4) -> int:
    """Analytic K+V HBM bytes the chunk-row attention of one mixed step
    reads (the memory-bound quantity on the TPU decode roofline).

    ``path="decode"`` is the per-token flash-decode loop: every chunk row
    streams its whole visible context. ``path="fused"`` is the paged
    flash-prefill kernel: each q block streams the context once, and pages
    past a block's causal horizon are never fetched (the index map parks
    them on a resident page).
    """
    if path == "decode":
        pages = sum(math.ceil((pos0 + i + 1) / page_size)
                    for i in range(chunk))
    elif path == "fused":
        bq = min(block_q, chunk)
        pages = sum(
            math.ceil((pos0 + min((qi + 1) * bq, chunk)) / page_size)
            for qi in range(math.ceil(chunk / bq)))
    else:
        raise ValueError(f"unknown mixed-step path {path!r}")
    return 2 * pages * page_size * kv_heads * head_dim * itemsize
