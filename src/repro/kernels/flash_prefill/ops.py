"""Jit'd public wrapper for the flash prefill kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .flash_prefill import flash_prefill
from .ref import flash_prefill_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "use_kernel"))
def flash_attention(q, k, v, causal: bool = True, block_q: int = 256,
                    block_k: int = 256, use_kernel: bool = True):
    """Flash prefill attention; pads S to the block size."""
    if not use_kernel:
        return flash_prefill_ref(q, k, v, causal=causal)
    s = q.shape[1]
    bq = min(block_q, max(s, 8))
    bk = min(block_k, max(s, 8))
    pad = max((-s) % bq, (-s) % bk)
    if pad:
        # causal masking keeps real queries away from padded keys; padded
        # query rows are sliced off below (padding is causal-only)
        assert causal, "seq padding requires causal masking"
        zp = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q, k, v = zp(q), zp(k), zp(v)
    out = flash_prefill(q, k, v, causal=causal, block_q=bq, block_k=bk,
                        interpret=not _on_tpu())
    return out[:, :s]
