"""Pure-jnp oracles for the flash prefill kernels (dense and paged)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_prefill_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                      causal: bool = True) -> jax.Array:
    """q: [B, S, H, hd]; k, v: [B, S, Hkv, hd] with Hkv | H (GQA-native).
    Returns [B, S, H, hd] (full softmax attention)."""
    hd = q.shape[-1]
    s = q.shape[1]
    h, hkv = q.shape[2], k.shape[2]
    assert h % hkv == 0, (h, hkv)
    if hkv != h:
        k = jnp.repeat(k, h // hkv, axis=2)
        v = jnp.repeat(v, h // hkv, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(
        jnp.asarray(hd, jnp.float32))
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        scores = jnp.where(mask[None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)


def paged_flash_prefill_ref(q: jax.Array, k_pages: jax.Array,
                            v_pages: jax.Array, block_table: jax.Array,
                            pos0: jax.Array,
                            valid_len: jax.Array) -> jax.Array:
    """Oracle for ``paged_prefill.paged_flash_prefill_fwd`` (same shapes).

    Gathers the request's pages into one contiguous [S, kv, hd] context and
    runs masked softmax attention for every chunk row: row i (at absolute
    position pos0 + i) sees keys at positions <= pos0 + i; rows >= valid_len
    are bucket padding and return exact zeros. Sentinel block-table entries
    are clamped — their positions lie beyond every valid row's causal
    horizon, so the garbage they gather is always masked. O(T·S) memory,
    correctness-only.
    """
    t, q_heads, head_dim = q.shape
    kv_heads, num_pages, page_size, _ = k_pages.shape
    group = q_heads // kv_heads
    s_max = block_table.shape[0] * page_size

    bt = jnp.clip(block_table, 0, num_pages - 1)
    k = k_pages[:, bt].reshape(kv_heads, s_max, head_dim)
    v = v_pages[:, bt].reshape(kv_heads, s_max, head_dim)

    qg = q.reshape(t, kv_heads, group, head_dim).astype(jnp.float32)
    scores = jnp.einsum("tkgd,ksd->tkgs", qg, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(jnp.asarray(head_dim, jnp.float32))
    qpos = pos0 + jnp.arange(t)
    mask = jnp.arange(s_max)[None, :] <= qpos[:, None]       # [T, S]
    scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("tkgs,ksd->tkgd", w, v.astype(jnp.float32))
    out = jnp.where((jnp.arange(t) < valid_len)[:, None, None, None],
                    out, 0.0)
    return out.reshape(t, q_heads, head_dim).astype(q.dtype)
