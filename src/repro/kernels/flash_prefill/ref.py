"""Pure-jnp oracle for the flash prefill kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_prefill_ref(q, k, v, causal: bool = True):
    """q, k, v: [B, S, H, hd] -> [B, S, H, hd] (full softmax attention)."""
    hd = q.shape[-1]
    s = q.shape[1]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(
        jnp.asarray(hd, jnp.float32))
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        scores = jnp.where(mask[None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)
