from .ops import flash_attention, mixed_step_bytes_read, paged_flash_prefill

__all__ = ["flash_attention", "mixed_step_bytes_read", "paged_flash_prefill"]
