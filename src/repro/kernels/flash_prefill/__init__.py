from .flash_prefill import flash_prefill_grid
from .ops import flash_attention, mixed_step_bytes_read, paged_flash_prefill
from .paged_prefill import paged_prefill_grid

__all__ = ["flash_attention", "flash_prefill_grid", "mixed_step_bytes_read",
           "paged_flash_prefill", "paged_prefill_grid"]
