"""Pallas TPU fused paged flash-prefill for the mixed decode+prefill step.

The serving engine admits prompts chunk-by-chunk as extra rows of the decode
step (docs/scheduling.md). Before this kernel, each chunk row re-used the
per-token flash-decode path: every row streamed the request's *entire* paged
context from HBM, an O(chunk · context) read that gates time-to-first-branch
— the quantity SART's redundant sampling with early stopping (Algorithm 1)
needs small to keep the branch queue fed.

This kernel block-processes the whole chunk against the paged KV in one
flash pass:

  * grid = (kv_heads, q_blocks, kv_pages) — the page axis is minor and
    sequential; VMEM scratch (m, l, acc) carries the online softmax across
    page blocks, so each q block streams the context once instead of once
    per row.
  * The request's block table and a (pos0, valid_len) descriptor are
    scalar-prefetched (``PrefetchScalarGridSpec``); the K/V index map chases
    the table exactly like the flash-decode kernel, and clamps dead
    iterations (pages past the q block's causal horizon, sentinel table
    entries) onto an already-fetched page so the pipeline re-uses the
    buffer instead of DMA'ing pages that contribute nothing.
  * Causal masking is against true absolute positions: chunk row i sits at
    position pos0 + i and sees keys at positions <= pos0 + i — the prefix
    plus the causally-visible part of the chunk itself (whose K/V the mixed
    step scatters before attention runs).
  * Rows at i >= valid_len are bucket padding: a validity mask keeps them
    out of every softmax claim and the epilogue writes exact zeros for
    them (never the exp(-inf - -inf) = 1 mis-normalized residue).

The GQA group rides the sublane dimension next to the q rows ([bq, group,
hd] blocks flattened to [bq·group, hd] for the MXU), mirroring the decode
kernel's layout. Validated in ``interpret=True`` mode on CPU against
``ref.paged_flash_prefill_ref``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..introspect import BlockMapping, KernelGrid, block_specs

NEG_INF = -1e30


def paged_prefill_grid(
    t: int,
    q_heads: int,
    head_dim: int,
    kv_heads: int,
    num_pages: int,
    page_size: int,
    pages_per_seq: int,
    *,
    block_q: int = 128,
) -> KernelGrid:
    """Launch geometry for :func:`paged_flash_prefill_fwd`.

    Scalar-prefetch operands (appended to every index map after the grid
    indices): ``bt`` — [pages_per_seq] int32 block table, ``info`` — [2]
    int32 (pos0, valid_len). The K/V index map chases the table and clamps
    both dead iterations (past the q block's causal horizon) and sentinel
    entries onto already-resident pages.
    """
    assert q_heads % kv_heads == 0, (q_heads, kv_heads)
    group = q_heads // kv_heads
    bq = min(block_q, t)
    assert t % bq == 0, (t, bq)

    def q_index(h: int, qi: int, ki: int, bt: Any,
                info: Any) -> Tuple[int, ...]:
        return (h, qi, 0, 0)

    def kv_index(h: int, qi: int, ki: int, bt: Any,
                 info: Any) -> Tuple[Any, ...]:
        # park iterations past the q block's causal horizon on its last
        # live page, and clamp sentinel entries into range — both read
        # already-resident pages, so skipped grid steps move no bytes
        max_kpos = info[0] + jnp.minimum((qi + 1) * bq, info[1]) - 1
        ki_live = jnp.minimum(ki, jnp.maximum(max_kpos, 0) // page_size)
        return (h, jnp.minimum(bt[ki_live], num_pages - 1), 0, 0)

    q_map = BlockMapping("q", (kv_heads, t, group, head_dim),
                         (1, bq, group, head_dim), q_index)
    kv_shape = (kv_heads, num_pages, page_size, head_dim)
    kv_block = (1, 1, page_size, head_dim)
    return KernelGrid(
        kernel="paged_flash_prefill",
        grid=(kv_heads, t // bq, pages_per_seq),
        in_mappings=(
            q_map,
            BlockMapping("k_pages", kv_shape, kv_block, kv_index),
            BlockMapping("v_pages", kv_shape, kv_block, kv_index),
        ),
        out_mappings=(dataclasses.replace(q_map, name="out"),),
        num_scalar_prefetch=2,
    )


def _paged_prefill_kernel(
    # scalar-prefetch refs
    block_table_ref: Any,  # [pages_per_seq] int32 (sentinels >= npages)
    info_ref: Any,         # [2] int32: (pos0, valid_len)
    # inputs
    q_ref: Any,            # [1, bq, group, head_dim]
    k_ref: Any,            # [1, 1, page_size, head_dim]
    v_ref: Any,            # [1, 1, page_size, head_dim]
    # outputs
    out_ref: Any,          # [1, bq, group, head_dim]
    # scratch
    m_ref: Any,            # [bq * group, 1] f32
    l_ref: Any,            # [bq * group, 1] f32
    acc_ref: Any,          # [bq * group, head_dim] f32
    *,
    bq: int,
    group: int,
    page_size: int,
    scale: float,
) -> None:
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)
    pos0 = info_ref[0]
    valid_len = info_ref[1]
    q_start = qi * bq
    k_start = ki * page_size

    @pl.when(ki == 0)
    def _init() -> None:
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # last key position any valid row of this q block can see; pages past it
    # (and whole-pad q blocks) are skipped — the index map already parked
    # their DMA on a live page
    max_qpos = pos0 + jnp.minimum(q_start + bq, valid_len) - 1
    live = (q_start < valid_len) & (k_start <= max_qpos)

    @pl.when(live)
    def _compute() -> None:
        q = q_ref[0].astype(jnp.float32).reshape(bq * group, -1) * scale
        k = k_ref[0, 0].astype(jnp.float32)                 # [P, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [bq*G, P]
        row = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // group
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        # causal against absolute positions + bucket-pad row validity
        mask = (kpos <= pos0 + q_start + row) & (q_start + row < valid_len)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize() -> None:
        denom = jnp.maximum(l_ref[...], 1e-30)
        row = jax.lax.broadcasted_iota(
            jnp.int32, (bq * group, 1), 0) // group
        out = jnp.where(q_start + row < valid_len,
                        acc_ref[...] / denom, 0.0)
        out_ref[0] = out.reshape(bq, group, -1).astype(out_ref.dtype)


def paged_flash_prefill_fwd(
    q: jax.Array,             # [T, q_heads, head_dim] — chunk query rows
    k_pages: jax.Array,       # [kv_heads, num_pages, page_size, head_dim]
    v_pages: jax.Array,       # [kv_heads, num_pages, page_size, head_dim]
    block_table: jax.Array,   # [pages_per_seq] int32 (shared by all rows)
    pos0: jax.Array,          # scalar int32: absolute position of row 0
    valid_len: jax.Array,     # scalar int32: rows >= valid_len are padding
    *,
    block_q: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Flash-prefill the chunk rows against one request's paged KV.

    Row i attends keys at absolute positions 0..pos0+i (its own token
    included — the mixed step writes the chunk's K/V before attention).
    ``block_table`` must cover positions 0..pos0+valid_len-1; entries past
    that may be the engine's OOB sentinel (they are clamped and their
    positions fall outside every row's causal horizon). Rows >= valid_len
    return exact zeros. T must divide block_q (``ops.paged_flash_prefill``
    pads). Returns [T, q_heads, head_dim].
    """
    t, q_heads, head_dim = q.shape
    kv_heads, num_pages, page_size, _ = k_pages.shape
    group = q_heads // kv_heads
    pages_per_seq = block_table.shape[0]
    scale = 1.0 / (head_dim ** 0.5)

    kg = paged_prefill_grid(t, q_heads, head_dim, kv_heads, num_pages,
                            page_size, pages_per_seq, block_q=block_q)
    bq = kg.in_mappings[0].block_shape[1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=kg.num_scalar_prefetch,
        grid=kg.grid,
        in_specs=block_specs(kg.in_mappings),
        out_specs=block_specs(kg.out_mappings)[0],
        scratch_shapes=[
            pltpu.VMEM((bq * group, 1), jnp.float32),
            pltpu.VMEM((bq * group, 1), jnp.float32),
            pltpu.VMEM((bq * group, head_dim), jnp.float32),
        ],
    )

    kernel = pl.pallas_call(
        functools.partial(_paged_prefill_kernel, bq=bq, group=group,
                          page_size=page_size, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(
            kg.out_mappings[0].array_shape, q.dtype),
        interpret=interpret,
    )
    info = jnp.stack([jnp.asarray(pos0, jnp.int32),
                      jnp.asarray(valid_len, jnp.int32)])
    q4 = q.reshape(t, kv_heads, group, head_dim).transpose(1, 0, 2, 3)
    out = kernel(block_table.astype(jnp.int32), info, q4, k_pages, v_pages)
    return out.transpose(1, 0, 2, 3).reshape(t, q_heads, head_dim)
