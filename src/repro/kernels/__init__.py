# Compute hot-spots of SART's decode phase, TPU-adapted:
#   paged_attention — flash-decode over block-table-indexed KV pages (the
#                     TPU re-think of vLLM PagedAttention, which the paper
#                     builds on).
#   ssd_scan        — Mamba2 chunked SSD scan for the ssm/hybrid assigned
#                     architectures.
#   flash_prefill   — causal flash-attention forward for the prefill phase
#                     (prefill latency gates queuing delay in Algorithm 1),
#                     plus the fused paged variant that block-processes a
#                     prefill chunk's rows against paged KV in the mixed
#                     decode+prefill step.
from .flash_prefill.ops import flash_attention, paged_flash_prefill
from .paged_attention.ops import paged_attention
from .ssd_scan.ops import ssd

__all__ = ["flash_attention", "paged_attention", "paged_flash_prefill",
           "ssd"]
