# Compute hot-spots of SART's decode phase, TPU-adapted:
#   paged_attention — flash-decode over block-table-indexed KV pages (the
#                     TPU re-think of vLLM PagedAttention, which the paper
#                     builds on).
#   ssd_scan        — Mamba2 chunked SSD scan for the ssm/hybrid assigned
#                     architectures.
#   flash_prefill   — causal flash-attention forward for the prefill phase
#                     (prefill latency gates queuing delay in Algorithm 1),
#                     plus the fused paged variant that block-processes a
#                     prefill chunk's rows against paged KV in the mixed
#                     decode+prefill step.
from .flash_prefill.flash_prefill import flash_prefill_grid
from .flash_prefill.ops import flash_attention, paged_flash_prefill
from .flash_prefill.paged_prefill import paged_prefill_grid
from .introspect import BlockMapping, KernelGrid, block_specs
from .paged_attention.ops import paged_attention, paged_tree_attention
from .paged_attention.paged_attention import paged_attention_grid
from .paged_attention.tree_decode import (paged_tree_branch_grid,
                                          paged_tree_shared_grid)
from .ssd_scan.ops import ssd
from .ssd_scan.ssd_scan import ssd_scan_grid

__all__ = ["BlockMapping", "KernelGrid", "block_specs", "flash_attention",
           "flash_prefill_grid", "paged_attention", "paged_attention_grid",
           "paged_flash_prefill", "paged_prefill_grid",
           "paged_tree_attention", "paged_tree_branch_grid",
           "paged_tree_shared_grid", "ssd", "ssd_scan_grid"]
