"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

TPU adaptation of the SSD algorithm (arXiv:2405.21060): the GPU
implementation leans on warp-level parallel prefix; on TPU we instead map the
chunk axis onto the *sequential minor grid dimension* and carry the inter-
chunk SSM state in VMEM scratch — the systolic analogue of the chunked
recurrence. Per grid step the kernel computes, entirely in VMEM:

  intra-chunk:  Y_diag = (C·Bᵀ ∘ L) · X        (two MXU matmuls, [Q,Q] gate)
  state update: S      = decay·S + (dt·decay_to_end·B)ᵀ X
  inter-chunk:  Y_off  = (C · S_prev) ∘ exp(cumsum dA)

Grid = (batch, heads, num_chunks); chunk length Q and head_dim P are chosen
so [Q,Q] + [Q,N] + [P,N] tiles fit VMEM with MXU-aligned (multiples of 128 in
production; smaller in smoke shapes) dimensions.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..introspect import BlockMapping, KernelGrid, block_specs


def ssd_scan_grid(bs: int, s: int, h: int, p: int, n: int,
                  chunk: int) -> KernelGrid:
    """Launch geometry for :func:`ssd_scan`.

    Grid = (batch, heads, num_chunks) with the chunk axis minor and
    sequential — the VMEM state scratch carries the inter-chunk SSM
    recurrence across it. No scalar prefetch; every index map is affine
    in the grid indices.
    """
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    def x_index(bi, hi, ci):
        return (bi, ci, hi, 0)

    def dt_index(bi, hi, ci):
        return (bi, ci, hi)

    def a_index(bi, hi, ci):
        return (hi,)

    x_map = BlockMapping("x", (bs, s, h, p), (1, chunk, 1, p), x_index)
    bc_shape = (bs, s, h, n)
    bc_block = (1, chunk, 1, n)
    return KernelGrid(
        kernel="ssd_scan",
        grid=(bs, h, nc),
        in_mappings=(
            x_map,
            BlockMapping("dt", (bs, s, h), (1, chunk, 1), dt_index),
            BlockMapping("a", (h,), (1,), a_index),
            BlockMapping("b", bc_shape, bc_block, x_index),
            BlockMapping("c", bc_shape, bc_block, x_index),
        ),
        out_mappings=(dataclasses.replace(x_map, name="y"),),
    )


def _ssd_kernel(
    x_ref,      # [1, Q, 1, P]
    dt_ref,     # [1, Q, 1]
    a_ref,      # [1]       (per-head decay rate, negative)
    b_ref,      # [1, Q, 1, N]
    c_ref,      # [1, Q, 1, N]
    y_ref,      # [1, Q, 1, P]
    state_ref,  # scratch [P, N] f32 — carried across the chunk grid dim
    *,
    chunk: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)      # [Q, P]
    dt = dt_ref[0, :, 0].astype(jnp.float32)        # [Q]
    a = a_ref[0].astype(jnp.float32)                # scalar
    b = b_ref[0, :, 0, :].astype(jnp.float32)       # [Q, N]
    c = c_ref[0, :, 0, :].astype(jnp.float32)       # [Q, N]

    da = dt * a                                     # [Q] log-decay
    da_cs = jnp.cumsum(da)                          # [Q]

    # ---- intra-chunk quadratic term ----------------------------------------
    seg = da_cs[:, None] - da_cs[None, :]           # [Q, Q]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    lmat = jnp.where(ii >= jj, jnp.exp(seg), 0.0)
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())))   # [Q, Q]
    gate = cb * lmat * dt[None, :]
    y = jax.lax.dot_general(gate, x, (((1,), (0,)), ((), ()))) # [Q, P]

    # ---- inter-chunk contribution from carried state ------------------------
    s_prev = state_ref[...]                          # [P, N]
    y_off = jax.lax.dot_general(c, s_prev, (((1,), (1,)), ((), ())))  # [Q, P]
    y = y + y_off * jnp.exp(da_cs)[:, None]

    # ---- state update --------------------------------------------------------
    decay_to_end = jnp.exp(da_cs[-1] - da_cs)        # [Q]
    wb = b * (dt * decay_to_end)[:, None]            # [Q, N]
    s_new = jax.lax.dot_general(x, wb, (((0,), (0,)), ((), ())))  # [P, N]
    state_ref[...] = s_prev * jnp.exp(da_cs[-1]) + s_new

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)


def ssd_scan(x, dt, a, b, c, *, chunk: int = 64,
             interpret: bool = False, valid=None):
    """Chunked SSD scan (no initial state, returns outputs only).

    x: [B, S, H, P]; dt: [B, S, H] (>0); a: [H] (<0);
    b, c: [B, S, H, N] (head-broadcast). S must be a multiple of `chunk`
    (caller pads). Returns y [B, S, H, P].

    ``valid`` ([B, S] bool or None) zeroes dt at invalid positions before
    the kernel launches. Every in-kernel use of a position — its log-decay
    dt·a, its dt-gated B·x state contribution, and its column of the
    intra-chunk gate — is proportional to (or an exp of) dt, so dt = 0 is
    exactly an identity state transition; the kernel body needs no mask.
    """
    if valid is not None:
        dt = jnp.where(valid[..., None], dt, 0.0)
    bs, s, h, p = x.shape
    n = b.shape[-1]
    kg = ssd_scan_grid(bs, s, h, p, n, chunk)

    kernel = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=kg.grid,
        in_specs=block_specs(kg.in_mappings),
        out_specs=block_specs(kg.out_mappings)[0],
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )
    return kernel(x, dt, a, b, c)
