"""Jit'd public wrapper for the SSD scan kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .ref import ssd_scan_ref
from .ssd_scan import ssd_scan


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "use_kernel"))
def ssd(x, dt, a, b, c, chunk: int = 64, use_kernel: bool = True):
    """SSD scan; Pallas kernel on TPU / interpret elsewhere. Pads S to chunk."""
    if not use_kernel:
        return ssd_scan_ref(x, dt, a, b, c)
    s = x.shape[1]
    pad = (-s) % chunk
    if pad:
        zpad = lambda t: jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
        x, dt, b, c = zpad(x), zpad(dt), zpad(b), zpad(c)
    y = ssd_scan(x, dt, a, b, c, chunk=chunk, interpret=not _on_tpu())
    return y[:, :s]
