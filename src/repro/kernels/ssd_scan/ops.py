"""Jit'd public wrapper for the SSD scan kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .ref import ssd_scan_ref
from .ssd_scan import ssd_scan


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "use_kernel"))
def ssd(x, dt, a, b, c, chunk: int = 64, use_kernel: bool = True,
        valid=None):
    """SSD scan; Pallas kernel on TPU / interpret elsewhere. Pads S to chunk.

    ``valid`` ([B, S] bool or None) marks real positions: invalid ones get
    dt forced to 0, i.e. an exact identity state transition and zero
    contribution to every other position's output (masked-dt chunked
    prefill). Outputs at invalid positions are unspecified. The mask is
    forwarded to the leaf implementations — the dt-zeroing lives there, in
    exactly one place per path."""
    if not use_kernel:
        return ssd_scan_ref(x, dt, a, b, c, valid=valid)
    s = x.shape[1]
    pad = (-s) % chunk
    if pad:
        zpad = lambda t: jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
        x, dt, b, c = zpad(x), zpad(dt), zpad(b), zpad(c)
        if valid is not None:
            valid = jnp.pad(valid, [(0, 0), (0, pad)])   # pads are invalid
    y = ssd_scan(x, dt, a, b, c, chunk=chunk, interpret=not _on_tpu(),
                 valid=valid)
    return y[:, :s]
