"""Pure-jnp oracles for the SSD scan kernel.

Two references:
  * ``ssd_scan_ref``       — naive per-token linear recurrence (ground truth).
  * ``repro.models.mamba2.ssd_chunked`` — the chunked jnp implementation the
    model uses; tests check kernel == chunked == naive.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(x, dt, a, b, c, valid=None):
    """Token-by-token SSM recurrence.

    h_t = exp(dt_t a) h_{t-1} + dt_t B_t x_t ;  y_t = C_t · h_t
    x: [B,S,H,P]; dt: [B,S,H]; a: [H]; b,c: [B,S,H,N] -> y [B,S,H,P].
    ``valid`` ([B,S] bool or None) zeroes dt at invalid positions, making
    their state transition an exact identity.
    """
    if valid is not None:
        dt = jnp.where(valid[..., None], dt, 0.0)
    bs, s, h, p = x.shape
    n = b.shape[-1]

    def step(state, inp):
        xt, dtt, bt, ct = inp                      # [B,H,P], [B,H], [B,H,N]
        decay = jnp.exp(dtt * a)                   # [B,H]
        upd = jnp.einsum("bh,bhn,bhp->bhpn", dtt, bt, xt)
        state = state * decay[:, :, None, None] + upd
        y = jnp.einsum("bhpn,bhn->bhp", state, ct)
        return state, y

    init = jnp.zeros((bs, h, p, n), jnp.float32)
    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(b, 1, 0).astype(jnp.float32),
          jnp.moveaxis(c, 1, 0).astype(jnp.float32))
    _, ys = jax.lax.scan(step, init, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)  # [B,S,H,P]
