"""Pallas TPU tree/cascade decode attention over fork-shared KV pages.

SART's redundant sampling decodes N sibling branches forked off one
prompt: their block tables share every ancestor page up to the fork
point, yet per-branch flash-decode (``paged_attention.py``) streams
those shared pages from HBM once PER BRANCH every step. This module
splits the decode attention into two passes over a branch×page dedup
map (built host-side from ``BranchBlocks`` fork topology by
``repro.kv.tree_decode_map``):

  * **shared pass** — grid (kv_heads, num_groups, pages_per_seq): each
    fork group's shared ancestor pages are streamed ONCE; every decode
    row's queries ride along as one [batch·group, head_dim] block and a
    membership mask (``row_group[b] == g``) keeps non-members out of
    every softmax claim. The pass emits raw online-softmax partials
    (m, l, acc) as revisited f32 output blocks (the group axis is
    consecutive under the major head axis, so accumulation is the
    standard resident-block pattern).
  * **branch pass** — the per-branch flash-decode loop of
    ``paged_attention_decode``, but over each row's POST-FORK suffix
    pages only (``branch_bt`` / ``branch_lens``), also emitting raw
    partials. Key positions inside attention are order-free, so the
    suffix uses a fresh zero-based table; shared spans are always whole
    pages, so suffix token t lives at page t // page_size exactly.
  * the two partial sets merge in plain jnp (flash-style exp-rescale) —
    exact, because the passes cover disjoint key sets whose union is the
    row's full context.

Sentinel handling matches the decode kernel: table entries past a row's
pages hold ``num_pages`` and are clamped in the index map (masks discard
the clamped fetch); shared-pass iterations past a group's span park on
the group's last live page so skipped grid steps move no bytes. Masked
probabilities use ``p = where(mask, exp(s - m), 0)`` — with the finite
``NEG_INF``, a row with no valid key yet would otherwise claim
``exp(0) = 1`` mass into l.

Validated in ``interpret=True`` mode on CPU against
``ref.paged_tree_attention_ref``, which reconstructs each row's full
block table from the map and defers to ``paged_attention_decode_ref`` —
so the engine's CPU (ref) tree path is bit-identical to per-branch
decode by construction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..introspect import BlockMapping, KernelGrid, block_specs

NEG_INF = -1e30


def paged_tree_shared_grid(
    batch: int,
    q_heads: int,
    head_dim: int,
    kv_heads: int,
    num_pages: int,
    page_size: int,
    num_groups: int,
    pages_per_seq: int,
) -> KernelGrid:
    """Launch geometry for the shared-ancestor pass of
    :func:`paged_tree_attention_fwd`.

    Scalar-prefetch operands: ``sbt`` — [num_groups, pages_per_seq]
    int32 shared page tables (sentinel ``num_pages`` past each group's
    span), ``sl`` — [num_groups] int32 shared token spans (whole pages:
    multiples of ``page_size``; 0 for unused groups). ``row_group`` and
    the per-row attend lengths ride as VMEM operands (the index maps
    never need them). The K/V index map parks iterations past a group's
    span on its last live page and clamps sentinels into range.
    """
    assert q_heads % kv_heads == 0, (q_heads, kv_heads)
    group = q_heads // kv_heads
    rows = batch * group

    def q_index(h, g, ki, sbt, sl):
        return (h, 0, 0)

    def col_index(h, g, ki, sbt, sl):
        return (0, 0)

    def kv_index(h, g, ki, sbt, sl):
        # park iterations past the group's shared span on its last live
        # page (unused groups have span 0 and park on entry 0), then
        # clamp sentinel entries into range — both read already-resident
        # pages, so skipped grid steps move no bytes
        last_live = jnp.maximum(sl[g] // page_size - 1, 0)
        ki_live = jnp.minimum(ki, last_live)
        return (h, jnp.minimum(sbt[g, ki_live], num_pages - 1), 0, 0)

    kv_shape = (kv_heads, num_pages, page_size, head_dim)
    kv_block = (1, 1, page_size, head_dim)
    return KernelGrid(
        kernel="paged_tree_shared",
        grid=(kv_heads, num_groups, pages_per_seq),
        in_mappings=(
            BlockMapping("q", (kv_heads, rows, head_dim),
                         (1, rows, head_dim), q_index),
            BlockMapping("row_group", (batch, 1), (batch, 1), col_index),
            BlockMapping("lengths", (batch, 1), (batch, 1), col_index),
            BlockMapping("k_pages", kv_shape, kv_block, kv_index),
            BlockMapping("v_pages", kv_shape, kv_block, kv_index),
        ),
        out_mappings=(
            BlockMapping("m", (kv_heads, rows, 1), (1, rows, 1), q_index),
            BlockMapping("l", (kv_heads, rows, 1), (1, rows, 1), q_index),
            BlockMapping("acc", (kv_heads, rows, head_dim),
                         (1, rows, head_dim), q_index),
        ),
        num_scalar_prefetch=2,
    )


def paged_tree_branch_grid(
    batch: int,
    q_heads: int,
    head_dim: int,
    kv_heads: int,
    num_pages: int,
    page_size: int,
    pages_per_seq: int,
) -> KernelGrid:
    """Launch geometry for the post-fork suffix pass — the decode
    kernel's grid with raw-partial outputs.

    Scalar-prefetch operands: ``bt`` — [batch, pages_per_seq] int32
    suffix page tables, ``ln`` — [batch] int32 suffix spans
    (``max(attend_len - shared_span, 0)``; 0 for rows fully covered by
    the shared pass). Sentinel entries are clamped exactly like
    ``paged_attention_grid``.
    """
    assert q_heads % kv_heads == 0, (q_heads, kv_heads)
    group = q_heads // kv_heads

    def q_index(b, h, i, bt, ln):
        return (b, h, 0)

    def kv_index(b, h, i, bt, ln):
        return (h, jnp.minimum(bt[b, i], num_pages - 1), 0, 0)

    kv_shape = (kv_heads, num_pages, page_size, head_dim)
    kv_block = (1, 1, page_size, head_dim)
    return KernelGrid(
        kernel="paged_tree_branch",
        grid=(batch, kv_heads, pages_per_seq),
        in_mappings=(
            BlockMapping("q", (batch, kv_heads * group, head_dim),
                         (1, group, head_dim), q_index),
            BlockMapping("k_pages", kv_shape, kv_block, kv_index),
            BlockMapping("v_pages", kv_shape, kv_block, kv_index),
        ),
        out_mappings=(
            BlockMapping("m", (batch, kv_heads * group, 1),
                         (1, group, 1), q_index),
            BlockMapping("l", (batch, kv_heads * group, 1),
                         (1, group, 1), q_index),
            BlockMapping("acc", (batch, kv_heads * group, head_dim),
                         (1, group, head_dim), q_index),
        ),
        num_scalar_prefetch=2,
    )


def _tree_shared_kernel(
    # scalar-prefetch refs
    shared_bt_ref,       # [num_groups, pages_per_seq] int32
    shared_lens_ref,     # [num_groups] int32 (multiples of page_size)
    # inputs
    q_ref,               # [1, batch * group, head_dim]
    rg_ref,              # [batch, 1] int32 row -> group (sentinel >= G)
    ln_ref,              # [batch, 1] int32 per-row attend lengths
    k_ref,               # [1, 1, page_size, head_dim]
    v_ref,               # [1, 1, page_size, head_dim]
    # outputs (revisited accumulators, f32)
    m_ref,               # [1, batch * group, 1]
    l_ref,               # [1, batch * group, 1]
    acc_ref,             # [1, batch * group, head_dim]
    *,
    batch: int,
    group: int,
    page_size: int,
    scale: float,
):
    g = pl.program_id(1)
    ki = pl.program_id(2)
    sl = shared_lens_ref[g]

    @pl.when((g == 0) & (ki == 0))
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(ki * page_size < sl)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale            # [B*G, hd]
        k = k_ref[0, 0].astype(jnp.float32)                 # [P, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [B*G, P]
        # membership + per-row shared-span mask, expanded to GQA rows
        member = jnp.broadcast_to(rg_ref[...] == g, (batch, group)) \
            .reshape(batch * group, 1)
        attend = jnp.broadcast_to(jnp.minimum(ln_ref[...], sl),
                                  (batch, group)).reshape(batch * group, 1)
        kpos = ki * page_size + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        mask = member & (kpos < attend)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[0]                                   # [B*G, 1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        # where-masked p: a fully-masked row has m_new == m_prev ==
        # NEG_INF and exp(s - m_new) would claim exp(0) = 1 per key
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[0] = alpha * l_ref[0] + jnp.sum(p, -1, keepdims=True)
        acc_ref[0] = acc_ref[0] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[0] = m_new


def _tree_branch_kernel(
    # scalar-prefetch refs
    branch_bt_ref,       # [B, pages_per_seq] int32
    branch_lens_ref,     # [B] int32 suffix spans
    # inputs
    q_ref,               # [1, group, head_dim]
    k_ref,               # [1, 1, page_size, head_dim]
    v_ref,               # [1, 1, page_size, head_dim]
    # outputs (raw partials, f32)
    m_out_ref,           # [1, group, 1]
    l_out_ref,           # [1, group, 1]
    acc_out_ref,         # [1, group, head_dim]
    # scratch
    m_ref,               # [group, 1] f32
    l_ref,               # [group, 1] f32
    acc_ref,             # [group, head_dim] f32
    *,
    page_size: int,
    scale: float,
):
    b = pl.program_id(0)
    page_idx = pl.program_id(2)
    num_pages = pl.num_programs(2)
    length = branch_lens_ref[b]

    @pl.when(page_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    start = page_idx * page_size

    @pl.when(start < length)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale            # [G, hd]
        k = k_ref[0, 0].astype(jnp.float32)                 # [P, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [G, P]
        pos = start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = pos < length
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(page_idx == num_pages - 1)
    def _finalize():
        # raw partials — the caller merges with the shared pass
        m_out_ref[0] = m_ref[...]
        l_out_ref[0] = l_ref[...]
        acc_out_ref[0] = acc_ref[...]


def paged_tree_attention_fwd(
    q: jax.Array,             # [B, q_heads, head_dim]
    k_pages: jax.Array,       # [kv_heads, num_pages, page_size, head_dim]
    v_pages: jax.Array,       # [kv_heads, num_pages, page_size, head_dim]
    row_group: jax.Array,     # [B] int32; >= num_groups means ungrouped
    shared_bt: jax.Array,     # [num_groups, pages_per_seq] int32
    shared_lens: jax.Array,   # [num_groups] int32 (whole pages)
    branch_bt: jax.Array,     # [B, pages_per_seq] int32 suffix tables
    lengths: jax.Array,       # [B] int32 full attend lengths
    *,
    interpret: bool = False,
) -> jax.Array:
    """Tree-decode over the branch×page dedup map.

    Returns [B, q_heads, head_dim] — same contract as
    ``paged_attention_decode`` over the per-row full tables the map
    decomposes.
    """
    batch, q_heads, head_dim = q.shape
    kv_heads, num_pages, page_size, _ = k_pages.shape
    group = q_heads // kv_heads
    num_groups = shared_bt.shape[0]
    pages_per_seq = branch_bt.shape[1]
    scale = 1.0 / (head_dim ** 0.5)

    row_group = row_group.astype(jnp.int32)
    lengths = lengths.astype(jnp.int32)
    grp = jnp.clip(row_group, 0, num_groups - 1)
    sh_len = jnp.where(row_group < num_groups,
                       shared_lens.astype(jnp.int32)[grp], 0)
    branch_lens = jnp.maximum(lengths - sh_len, 0)

    kg_s = paged_tree_shared_grid(batch, q_heads, head_dim, kv_heads,
                                  num_pages, page_size, num_groups,
                                  shared_bt.shape[1])
    shared_call = pl.pallas_call(
        functools.partial(_tree_shared_kernel, batch=batch, group=group,
                          page_size=page_size, scale=scale),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=kg_s.num_scalar_prefetch,
            grid=kg_s.grid,
            in_specs=block_specs(kg_s.in_mappings),
            out_specs=block_specs(kg_s.out_mappings),
        ),
        out_shape=[jax.ShapeDtypeStruct(m.array_shape, jnp.float32)
                   for m in kg_s.out_mappings],
        interpret=interpret,
    )
    q_s = q.reshape(batch, kv_heads, group, head_dim) \
        .transpose(1, 0, 2, 3).reshape(kv_heads, batch * group, head_dim)
    m_s, l_s, acc_s = shared_call(
        shared_bt.astype(jnp.int32), shared_lens.astype(jnp.int32), q_s,
        row_group[:, None], lengths[:, None], k_pages, v_pages)

    kg_b = paged_tree_branch_grid(batch, q_heads, head_dim, kv_heads,
                                  num_pages, page_size, pages_per_seq)
    branch_call = pl.pallas_call(
        functools.partial(_tree_branch_kernel, page_size=page_size,
                          scale=scale),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=kg_b.num_scalar_prefetch,
            grid=kg_b.grid,
            in_specs=block_specs(kg_b.in_mappings),
            out_specs=block_specs(kg_b.out_mappings),
            scratch_shapes=[
                pltpu.VMEM((group, 1), jnp.float32),
                pltpu.VMEM((group, 1), jnp.float32),
                pltpu.VMEM((group, head_dim), jnp.float32),
            ],
        ),
        out_shape=[jax.ShapeDtypeStruct(m.array_shape, jnp.float32)
                   for m in kg_b.out_mappings],
        interpret=interpret,
    )
    m_b, l_b, acc_b = branch_call(
        branch_bt.astype(jnp.int32), branch_lens,
        q.reshape(batch, kv_heads * group, head_dim), k_pages, v_pages)

    # fold shared partials into the branch layout, then merge the two
    # disjoint-key-set softmax partials flash-style
    def fold(a):
        w = a.shape[-1]
        return a.reshape(kv_heads, batch, group, w) \
            .transpose(1, 0, 2, 3).reshape(batch, kv_heads * group, w)

    m_s, l_s, acc_s = fold(m_s), fold(l_s), fold(acc_s)
    m = jnp.maximum(m_s, m_b)
    a_s = jnp.exp(m_s - m)
    a_b = jnp.exp(m_b - m)
    l = l_s * a_s + l_b * a_b
    acc = acc_s * a_s + acc_b * a_b
    out = acc / jnp.maximum(l, 1e-30)
    return out.reshape(batch, q_heads, head_dim).astype(q.dtype)
