"""Jit'd public wrappers for the paged flash-decode kernels (per-branch
and tree/cascade).

On CPU (this container) the Pallas kernel bodies execute via
``interpret=True``; on TPU the same ``pallas_call``s compile to Mosaic.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax

from .paged_attention import paged_attention_decode
from .ref import paged_attention_decode_ref, paged_tree_attention_ref
from .tree_decode import paged_tree_attention_fwd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def paged_attention(q, k_pages, v_pages, block_tables, lengths,
                    use_kernel: bool = True):
    """Paged decode attention; kernel on TPU / interpret elsewhere."""
    if not use_kernel:
        return paged_attention_decode_ref(q, k_pages, v_pages, block_tables,
                                          lengths)
    return paged_attention_decode(q, k_pages, v_pages, block_tables, lengths,
                                  interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def paged_tree_attention(q, k_pages, v_pages, row_group, shared_bt,
                         shared_lens, branch_bt, lengths,
                         use_kernel: bool = True):
    """Tree/cascade decode attention over a branch×page dedup map (built
    by ``repro.kv.tree_decode_map``); shared ancestor pages are streamed
    once per step for all descendant branches. Same output contract as
    ``paged_attention`` over the per-row full tables the map decomposes.
    """
    if not use_kernel:
        return paged_tree_attention_ref(q, k_pages, v_pages, row_group,
                                        shared_bt, shared_lens, branch_bt,
                                        lengths)
    return paged_tree_attention_fwd(q, k_pages, v_pages, row_group,
                                    shared_bt, shared_lens, branch_bt,
                                    lengths, interpret=not _on_tpu())


def tree_decode_bytes_read(shared_pages: int, branch_pages: Sequence[int],
                           page_size: int, kv_heads: int, head_dim: int, *,
                           path: str, itemsize: int = 4) -> int:
    """Analytic K+V HBM bytes one decode step reads for a fork group of
    sibling branches with ``shared_pages`` common ancestor pages and
    per-branch post-fork suffixes ``branch_pages``.

    ``path="branch"`` is the per-branch flash-decode loop: every sibling
    re-streams the shared ancestor pages. ``path="tree"`` streams them
    once (shared pass) plus each suffix once (branch pass).
    """
    if path == "branch":
        pages = sum(shared_pages + bp for bp in branch_pages)
    elif path == "tree":
        pages = shared_pages + sum(branch_pages)
    else:
        raise ValueError(f"unknown tree-decode path {path!r}")
    return 2 * pages * page_size * kv_heads * head_dim * itemsize
