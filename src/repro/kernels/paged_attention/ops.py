"""Jit'd public wrapper for the paged flash-decode kernel.

On CPU (this container) the Pallas kernel body executes via
``interpret=True``; on TPU the same ``pallas_call`` compiles to Mosaic.
"""
from __future__ import annotations

import functools

import jax

from .paged_attention import paged_attention_decode
from .ref import paged_attention_decode_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def paged_attention(q, k_pages, v_pages, block_tables, lengths,
                    use_kernel: bool = True):
    """Paged decode attention; kernel on TPU / interpret elsewhere."""
    if not use_kernel:
        return paged_attention_decode_ref(q, k_pages, v_pages, block_tables,
                                          lengths)
    return paged_attention_decode(q, k_pages, v_pages, block_tables, lengths,
                                  interpret=not _on_tpu())
