"""Pure-jnp oracle for the paged flash-decode kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def paged_attention_decode_ref(q, k_pages, v_pages, block_tables, lengths):
    """Shapes as in `paged_attention_decode`. Returns [B, q_heads, head_dim].

    Gathers every sequence's pages into a contiguous [B, S, kv, hd] tensor and
    runs masked softmax attention — O(B·S) memory, correctness-only.
    """
    batch, q_heads, head_dim = q.shape
    kv_heads, _, page_size, _ = k_pages.shape
    group = q_heads // kv_heads
    pages_per_seq = block_tables.shape[1]
    s_max = pages_per_seq * page_size

    # gather pages -> [B, kv, S, hd]
    def gather(pages):
        g = pages[:, block_tables]            # [kv, B, pages_per_seq, P, hd]
        g = jnp.moveaxis(g, 1, 0)             # [B, kv, pages, P, hd]
        return g.reshape(batch, kv_heads, s_max, head_dim)

    k = gather(k_pages)
    v = gather(v_pages)

    qg = q.reshape(batch, kv_heads, group, head_dim).astype(jnp.float32)
    scores = jnp.einsum("bkgd,bksd->bkgs", qg, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(jnp.asarray(head_dim, jnp.float32))
    mask = jnp.arange(s_max)[None, :] < lengths[:, None]   # [B, S]
    scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bksd->bkgd", w, v.astype(jnp.float32))
    return out.reshape(batch, q_heads, head_dim).astype(q.dtype)
