"""Pure-jnp oracle for the paged flash-decode kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def paged_attention_decode_ref(q, k_pages, v_pages, block_tables, lengths):
    """Shapes as in `paged_attention_decode`. Returns [B, q_heads, head_dim].

    Gathers every sequence's pages into a contiguous [B, S, kv, hd] tensor and
    runs masked softmax attention — O(B·S) memory, correctness-only.
    """
    batch, q_heads, head_dim = q.shape
    kv_heads, _, page_size, _ = k_pages.shape
    group = q_heads // kv_heads
    pages_per_seq = block_tables.shape[1]
    s_max = pages_per_seq * page_size

    # gather pages -> [B, kv, S, hd]
    def gather(pages):
        g = pages[:, block_tables]            # [kv, B, pages_per_seq, P, hd]
        g = jnp.moveaxis(g, 1, 0)             # [B, kv, pages, P, hd]
        return g.reshape(batch, kv_heads, s_max, head_dim)

    k = gather(k_pages)
    v = gather(v_pages)

    qg = q.reshape(batch, kv_heads, group, head_dim).astype(jnp.float32)
    scores = jnp.einsum("bkgd,bksd->bkgs", qg, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(jnp.asarray(head_dim, jnp.float32))
    mask = jnp.arange(s_max)[None, :] < lengths[:, None]   # [B, S]
    scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bksd->bkgd", w, v.astype(jnp.float32))
    return out.reshape(batch, q_heads, head_dim).astype(q.dtype)


def paged_tree_attention_ref(q, k_pages, v_pages, row_group, shared_bt,
                             shared_lens, branch_bt, lengths):
    """Pure-jnp oracle for the tree-decode pair (`tree_decode.py`).

    Reconstructs each row's full block table — the group's shared prefix
    pages followed by the row's post-fork suffix, sentinel-padded — and
    defers to `paged_attention_decode_ref`. The reconstruction is
    bit-identical to the per-branch table the map was decomposed from
    (`repro.kv.tree_decode_map` splits on whole-page boundaries only), so
    the engine's tree ref path reproduces the per-branch ref exactly.
    """
    num_groups = shared_bt.shape[0]
    pages_per_seq = branch_bt.shape[1]
    page_size = k_pages.shape[2]

    row_group = row_group.astype(jnp.int32)
    grp = jnp.clip(row_group, 0, num_groups - 1)
    sh_pages = jnp.where(row_group < num_groups,
                         shared_lens.astype(jnp.int32)[grp] // page_size, 0)
    idx = jnp.arange(pages_per_seq)[None, :]
    from_shared = idx < sh_pages[:, None]
    suffix_idx = jnp.clip(idx - sh_pages[:, None], 0, pages_per_seq - 1)
    full_bt = jnp.where(from_shared, shared_bt[grp],
                        jnp.take_along_axis(branch_bt, suffix_idx, axis=1))
    return paged_attention_decode_ref(q, k_pages, v_pages, full_bt, lengths)
