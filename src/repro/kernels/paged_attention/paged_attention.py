"""Pallas TPU flash-decode attention over a paged KV cache.

This is the TPU-native re-think of vLLM's PagedAttention CUDA kernel, the
compute hot-spot of SART's decode phase (the paper's serving substrate):

  * KV pages live in HBM as ``[kv_heads, num_pages, page_size, head_dim]``;
    the per-branch block table indexes them. Sibling branches of one request
    share prefix pages (ref-counted by ``repro.kv``) — the kernel is
    oblivious: shared pages are simply referenced by several block tables.
  * Grid = (batch, kv_head, pages_per_seq). The page axis is the minor,
    sequential grid dimension; an online-softmax (m, l, acc) accumulator in
    VMEM scratch merges pages flash-decode style, so a 500k-token context
    never materializes a full attention row.
  * Block tables and context lengths are scalar-prefetched
    (``PrefetchScalarGridSpec``) so the page index_map can consume them —
    the TPU analogue of the CUDA kernel's pointer chasing.
  * MXU alignment: page_size and head_dim are multiples of 128 in production
    configs; q is laid out ``[batch, q_heads, head_dim]`` with the GQA group
    as the sublane dimension.

Validated in ``interpret=True`` mode on CPU against ``ref.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..introspect import BlockMapping, KernelGrid, block_specs

NEG_INF = -1e30


def paged_attention_grid(
    batch: int,
    q_heads: int,
    head_dim: int,
    kv_heads: int,
    num_pages: int,
    page_size: int,
    pages_per_seq: int,
) -> KernelGrid:
    """Launch geometry for :func:`paged_attention_decode`.

    Scalar-prefetch operands: ``bt`` — [batch, pages_per_seq] int32 block
    tables, ``ln`` — [batch] int32 context lengths. The q operand is the
    caller's [batch, kv_heads·group, head_dim] layout; its block picks one
    (batch, kv_head) GQA group.
    """
    assert q_heads % kv_heads == 0, (q_heads, kv_heads)
    group = q_heads // kv_heads

    def q_index(b, h, i, bt, ln):
        return (b, h, 0)

    def kv_index(b, h, i, bt, ln):
        # sentinel block-table entries (the engine pads tables with
        # num_pages) are clamped into range: their pages sit past
        # `lengths`, so the length mask discards whatever the clamped
        # fetch returns — without the clamp the index map would address
        # HBM out of bounds on TPU
        return (h, jnp.minimum(bt[b, i], num_pages - 1), 0, 0)

    q_map = BlockMapping("q", (batch, kv_heads * group, head_dim),
                         (1, group, head_dim), q_index)
    kv_shape = (kv_heads, num_pages, page_size, head_dim)
    kv_block = (1, 1, page_size, head_dim)
    return KernelGrid(
        kernel="paged_attention",
        grid=(batch, kv_heads, pages_per_seq),
        in_mappings=(
            q_map,
            BlockMapping("k_pages", kv_shape, kv_block, kv_index),
            BlockMapping("v_pages", kv_shape, kv_block, kv_index),
        ),
        out_mappings=(
            BlockMapping("out", (batch, q_heads, head_dim),
                         (1, group, head_dim), q_index),
        ),
        num_scalar_prefetch=2,
    )


def _decode_kernel(
    # scalar-prefetch refs
    block_tables_ref,    # [B, pages_per_seq] int32
    lengths_ref,         # [B] int32
    # inputs
    q_ref,               # [1, group, head_dim]
    k_ref,               # [1, 1, page_size, head_dim]
    v_ref,               # [1, 1, page_size, head_dim]
    # outputs
    out_ref,             # [1, group, head_dim]
    # scratch
    m_ref,               # [group, 1] f32
    l_ref,               # [group, 1] f32
    acc_ref,             # [group, head_dim] f32
    *,
    page_size: int,
    scale: float,
):
    b = pl.program_id(0)
    page_idx = pl.program_id(2)
    num_pages = pl.num_programs(2)
    length = lengths_ref[b]

    @pl.when(page_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    start = page_idx * page_size

    @pl.when(start < length)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # [G, hd]
        k = k_ref[0, 0].astype(jnp.float32)                # [P, hd]
        v = v_ref[0, 0].astype(jnp.float32)                # [P, hd]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [G, P]
        pos = start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)

        m_prev = m_ref[...]                                # [G, 1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)         # [G, 1]
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                             # [G, P]
        alpha = jnp.exp(m_prev - m_new)                    # [G, 1]
        l_new = alpha * l_ref[...] + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(page_idx == num_pages - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        out_ref[0] = (acc_ref[...] / denom).astype(out_ref.dtype)


def paged_attention_decode(
    q: jax.Array,             # [B, q_heads, head_dim]
    k_pages: jax.Array,       # [kv_heads, num_pages, page_size, head_dim]
    v_pages: jax.Array,       # [kv_heads, num_pages, page_size, head_dim]
    block_tables: jax.Array,  # [B, pages_per_seq] int32
    lengths: jax.Array,       # [B] int32 (valid tokens per sequence)
    *,
    interpret: bool = False,
) -> jax.Array:
    """Flash-decode over paged KV. Returns [B, q_heads, head_dim]."""
    batch, q_heads, head_dim = q.shape
    kv_heads, num_pages, page_size, _ = k_pages.shape
    group = q_heads // kv_heads
    pages_per_seq = block_tables.shape[1]
    scale = 1.0 / (head_dim ** 0.5)

    kg = paged_attention_grid(batch, q_heads, head_dim, kv_heads,
                              num_pages, page_size, pages_per_seq)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=kg.num_scalar_prefetch,
        grid=kg.grid,
        in_specs=block_specs(kg.in_mappings),
        out_specs=block_specs(kg.out_mappings)[0],
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, head_dim), jnp.float32),
        ],
    )

    kernel = pl.pallas_call(
        functools.partial(_decode_kernel, page_size=page_size, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(
            (batch, q_heads, head_dim), q.dtype),
        interpret=interpret,
    )
    # q reshaped so that (kv_head, group) is explicit for the BlockSpec
    q4 = q.reshape(batch, kv_heads, group, head_dim)
    out = kernel(block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
                 q4.reshape(batch, kv_heads * group, head_dim), k_pages,
                 v_pages)
    return out
