"""Grid/BlockSpec introspection surface for the Pallas kernels.

Every kernel in this package describes its launch geometry — the grid, and
per-operand (array shape, block shape, index map) triples — as data before
lowering it to ``pl.pallas_call``. The kernel builds its ``BlockSpec``s
*from* this description (``block_specs``), and static analysis consumes the
same description (``tools/stepcheck`` evaluates every index map over the
full grid and proves each block access in-bounds). One source of truth:
the geometry the analyzer checks is the geometry the kernel launches.

The index maps stored here are the exact callables handed to Pallas. For a
kernel using ``PrefetchScalarGridSpec`` they take ``(*grid_indices,
*scalar_prefetch_refs)``; evaluating them with concrete integers and numpy
arrays (as stepcheck does) exercises the same arithmetic — including the
OOB-sentinel clamps — that runs on device.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Tuple

from jax.experimental import pallas as pl

IndexMap = Callable[..., Tuple[Any, ...]]


@dataclasses.dataclass(frozen=True)
class BlockMapping:
    """One operand's blocking: full array shape, block shape, index map.

    ``index_map`` returns *block* indices: element range covered along
    dim d is ``idx[d] * block_shape[d] : (idx[d] + 1) * block_shape[d]``,
    which the bounds verifier checks against ``array_shape[d]``.
    """

    name: str
    array_shape: Tuple[int, ...]
    block_shape: Tuple[int, ...]
    index_map: IndexMap


@dataclasses.dataclass(frozen=True)
class KernelGrid:
    """A kernel's full launch geometry, as data.

    ``grid`` iterates row-major with the last axis minor/sequential (the
    Pallas TPU convention all kernels here rely on for VMEM-carried
    accumulators). ``num_scalar_prefetch`` scalar operands are passed to
    every index map after the grid indices. ``in_mappings`` follow the
    kernel's operand order; ``out_mappings`` the result order.
    """

    kernel: str
    grid: Tuple[int, ...]
    in_mappings: Tuple[BlockMapping, ...]
    out_mappings: Tuple[BlockMapping, ...]
    num_scalar_prefetch: int = 0

    @property
    def mappings(self) -> Tuple[BlockMapping, ...]:
        """All mappings, inputs then outputs."""
        return self.in_mappings + self.out_mappings


def block_specs(mappings: Tuple[BlockMapping, ...]) -> List[pl.BlockSpec]:
    """Materialize ``pl.BlockSpec``s from mapping descriptors.

    This is the only path from a :class:`KernelGrid` to Pallas — kernels
    must not hand-build specs next to it, or the analyzed geometry and the
    launched geometry can drift.
    """
    return [pl.BlockSpec(m.block_shape, m.index_map) for m in mappings]
