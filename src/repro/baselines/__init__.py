"""Baseline serving policies (paper §5.1).

All baselines share the continuous-batching engine and live as policies of
``repro.core.Scheduler`` so the comparison is apples-to-apples (the paper
does the same: each baseline is integrated with continuous batching and
releases branches as they complete):

  * ``vanilla``       — no branch sampling (N = 1).
  * ``sc``            — Self-Consistency [Wang et al., ICLR'23]: N parallel
                        branches, wait for all N, majority vote.
  * ``rebase``        — reward-guided tree search [Wu et al., 2024]:
                        <= N live leaves, cull weak / fork strong every T
                        steps (see Scheduler._rebase_step).
  * ``sart_noprune``  — SART ablation: early stopping only (Fig. 6).

Use: ``SchedulerConfig(policy=<name>, ...)``.
"""
from ..core.scheduler import POLICIES, Scheduler, SchedulerConfig

__all__ = ["POLICIES", "Scheduler", "SchedulerConfig"]
