"""stablelm-1.6b — full MHA, partial rotary [hf:stabilityai/stablelm-2-1_6b]."""
from ..models import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    arch_type="dense",
    source="hf:stabilityai/stablelm-2-1_6b",
    num_layers=24,
    d_model=2048,
    vocab_size=100352,
    num_heads=32, num_kv_heads=32, head_dim=64,
    d_ff=5632,
    mlp_activation="silu", mlp_gated=True,
    rope_pct=0.25,
    norm_type="layernorm",
    max_seq_len=32768,
)
