"""qwen3-moe-235b-a22b — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B family]."""
from ..models import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    arch_type="moe",
    source="hf:Qwen/Qwen3-30B-A3B",
    num_layers=94,
    d_model=4096,
    vocab_size=151936,
    num_heads=64, num_kv_heads=4, head_dim=128,
    d_ff=1536,                      # per-expert FFN width (fine-grained)
    mlp_activation="silu", mlp_gated=True,
    num_experts=128, num_experts_per_tok=8,
    moe_capacity_factor=1.25,
    norm_topk_prob=True,
    rope_theta=1e6,
    norm_type="rmsnorm",
    max_seq_len=32768,
)
