"""qwen2-0.5b — GQA with QKV bias [arXiv:2407.10671]."""
from ..models import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    arch_type="dense",
    source="arXiv:2407.10671",
    num_layers=24,
    d_model=896,
    vocab_size=151936,
    num_heads=14, num_kv_heads=2, head_dim=64,
    qkv_bias=True,
    d_ff=4864,
    mlp_activation="silu", mlp_gated=True,
    rope_theta=1e6,
    norm_type="rmsnorm",
    tie_embeddings=True,
    max_seq_len=32768,
)
