"""hymba-1.5b — parallel attention+mamba heads per block [arXiv:2411.13676].

Hybrid-head block: attention and SSD paths read the same normed input in
parallel; outputs are averaged (the paper's learnable fusion simplified to
mean — noted in DESIGN.md). Sliding-window attention (most Hymba layers are
SWA) + constant-size SSM state -> long_500k runs with O(window) attention
state. Meta tokens are not implemented (DESIGN.md §Arch-applicability).
"""
from ..models import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    arch_type="hybrid",
    source="arXiv:2411.13676",
    num_layers=32,
    d_model=1600,
    vocab_size=32001,
    num_heads=25, num_kv_heads=5, head_dim=64,
    d_ff=5504,
    mlp_activation="silu", mlp_gated=True,
    sliding_window=1024,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_groups=1,
    ssm_chunk=128,
    norm_type="rmsnorm",
    max_seq_len=1 << 20,
)
