"""gemma-7b — GeGLU, head_dim=256, embeddings scaled by sqrt(d) [arXiv:2403.08295]."""
from ..models import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    arch_type="dense",
    source="arXiv:2403.08295",
    num_layers=28,
    d_model=3072,
    vocab_size=256000,
    num_heads=16, num_kv_heads=16, head_dim=256,
    d_ff=24576,
    mlp_activation="gelu", mlp_gated=True,   # GeGLU
    norm_type="rmsnorm",
    embedding_scale=True,
    tie_embeddings=True,
    max_seq_len=32768,
)
