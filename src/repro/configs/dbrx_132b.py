"""dbrx-132b — 16 experts top-4, fine-grained MoE [hf:databricks/dbrx-base]."""
from ..models import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    arch_type="moe",
    source="hf:databricks/dbrx-base",
    num_layers=40,
    d_model=6144,
    vocab_size=100352,
    num_heads=48, num_kv_heads=8, head_dim=128,
    d_ff=10752,
    mlp_activation="silu", mlp_gated=True,
    num_experts=16, num_experts_per_tok=4,
    moe_capacity_factor=1.25,
    rope_theta=5e5,
    norm_type="layernorm",
    max_seq_len=32768,
)
