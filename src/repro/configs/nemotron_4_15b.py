"""nemotron-4-15b — GQA, squared-ReLU MLP, 256k vocab [arXiv:2402.16819]."""
from ..models import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    arch_type="dense",
    source="arXiv:2402.16819",
    num_layers=32,
    d_model=6144,
    vocab_size=256000,
    num_heads=48, num_kv_heads=8, head_dim=128,
    d_ff=24576,
    mlp_activation="relu2", mlp_gated=False,
    rope_pct=0.5,
    norm_type="layernorm",
    max_seq_len=32768,
)
