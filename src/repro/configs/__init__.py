"""Assigned-architecture registry: ``get_config(arch_id)`` / ``--arch``.

Ten architectures from the public pool (six families) + the paper's own
model family. Each module documents its source; ``smoke(arch_id)`` returns
the reduced CPU-testable variant of the same family.
"""
from ..models import ModelConfig, smoke_variant
from . import (dbrx_132b, gemma_7b, hymba_1_5b, mamba2_130m,
               musicgen_medium, nemotron_4_15b, qwen2_0_5b, qwen2_vl_72b,
               qwen3_moe_235b, r1_distill_14b, stablelm_1_6b)

REGISTRY = {
    "mamba2-130m": mamba2_130m.CONFIG,
    "qwen2-vl-72b": qwen2_vl_72b.CONFIG,
    "dbrx-132b": dbrx_132b.CONFIG,
    "hymba-1.5b": hymba_1_5b.CONFIG,
    "qwen3-moe-235b-a22b": qwen3_moe_235b.CONFIG,
    "qwen2-0.5b": qwen2_0_5b.CONFIG,
    "stablelm-1.6b": stablelm_1_6b.CONFIG,
    "musicgen-medium": musicgen_medium.CONFIG,
    "nemotron-4-15b": nemotron_4_15b.CONFIG,
    "gemma-7b": gemma_7b.CONFIG,
    "r1-distill-14b": r1_distill_14b.CONFIG,
}

ASSIGNED = [k for k in REGISTRY if k != "r1-distill-14b"]


def get_config(arch: str) -> ModelConfig:
    if arch not in REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[arch]


def smoke(arch: str) -> ModelConfig:
    return smoke_variant(get_config(arch))
