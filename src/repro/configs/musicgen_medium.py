"""musicgen-medium — decoder-only over EnCodec tokens [arXiv:2306.05284].

Audio decoder backbone only: the EnCodec conv codec frontend is stubbed per
the assignment; input_specs() provides frame embeddings. Sinusoidal
positions, LayerNorm, non-gated GELU MLP, full MHA (kv=24), vocab = 2048
EnCodec codebook entries.
"""
from ..models import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    arch_type="audio",
    source="arXiv:2306.05284",
    num_layers=48,
    d_model=1536,
    vocab_size=2048,
    num_heads=24, num_kv_heads=24, head_dim=64,
    d_ff=6144,
    mlp_activation="gelu", mlp_gated=False,
    pos_embedding="sinusoidal",
    norm_type="layernorm",
    max_seq_len=32768,
)
