"""qwen2-vl-72b — M-RoPE, dynamic resolution [arXiv:2409.12191].

VLM decoder backbone only: the ViT vision frontend is stubbed per the
assignment; input_specs() provides patch embeddings. M-RoPE splits rotary
frequencies into (temporal, height, width) sections (16/24/24).
"""
from ..models import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    arch_type="vlm",
    source="arXiv:2409.12191",
    num_layers=80,
    d_model=8192,
    vocab_size=152064,
    num_heads=64, num_kv_heads=8, head_dim=128,
    qkv_bias=True,
    d_ff=29568,
    mlp_activation="silu", mlp_gated=True,
    pos_embedding="mrope",
    mrope_sections=(16, 24, 24),
    rope_theta=1e6,
    norm_type="rmsnorm",
    max_seq_len=32768,
)
