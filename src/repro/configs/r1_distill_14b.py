"""r1-distill-qwen-14b-like — the paper's own serving model family
[arXiv:2501.12948, DeepSeek-R1-Distill-Qwen-14B]. Not part of the assigned
pool; used by the paper-faithful serving experiments."""
from ..models import ModelConfig

CONFIG = ModelConfig(
    name="r1-distill-14b",
    arch_type="dense",
    source="arXiv:2501.12948",
    num_layers=48,
    d_model=5120,
    vocab_size=152064,
    num_heads=40, num_kv_heads=8, head_dim=128,
    qkv_bias=True,
    d_ff=13824,
    mlp_activation="silu", mlp_gated=True,
    rope_theta=1e6,
    norm_type="rmsnorm",
    max_seq_len=32768,
)
