"""mamba2-130m — SSD (state-space duality) [arXiv:2405.21060].

Pure Mamba2 stack: no attention, no MLP (d_ff=0); d_inner = 2*d_model = 1536,
head_dim 64 -> 24 SSD heads, state N=128, depthwise conv width 4.
Decode state is O(1) in sequence length -> runs long_500k natively.
"""
from ..models import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    arch_type="ssm",
    source="arXiv:2405.21060",
    num_layers=24,
    d_model=768,
    vocab_size=50280,
    num_heads=24, num_kv_heads=24,     # unused (attention-free)
    d_ff=0,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_groups=1,
    ssm_conv_width=4,
    ssm_chunk=128,
    norm_type="rmsnorm",
    pos_embedding="none",
    tie_embeddings=True,
    max_seq_len=1 << 20,
)
