from .engine import (BranchHandle, ChunkedPrefillState, Engine,
                     EngineConfig)
from .sampling import SamplingParams, sample

__all__ = ["BranchHandle", "ChunkedPrefillState", "Engine", "EngineConfig",
           "SamplingParams", "sample"]
