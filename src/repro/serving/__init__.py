from .engine import (BranchHandle, ChunkedPrefillState, Engine,
                     EngineConfig, StepVariant)
from .sampling import SamplingParams, sample

__all__ = ["BranchHandle", "ChunkedPrefillState", "Engine", "EngineConfig",
           "SamplingParams", "StepVariant", "sample"]
