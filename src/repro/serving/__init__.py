from .engine import (BranchHandle, ChunkedPrefillState, Engine,
                     EngineConfig, StepVariant)
from .faults import (CorruptedLogitsFault, EngineCrashFault, FaultInjector,
                     FaultPlan, InjectedFault, InjectedStepFault,
                     PoisonedRequestFault)
from .sampling import SamplingParams, sample

__all__ = ["BranchHandle", "ChunkedPrefillState", "Engine", "EngineConfig",
           "SamplingParams", "StepVariant", "sample",
           "CorruptedLogitsFault", "EngineCrashFault", "FaultInjector",
           "FaultPlan", "InjectedFault", "InjectedStepFault",
           "PoisonedRequestFault"]
