from .engine import BranchHandle, Engine, EngineConfig
from .sampling import SamplingParams, sample

__all__ = ["BranchHandle", "Engine", "EngineConfig", "SamplingParams",
           "sample"]
