"""Deterministic fault injection for the serving stack (chaos harness).

Production serving fails in ways the happy-path tests never exercise: the
KV pool briefly over-commits, a device step raises, logits come back NaN,
a step stalls long enough to threaten deadlines, or the whole engine
process dies mid-run. ``FaultInjector`` wraps any object implementing the
engine interface (``repro.serving.Engine`` live, ``SimEngine`` traced)
behind the *same* duck-typed surface the ``Scheduler`` already drives, and
injects those failures at points planned by a seeded ``FaultPlan`` — so
every chaos run is replayable token-for-token from ``(plan, workload
seed)`` and every fixed bug gets a deterministic regression test.

Fault taxonomy (see docs/robustness.md for how the scheduler reacts):

  * ``OutOfPagesError`` storm — ``decode_step`` raises the allocator's
    own exception *before* touching engine state, modeling transient KV
    over-commit. The scheduler's eviction path handles it.
  * ``InjectedStepFault`` — ``decode_step`` raises before delegating (the
    step never ran): a generic non-attributable engine failure.
  * ``CorruptedLogitsFault`` — the inner step *runs to completion* and
    then the wrapper raises: models NaN/garbage logits detected after
    state was already mutated. The scheduler must tolerate a step whose
    side effects landed but whose output is unusable.
  * slow step — no exception; the wrapper sets ``last_step_penalty`` so
    the scheduler charges extra clock ticks (deadline pressure).
  * ``EngineCrashFault`` — hard crash at planned step indices: the
    injector goes dead and every subsequent ``decode_step`` fails until
    ``restart()`` — the scheduler's engine-restart path must kick in.
  * ``PoisonedRequestFault`` — ``begin_prefill`` rejects any prompt
    containing ``poison_token``, *every* time: a request-attributable
    fault that must end in quarantine, never an infinite retry loop.
  * transient admission fault — ``begin_prefill`` fails at
    ``admit_fail_rate``: attributable but transient, so bounded retry
    with backoff must eventually admit the request.

Determinism contract: one uniform draw per fault category per
``decode_step`` call, in a fixed order, regardless of which rates are
enabled — so turning one category on never shifts another category's
draw sequence, and a chaos failure replays exactly from its seed.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from ..kv import OutOfPagesError


class InjectedFault(RuntimeError):
    """Base class for every failure raised by the injector."""


class InjectedStepFault(InjectedFault):
    """Non-attributable engine failure: the step never ran."""


class CorruptedLogitsFault(InjectedFault):
    """The step ran (state mutated) but produced unusable output."""


class EngineCrashFault(InjectedFault):
    """Hard crash: the engine is down until ``restart()``."""


class PoisonedRequestFault(InjectedFault):
    """Request-attributable admission failure (deterministic)."""


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Seeded description of what to inject and how often.

    Rates are per-``decode_step`` probabilities; ``crash_at`` lists
    injector step indices (the injector's own call counter, not the
    scheduler clock) that hard-crash the engine. An all-default plan
    injects nothing — the wrapper is then observationally identical to
    the bare engine (pinned by test)."""
    seed: int = 0
    oop_rate: float = 0.0         # OutOfPagesError storms
    step_rate: float = 0.0        # step-level exceptions (step never ran)
    nan_rate: float = 0.0         # corrupted logits (step ran, then raise)
    slow_rate: float = 0.0        # slow steps (extra clock ticks)
    slow_penalty: int = 8         # ticks a slow step costs beyond the 1
    crash_at: Tuple[int, ...] = ()  # decode_step indices that hard-crash
    admit_fail_rate: float = 0.0  # transient begin_prefill failures
    poison_token: Optional[int] = None  # prompts containing it never admit

    @property
    def enabled(self) -> bool:
        """False iff the plan can never inject anything."""
        return bool(self.oop_rate or self.step_rate or self.nan_rate
                    or self.slow_rate or self.crash_at
                    or self.admit_fail_rate
                    or self.poison_token is not None)

    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        """Build a plan from a CLI string: comma-separated ``key=value``
        pairs, with ``crash_at`` taking ``+``-separated step indices —
        e.g. ``"seed=3,step_rate=0.1,crash_at=50+120,poison_token=5"``."""
        kwargs = {}
        fields = {f.name: f for f in dataclasses.fields(cls)}
        for part in spec.split(","):
            part = part.strip()
            if not part:
                continue
            key, _, val = part.partition("=")
            key = key.strip()
            if key not in fields:
                raise ValueError(f"unknown FaultPlan field {key!r} "
                                 f"(have: {sorted(fields)})")
            if key == "crash_at":
                kwargs[key] = tuple(int(v) for v in val.split("+") if v)
            elif key in ("seed", "slow_penalty", "poison_token"):
                kwargs[key] = int(val)
            else:
                kwargs[key] = float(val)
        return cls(**kwargs)


class FaultInjector:
    """Engine wrapper injecting the faults a ``FaultPlan`` describes.

    Every attribute not overridden here (slots, allocator, cfg,
    spawn/fork/free/suspend/resume, prefix-cache probes, ...) delegates
    to the wrapped engine, so the ``Scheduler`` drives the wrapper
    through the identical duck-typed interface. Only ``decode_step`` and
    ``begin_prefill`` are intercepted."""

    def __init__(self, engine, plan: FaultPlan):
        self.inner = engine
        self.plan = plan
        self._rng = np.random.default_rng(plan.seed)
        self._crash_set = frozenset(plan.crash_at)
        self.steps_seen = 0           # injector call counter (crash_at base)
        self.dead = False             # crashed and not yet restarted
        self.last_step_penalty = 0    # extra ticks the last step cost
        self.counts = {"oop": 0, "step": 0, "nan": 0, "slow": 0,
                       "crash": 0, "admit": 0, "poisoned": 0, "restarts": 0}

    def __getattr__(self, name):
        # only reached for names not set on the wrapper itself
        return getattr(self.inner, name)

    # ------------------------------------------------------------ intercepts
    def decode_step(self):
        """Delegate one decode step, injecting per the plan. Draw order is
        fixed (oop, step, nan, slow) and unconditional so the stream stays
        aligned whichever categories are enabled."""
        self.last_step_penalty = 0
        if self.dead:
            raise EngineCrashFault(
                "engine is down (crashed; restart() required)")
        idx = self.steps_seen
        self.steps_seen += 1
        u_oop, u_step, u_nan, u_slow = self._rng.random(4)
        if idx in self._crash_set:
            self.dead = True
            self.counts["crash"] += 1
            raise EngineCrashFault(f"injected hard crash at step {idx}")
        if u_oop < self.plan.oop_rate:
            self.counts["oop"] += 1
            raise OutOfPagesError(f"injected OutOfPages storm at step {idx}")
        if u_step < self.plan.step_rate:
            self.counts["step"] += 1
            raise InjectedStepFault(f"injected step fault at step {idx}")
        out = self.inner.decode_step()
        if u_nan < self.plan.nan_rate:
            # the inner step already ran: state mutated, output unusable
            self.counts["nan"] += 1
            raise CorruptedLogitsFault(
                f"injected corrupted logits at step {idx}")
        if u_slow < self.plan.slow_rate:
            self.counts["slow"] += 1
            self.last_step_penalty = self.plan.slow_penalty
        return out

    def begin_prefill(self, prompt):
        """Delegate admission, rejecting poisoned prompts (always) and a
        seeded fraction of the rest (transient)."""
        if (self.plan.poison_token is not None
                and self.plan.poison_token in prompt):
            self.counts["poisoned"] += 1
            raise PoisonedRequestFault(
                f"prompt contains poison token {self.plan.poison_token}")
        if (self.plan.admit_fail_rate
                and self._rng.random() < self.plan.admit_fail_rate):
            self.counts["admit"] += 1
            raise InjectedStepFault("injected transient admission fault")
        return self.inner.begin_prefill(prompt)

    # ------------------------------------------------------------- lifecycle
    def restart(self) -> None:
        """Bring a crashed engine back up (scheduler restart path). The
        wrapped engine object survives — in this model a crash kills the
        serving pipeline, not the KV pool, so warm prefix-cache pages
        remain valid for resurrection on re-admission."""
        self.dead = False
        self.counts["restarts"] += 1
        inner_restart = getattr(self.inner, "restart", None)
        if inner_restart is not None:
            inner_restart()

    def fault_stats(self) -> dict:
        """Injection counters for ``Scheduler.metrics()['faults']``."""
        return dict(self.counts, steps_seen=self.steps_seen)
