"""Trace-driven serving simulator for paper-scale experiments.

The CPU container can only run tiny live models, but the paper's end-to-end
claims (Fig. 5/6/7) are about *scheduling* at realistic response lengths
(thousands of tokens) and arrival rates. ``SimEngine`` implements the exact
host-side interface of ``repro.serving.Engine`` — including the real
``PageAllocator`` for KV memory accounting — but branches play back sampled
length/quality traces instead of running a model. The unmodified
``repro.core.Scheduler`` (Algorithm 1 and every baseline policy) drives it,
so the scheduling logic under test is byte-identical to the live engine's.

Length model: mixture of a lognormal body and an over-thinking tail
(paper §3 Obs. 1: lengths vary substantially per request; correctness is
weakly related to length). Reward model: the PRM's discriminability is
parameterized — rewards drift toward 1 (right-thinking) or 0 (wrong) as the
branch progresses, with noise.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional

import numpy as np

from ..data import tokenizer as tk
from ..kv import (BranchBlocks, OutOfPagesError, PageAllocator,
                  PrefixCache)
from .engine import (BranchHandle, ChunkedPrefillState, StepVariant,
                     derive_lane_configs,
                     pack_chunk_lanes)


@dataclasses.dataclass(frozen=True)
class SimWorkload:
    """Distribution of branch behaviour for one experiment."""
    mean_len: float = 2000.0          # lognormal body, tokens
    sigma_len: float = 0.6
    overthink_p: float = 0.12         # probability of the long-tail mode
    overthink_mult: float = 4.0       # tail length multiplier
    correct_p: float = 0.55           # P(branch reaches a correct answer)
    prm_drift: float = 3.0            # reward drift magnitude (discriminability)
    prm_noise: float = 0.12
    prompt_len: int = 64
    # Last ``prompt_tail`` prompt tokens are request-distinct (the rest is
    # a shared few-shot header) — the workload shape prefix caching
    # exploits. 0 keeps the legacy identical prompts.
    prompt_tail: int = 0
    # NOTE: correctness is sampled independently of length (paper Obs. 1)


@dataclasses.dataclass(frozen=True)
class SimEngineConfig:
    max_slots: int = 64               # decode batch B
    page_size: int = 16
    num_pages: int = 65536            # models HBM KV capacity
    eos_id: int = tk.EOS
    prefill_chunk: int = 64           # prompt tokens prefilled per step
    chunked_prefill: bool = True      # piggyback chunks on decode steps
    # Token-budget lane scheduling, mirroring EngineConfig: a decode step
    # carries up to this many chunk-row tokens drawn from multiple pending
    # prefills (0 = legacy single-lane FIFO, one chunk per step). The sim
    # has a single bucket (prefill_chunk), so the lane count per step is
    # at most step_token_budget // prefill_chunk.
    step_token_budget: int = 0
    prefill_starvation_bound: int = 4
    # Radix page-hash prompt prefix cache, mirroring EngineConfig: warm
    # admission skips the cached page-aligned prefix's chunk steps (and
    # pages), so ttfb under shared-header workloads improves. Off by
    # default (timing-identical to the seed).
    prefix_cache: bool = False


@dataclasses.dataclass
class _BranchSpec:
    length: int                       # tokens this branch will generate
    correct: bool
    quality: float                    # asymptotic PRM reward


@dataclasses.dataclass
class SimTask:
    answer: int = 7                   # the request's true answer digit


class SimEngine:
    """Drop-in Engine substitute: plays back sampled branch traces."""

    def __init__(self, cfg: SimEngineConfig, workload: SimWorkload,
                 seed: int = 0):
        self.cfg = cfg
        self.workload = workload
        # branch destinies and PRM noise draw from SEPARATE streams: spec
        # draws then depend only on spawn order, so scheduling/timing changes
        # (or policy choice, at equal seed) never re-roll the workload —
        # tail-latency comparisons stay paired instead of re-sampled
        self.rng = np.random.default_rng(seed)
        self.noise_rng = np.random.default_rng(seed + 0x5AB7)
        self.allocator = PageAllocator(cfg.num_pages, cfg.page_size)
        self.slots: List[Optional[BranchHandle]] = [None] * cfg.max_slots
        self._specs: Dict[int, _BranchSpec] = {}
        self.tasks: Dict[int, SimTask] = {}   # request_id -> SimTask
        self._next_branch_id = 0
        self.decode_steps_executed = 0
        self.prefill_chunk_steps = 0
        self.mixed_steps_executed = 0
        self._pending_prefills: List[ChunkedPrefillState] = []
        if cfg.step_token_budget > 0 and not cfg.chunked_prefill:
            raise ValueError("step_token_budget requires chunked_prefill "
                             "(mirror of the Engine contract)")
        self._lane_configs = derive_lane_configs(
            (), cfg.step_token_budget, cfg.prefill_chunk)
        if cfg.prefix_cache and not cfg.chunked_prefill:
            raise ValueError("prefix_cache requires chunked_prefill "
                             "(mirror of the Engine contract)")
        self.prefix_cache = (PrefixCache(self.allocator)
                             if cfg.prefix_cache else None)

    # ----------------------------------------------------- engine interface
    @property
    def free_slots(self) -> List[int]:
        """Unoccupied decode-slot indices, ascending."""
        return [i for i, s in enumerate(self.slots) if s is None]

    @property
    def num_active(self) -> int:
        """Occupied decode slots."""
        return sum(s is not None for s in self.slots)

    def live_tokens(self) -> int:
        """Total tokens resident in the KV pool (paper Fig. 3)."""
        return sum(s.blocks.length for s in self.slots if s is not None)

    def prefill(self, prompt: List[int]):
        """Legacy synchronous prefill: allocate the prompt's pages in one
        shot. Returns ``(blocks, last_logits, ssm_state)`` — the latter two
        are None (the sim plays back traces, no model runs)."""
        blocks = self.allocator.alloc_prefix(len(prompt))
        return blocks, None, None

    # ------------------------------------------- chunked admission interface
    def begin_prefill(self, prompt: List[int]) -> ChunkedPrefillState:
        """Mirror of Engine.begin_prefill: allocate the prompt's pages up
        front, then account one ``prefill_chunk``-token chunk per decode
        step. With the prefix cache, the longest cached page-aligned
        prefix is increfed into the block list and chunk accounting starts
        at the first uncached token (warm hits skip those chunk steps and
        pages). With chunking disabled the state completes immediately
        (the scheduler then charges the legacy synchronous prefill
        tick)."""
        if self.prefix_cache is None:
            blocks, cached = self.allocator.alloc_prefix(len(prompt)), 0
        else:
            blocks, _ = self.prefix_cache.admit(prompt)
            cached = blocks.num_shared * self.cfg.page_size
        st = ChunkedPrefillState(prompt=list(prompt), blocks=blocks,
                                 next_pos=cached, cached_tokens=cached)
        if not self.cfg.chunked_prefill:
            st.next_pos = len(prompt)
            st.done = True
            return st
        self._pending_prefills.append(st)
        return st

    def finish_prefill(self, st: ChunkedPrefillState):
        """Harvest a completed chunked prefill: ownership of its pages
        passes to the branches forked off it (mirror of
        ``Engine.finish_prefill``)."""
        assert st.done, "prefill still has pending chunks"
        st.harvested = True
        return st.blocks, st.last_logits, st.ssm_state

    def abort_prefill(self, st: ChunkedPrefillState) -> None:
        """Mirror of Engine.abort_prefill: harvested states no longer own
        their pages (branches fork off them), so only unharvested aborts
        release."""
        if st in self._pending_prefills:
            self._pending_prefills.remove(st)
        if not st.harvested:
            self.allocator.release(st.blocks)
        st.done = True

    @property
    def has_pending_prefill(self) -> bool:
        """True while any admitted prompt still has chunks to account."""
        return bool(self._pending_prefills)

    @property
    def admission_capacity(self) -> int:
        """Mirror of Engine.admission_capacity: max chunk lanes one step
        can carry under the token budget (1 = legacy FIFO)."""
        return self._lane_configs[-1]

    def step_variants(self) -> List[StepVariant]:
        """Mirror of ``Engine.step_variants`` for the name/lane_buckets
        enumeration (``args=None`` — the simulator has no step program).
        The simulator has a single bucket (``prefill_chunk``), so the
        variant set is 1 + len(lane_configs); tools/stepcheck asserts
        this stays a projection of the Engine enumeration."""
        variants = [StepVariant("decode", ())]
        bucket = self.cfg.prefill_chunk
        for n in self._lane_configs:
            variants.append(
                StepVariant(f"mixed:b{bucket}xl{n}", (bucket,) * n))
        return variants

    def prefix_cache_stats(self):
        """Mirror of Engine.prefix_cache_stats (None with the cache off)."""
        return (self.prefix_cache.stats()
                if self.prefix_cache is not None else None)

    def match_cached_tokens(self, prompt: List[int]) -> int:
        """Mirror of Engine.match_cached_tokens: non-mutating LPM probe
        (the sim plays back traces, so no SSM gating applies)."""
        if self.prefix_cache is None:
            return 0
        return self.prefix_cache.match_tokens(prompt)

    def _advance_pending_prefill(self) -> None:
        """Account the chunk lanes riding this decode step: the same
        ``pack_chunk_lanes`` the live engine uses selects which pending
        prefills advance (oldest-first under ``step_token_budget``, with
        the starvation bound), each by one ``prefill_chunk``."""
        lanes, _ = pack_chunk_lanes(
            self._pending_prefills, budget=self.cfg.step_token_budget,
            chunk_bucket=lambda st: self.cfg.prefill_chunk,
            lane_configs=self._lane_configs,
            starvation_bound=self.cfg.prefill_starvation_bound)
        if lanes:
            self.mixed_steps_executed += 1
        for st in lanes:
            st.next_pos = min(st.next_pos + self.cfg.prefill_chunk,
                              len(st.prompt))
            self.prefill_chunk_steps += 1
            if st.next_pos >= len(st.prompt):
                st.done = True
                self._pending_prefills.remove(st)
                if self.prefix_cache is not None:
                    self.prefix_cache.insert(st.prompt, st.blocks.pages)

    def _sample_spec(self) -> _BranchSpec:
        w = self.workload
        ln = self.rng.lognormal(math.log(w.mean_len), w.sigma_len)
        if self.rng.random() < w.overthink_p:
            ln *= w.overthink_mult    # over-thinking dilemma tail
        correct = bool(self.rng.random() < w.correct_p)
        quality = 0.85 if correct else 0.25
        return _BranchSpec(length=max(int(ln), 4), correct=correct,
                           quality=quality)

    def spawn_branch(self, request_id: int, prefix_blocks: BranchBlocks,
                     last_logits, ssm_state, prompt_len: int,
                     prompt_tokens: Optional[List[int]] = None
                     ) -> Optional[BranchHandle]:
        """Seat a new branch sharing the request's prefix pages, sampling
        its destiny (length/correctness/quality) from the workload.
        Returns None when no decode slot is free. ``prompt_tokens``
        mirrors Engine.spawn_branch: it keys the branch's generated full
        pages into the prefix cache at completion and page-aligned decode
        boundaries."""
        free = self.free_slots
        if not free:
            return None
        slot = free[0]
        blocks = self.allocator.fork(prefix_blocks)
        h = BranchHandle(branch_id=self._next_branch_id,
                         request_id=request_id, slot=slot, blocks=blocks,
                         tokens=[tk.STEP], prompt_len=prompt_len,
                         prompt_tokens=(list(prompt_tokens)
                                        if prompt_tokens is not None
                                        else None))
        self._next_branch_id += 1
        self._specs[h.branch_id] = self._sample_spec()
        self.slots[slot] = h
        return h

    def fork_branch(self, parent: BranchHandle) -> Optional[BranchHandle]:
        """Seat a copy-on-write child of a live branch (rebase expansion):
        shares all parent pages, inherits its tokens, resamples the
        remaining destiny. Returns None when no slot is free."""
        free = self.free_slots
        if not free:
            return None
        slot = free[0]
        blocks = self.allocator.fork(parent.blocks)
        h = BranchHandle(branch_id=self._next_branch_id,
                         request_id=parent.request_id, slot=slot,
                         blocks=blocks, tokens=list(parent.tokens),
                         prompt_len=parent.prompt_len,
                         prompt_tokens=(list(parent.prompt_tokens)
                                        if parent.prompt_tokens is not None
                                        else None))
        self._next_branch_id += 1
        # child inherits progress; resamples its remaining destiny
        self._specs[h.branch_id] = self._sample_spec()
        self.slots[slot] = h
        return h

    def pages_needed_for_step(self) -> int:
        """Worst-case fresh pages the next decode step may allocate
        (boundary pages + CoW copies) — the admission-control pre-check
        ``decode_step`` runs before touching the allocator."""
        ps = self.cfg.page_size
        need = 0
        for h in self.slots:
            if h is None:
                continue
            b = h.blocks
            if self.allocator.needs_cow(b):
                need += 1
            if b.length % ps == 0 and b.length // ps == len(b.pages):
                need += 1
        return need

    def decode_step(self) -> Dict[int, int]:
        """One simulated decode step: account a page per active branch,
        advance pending prefill chunk lanes, and emit each branch's next
        trace token. Returns {slot: token} (mirror of
        ``Engine.decode_step``)."""
        if self.num_active == 0 and not self._pending_prefills:
            return {}
        if self.pages_needed_for_step() > self.allocator.free_pages:
            raise OutOfPagesError("sim KV pool exhausted")
        self._advance_pending_prefill()   # chunk piggybacks on this step
        out = {}
        # reprolint REP002 baselined: the pages_needed_for_step pre-check
        # above reserves this loop's worst case (mirror of Engine)
        for slot, h in enumerate(self.slots):
            if h is None:
                continue
            self.allocator.append_token(h.blocks)
            spec = self._specs[h.branch_id]
            gen = len(h.tokens)
            if gen >= spec.length:
                # emit the answer tail then EOS
                task = self.tasks.get(h.request_id, SimTask())
                ans = task.answer if spec.correct else (task.answer + 1) % 10
                if h.tokens[-1] != tk.ANSWER and not tk.is_digit(h.tokens[-1]):
                    tok = tk.ANSWER
                elif h.tokens[-1] == tk.ANSWER:
                    tok = tk.digit(ans)
                else:
                    tok = tk.EOS
            else:
                tok = tk.STEP
            h.tokens.append(tok)
            out[slot] = tok
            if (self.prefix_cache is not None
                    and h.prompt_tokens is not None
                    and h.blocks.length % self.cfg.page_size == 0):
                # page-aligned decode boundary: publish generated full
                # pages without waiting for completion (Engine mirror)
                self._insert_generated(h)
        self.decode_steps_executed += 1
        return out

    def suspend_branch(self, h: BranchHandle) -> None:
        """Vacate a branch's decode slot, keeping its pages (preemption);
        ``resume_branch`` reseats it."""
        assert self.slots[h.slot] is h
        self.slots[h.slot] = None
        h.slot = -1

    def resume_branch(self, h: BranchHandle) -> bool:
        """Reseat a suspended branch; False when no slot is free."""
        free = self.free_slots
        if not free:
            return False
        h.slot = free[0]
        self.slots[h.slot] = h
        return True

    def _insert_generated(self, h: BranchHandle) -> None:
        """Mirror of Engine._insert_generated: key the branch's generated
        full pages into the prefix cache by prompt + generated tokens (the
        trailing partial page keeps private CoW semantics)."""
        if self.prefix_cache is None or h.prompt_tokens is None:
            return
        written = h.blocks.length - h.prompt_len
        if written <= 0:
            return
        key = list(h.prompt_tokens) + h.tokens[:written]
        self.prefix_cache.insert(key, h.blocks.pages)

    def free_branch(self, h: BranchHandle):
        """Eagerly release a terminated branch's pages and its slot
        (inserting its generated full pages into the prefix cache first,
        so they park warm on the LRU instead of freeing)."""
        self._insert_generated(h)
        self.allocator.release(h.blocks)
        if h.slot >= 0:
            self.slots[h.slot] = None
        self._specs.pop(h.branch_id, None)
        h.done = True

    def release_prefix(self, prefix_blocks: BranchBlocks):
        """Drop the request's own reference on its prompt pages (the last
        sibling's release then frees or LRU-parks them)."""
        self.allocator.release(prefix_blocks)

    # ------------------------------------------------------------ PRM model
    def reward_of(self, h: BranchHandle) -> float:
        """Simulated PRM reward: drifts from 0.5 toward the branch's
        latent quality as generation progresses, plus noise, in [0, 1]."""
        spec = self._specs.get(h.branch_id)
        if spec is None:
            return 0.5
        w = self.workload
        progress = min(len(h.tokens) / spec.length, 1.0)
        # reward drifts from neutral 0.5 toward the branch's quality as the
        # PRM sees more of the trajectory (discriminability = prm_drift)
        logit = math.log(spec.quality / (1 - spec.quality)) \
            * progress * w.prm_drift / 2
        r = 1 / (1 + math.exp(-logit)) + self.noise_rng.normal(0, w.prm_noise)
        return float(np.clip(r, 0.0, 1.0))


class SimPRM:
    """PRM protocol over SimEngine's reward model."""

    def __init__(self, engine: SimEngine):
        self.engine = engine

    def score(self, request, handles) -> List[float]:
        """Reward per handle from the engine's simulated PRM model."""
        return [self.engine.reward_of(h) for h in handles]


def poisson_burst_arrivals(num_requests: int, *, burst_gap: int,
                           burst_mean: float, seed: int = 7) -> List[int]:
    """Arrival clocks for bursts every ``burst_gap`` decode steps, each of
    1 + Poisson(burst_mean) simultaneous requests — the bursty workload
    the token-budget chunk lanes are sized for (docs/scheduling.md)."""
    rng = np.random.default_rng(seed)
    times, t = [], 0
    while len(times) < num_requests:
        times += [t] * (1 + int(rng.poisson(burst_mean)))
        t += burst_gap
    return sorted(times[:num_requests])


def adversarial_shared_header_mix(num_warm: int = 6, num_cold: int = 8, *,
                                  prompt_len: int = 512,
                                  header_len: int = 448,
                                  burst_at: int = 160, seed: int = 0):
    """Workload for cache-aware admission studies: ``(prompts, arrivals)``.

    A seeder request (arrival 0) plants a shared few-shot header in the
    radix prefix cache; once it finishes, its pages idle on the cache's
    LRU free-list. Then one burst arrives in which ``num_cold`` fully
    distinct prompts are *submitted ahead of* the ``num_warm``
    header-sharing ones — adversarial for FIFO admission under page
    pressure: the colds' prompt allocations drain the free list and evict
    the idle header pages before the warms are admitted, so the warms
    miss. LPM ordering probes the cache, admits the warm matches first,
    and thereby *pins* the header pages (increfed = not evictable) while
    the colds queue behind. Size ``num_pages`` tight enough that the
    colds actually force eviction (see ``benchmarks/fig5_e2e.py``).
    """
    rng = np.random.default_rng(seed + 0x11A)
    tail = prompt_len - header_len
    hdr = [tk.BOS] + [tk.digit(0)] * (header_len - 1)
    prompts = [hdr + [tk.digit(9)] * (tail - 1) + [tk.EQUALS]]   # seeder
    times = [0]
    for _ in range(num_cold):
        prompts.append([tk.BOS] + [tk.digit(int(d)) for d in
                                   rng.integers(0, 10, size=prompt_len - 2)]
                       + [tk.EQUALS])
        times.append(burst_at)
    for i in range(num_warm):
        prompts.append(hdr + [tk.digit(1 + i % 8)] * (tail - 1)
                       + [tk.EQUALS])
        times.append(burst_at)
    return prompts, times


def mixed_deadline_workload(num_loose: int = 6, num_tight: int = 4, *,
                            loose_slack: int = 800, tight_slack: int = 100,
                            tight_lag: int = 2):
    """Workload for SLO-aware admission studies: ``(arrivals, deadlines)``.

    ``num_loose`` requests with a generous deadline arrive first (and are
    submitted first), then ``num_tight`` urgent requests arrive
    ``tight_lag`` ticks later with a tight absolute deadline. Under
    serialized admission (single chunk lane), FIFO serves the loose
    backlog first and the tight requests blow their deadlines waiting;
    EDF reorders the arrived set by deadline and meets them."""
    times = [0] * num_loose + [tight_lag] * num_tight
    deadlines = [t + loose_slack for t in times[:num_loose]] + \
                [t + tight_slack for t in times[num_loose:]]
    return times, deadlines


def run_sim_experiment(policy: str, n: int, *, num_requests: int = 40,
                       arrival_gap: int = 0, workload: SimWorkload = None,
                       engine_cfg: SimEngineConfig = None, window: int = 400,
                       max_tokens: int = 1 << 30, seed: int = 0,
                       m: int = 0, alpha: float = 0.5, beta: int = 0,
                       arrival_times: Optional[List[int]] = None,
                       admission_policy: str = "fifo",
                       deadlines: Optional[List[Optional[int]]] = None,
                       priorities: Optional[List[int]] = None,
                       prompts: Optional[List[List[int]]] = None,
                       max_steps: int = 200_000_000,
                       fault_plan=None):
    """One simulated serving run; returns (metrics, accuracy).

    ``arrival_gap`` is the decode-step gap between request arrivals (the
    decode-step analogue of the paper's 1 vs 4 requests/second rates).
    ``arrival_times`` overrides it with an explicit per-request arrival
    clock (e.g. Poisson bursts for the chunk-lane ttfb experiments).

    ``admission_policy`` selects the ordering over the arrived set
    (``repro.core.policies``); ``deadlines`` (absolute clocks) and
    ``priorities`` annotate requests for edf/priority ordering and the
    SLO-attainment metrics. ``prompts`` overrides the built-in
    shared-header prompt builder with explicit per-request token lists
    (e.g. adversarial warm/cold mixes for cache-aware policy studies).
    Accuracy counts only finished requests but divides by all submitted,
    so an overload run (``max_steps``) scores what it actually served.

    ``fault_plan`` (a ``repro.serving.FaultPlan``) wraps the SimEngine in
    a seeded ``FaultInjector`` for chaos runs — the scheduler then drives
    the wrapper through the identical duck-typed interface, so fault-free
    plans stay bit-exact with the unwrapped engine.
    """
    from ..core import OraclePRM, Scheduler, SchedulerConfig
    from ..data.tasks import extract_answer
    from .faults import FaultInjector

    workload = workload or SimWorkload()
    engine_cfg = engine_cfg or SimEngineConfig()
    engine = SimEngine(engine_cfg, workload, seed=seed)
    prm = SimPRM(engine)
    driven = (FaultInjector(engine, fault_plan)
              if fault_plan is not None else engine)
    cfg = SchedulerConfig(policy=policy, n=n, m=m, alpha=alpha, beta=beta,
                          window=window, max_tokens=max_tokens,
                          admission_policy=admission_policy)
    sch = Scheduler(driven, prm, cfg, answer_fn=extract_answer)
    rng = np.random.default_rng(seed + 1)
    for i in range(num_requests):
        task = SimTask(answer=int(rng.integers(0, 10)))
        if prompts is not None:
            prompt = list(prompts[i])
        else:
            # shared few-shot header + (optionally) a request-distinct
            # tail — the prefix-caching workload shape; prompt_tail=0
            # keeps the legacy identical prompts
            tail = min(workload.prompt_tail, workload.prompt_len - 2)
            prompt = [tk.BOS] \
                + [tk.digit(0)] * (workload.prompt_len - 2 - tail) \
                + [tk.digit(i % 10)] * tail + [tk.EQUALS]
        arrival = (arrival_times[i] if arrival_times is not None
                   else i * arrival_gap)
        req = sch.submit(prompt, payload=task, arrival=arrival,
                         deadline=deadlines[i] if deadlines else None,
                         priority=priorities[i] if priorities else 0)
        engine.tasks[req.request_id] = task
    metrics = sch.run(max_steps=max_steps)
    correct = sum(
        1 for r in metrics["requests"]
        if r["answer"] is not None
        and r["answer"] == engine.tasks[r["request_id"]].answer)
    accuracy = correct / max(len(metrics["requests"]), 1)
    return metrics, accuracy
