"""Token sampling: temperature / top-k / top-p, vmappable per slot."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    temperature: float = 1.0
    top_k: int = 0          # 0 -> disabled
    top_p: float = 1.0      # 1.0 -> disabled


def apply_top_k(logits, k: int):
    """Mask all but the k highest logits to NEG_INF (no-op for k<=0 or
    k >= vocab)."""
    if k <= 0 or k >= logits.shape[-1]:
        return logits
    kth = jnp.sort(logits, axis=-1)[..., -k][..., None]
    return jnp.where(logits < kth, NEG_INF, logits)


def apply_top_p(logits, p: float):
    """Nucleus filtering: mask logits outside the smallest set whose
    probability mass reaches p (top-1 always kept; no-op for p >= 1)."""
    if p >= 1.0:
        return logits
    sorted_logits = jnp.sort(logits, axis=-1)[..., ::-1]
    probs = jax.nn.softmax(sorted_logits, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    # keep tokens while cumulative prob (exclusive) < p; always keep top-1
    keep_sorted = jnp.concatenate(
        [jnp.ones_like(cum[..., :1], bool), cum[..., :-1] < p], axis=-1)
    # threshold = smallest kept logit
    thresh = jnp.min(
        jnp.where(keep_sorted, sorted_logits, jnp.inf), axis=-1, keepdims=True)
    return jnp.where(logits < thresh, NEG_INF, logits)


def sample(rng, logits, params: SamplingParams):
    """logits [..., V] -> token ids [...]. Greedy when temperature == 0."""
    if params.temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / params.temperature
    logits = apply_top_k(logits, params.top_k)
    logits = apply_top_p(logits, params.top_p)
    return jax.random.categorical(rng, logits, axis=-1).astype(jnp.int32)
