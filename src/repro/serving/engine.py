"""Continuous-batching serving engine over the paged KV cache.

The engine is the substrate Algorithm 1 (the SART scheduler) drives:

  * fixed ``max_slots`` decode batch (XLA static shapes) — a slot holds one
    *branch*; prune/complete frees the slot for the branch queue, which is
    exactly the paper's branch-granularity continuous batching;
  * prefill runs once per request; the resulting prefix pages are shared by
    all N sibling branches (ref-counted, copy-on-write on the trailing
    partial page);
  * decode steps are jit'd; host-side page accounting (boundary allocation,
    CoW) runs between steps, mirroring vLLM's CPU block manager;
  * the decode step also returns the last hidden state per slot, which feeds
    the PRM reward head with zero extra forwards (TPU adaptation: the paper
    runs a separate 7B PRM server).

On CPU the paged attention uses the vectorized jnp reference path; on TPU the
same call dispatches to the Pallas flash-decode kernel.

Public contracts (documented in docs/architecture.md and
docs/scheduling.md, which deep-link here):

  * **Admission is non-blocking**: ``begin_prefill`` reserves the prompt's
    pages up front (failing fast with ``OutOfPagesError``, allocating
    nothing) and queues a ``ChunkedPrefillState``; its chunks ride later
    ``decode_step`` calls as extra rows — one FIFO chunk per step, or up
    to ``EngineConfig.step_token_budget`` chunk-row tokens packed from
    several pending prefills as concurrent lanes (``pack_chunk_lanes``:
    oldest-first with a starvation bound).
  * **Harvested ownership**: once ``finish_prefill`` returns, the state's
    pages belong to the caller — ``abort_prefill`` on a harvested state
    only detaches it from the queue; releasing again would double-decref
    pages that live branches share.
  * **Bounded compiles**: mixed-step shapes are O(len(prefill_buckets) x
    len(chunk_lane_configs)) — all lanes of a step pad to one shared
    bucket, and lane counts round down into a small allowed set
    (``prefill_compile_count`` counts traced shapes).
  * **Inert rows never perturb state**: sentinel block-table entries drop
    K/V page writes and the ``slot_valid`` mask freezes per-slot SSM rows,
    so empty slots, suspended branches and standalone chunk draining leave
    live state bit-identical.
  * **Prefix-cache admission skips served tokens**: with
    ``EngineConfig.prefix_cache``, ``begin_prefill`` increfs the longest
    cached page-aligned prefix into the request's block list and chunks
    from the first uncached token; warm hits write zero K/V bytes and
    burn zero prefill FLOPs for shared tokens, and cache-on vs cache-off
    stays bit-exact on tokens/logits (docs/scheduling.md
    "Prefix caching").
  * **One page dispatch per step**: the step's CoW page copies and all
    lanes' chunk K/V writes execute inside the single jit'd step program
    (fused gather/scatter with OOB-sentinel padding) — no separate
    host-issued device copies, whatever the lane count.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.flash_prefill.ops import paged_flash_prefill
from ..kernels.paged_attention.ops import (paged_attention,
                                           paged_tree_attention)
from ..kv import (BranchBlocks, OutOfPagesError, PageAllocator,
                  PrefixCache, tree_decode_map)
from ..models.attention import _project_qkv, _rotate
from ..models.config import ModelConfig
from ..models.layers import (apply_mlp, apply_norm, embed_tokens,
                             sinusoidal_embedding, unembed)
from ..models.mamba2 import (init_mamba2_state, mamba2_decode,
                             mamba2_forward)
from ..models.model import Model
from ..models.moe import apply_moe
from .sampling import SamplingParams, sample


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    page_size: int = 16
    num_pages: int = 512
    max_slots: int = 16              # decode batch size B
    max_pages_per_branch: int = 64   # static block-table width
    max_branch_tokens: int = 512     # hard length cap per branch
    eos_id: int = 1
    sampling: SamplingParams = SamplingParams(temperature=1.0, top_p=0.95)
    seed: int = 0
    # Chunked prefill (all model families): prompts are split into
    # ``prefill_chunk``-token chunks, each padded up to one of
    # ``prefill_buckets`` and run as extra rows of the decode step, so
    # admission piggybacks on decode instead of stalling it and the number
    # of compiled prefill shapes is O(len(buckets)), not O(distinct prompt
    # lengths). ssm/hybrid chunk rows run a masked-dt scan (pad positions
    # are identity state transitions) with the running SSM state carried
    # across chunks. () derives buckets as (chunk // 2, chunk).
    chunked_prefill: bool = True
    prefill_chunk: int = 64
    prefill_buckets: tuple = ()
    # Chunk-row attention path of the mixed step. "fused" runs the chunk's
    # rows as one paged flash-prefill pass over the request's block table
    # (O(context) HBM reads per q block); "decode" is the fallback that
    # re-uses the per-token flash-decode path for every chunk row
    # (O(chunk · context) reads), kept for equivalence testing.
    mixed_step_kernel: str = "fused"
    # Decode-slot attention path. "paged" is the per-branch flash-decode
    # kernel (every branch streams its whole context, shared ancestor
    # pages once PER sibling); "tree" splits the step over a branch×page
    # dedup map built from the slots' fork topology
    # (``repro.kv.tree_decode_map``) so each shared ancestor page is
    # streamed once per step for all descendant branches and the
    # per-branch pass only covers post-fork pages. Bit-exact vs "paged"
    # (the jnp ref reconstructs identical full tables on CPU); requires
    # ``mixed_step_kernel="fused"`` — the "decode" fallback runs decode
    # slots and chunk rows through one per-branch call.
    decode_kernel: str = "paged"
    # Token-budget lane scheduling (vLLM-style): a mixed step carries up to
    # ``step_token_budget`` chunk-row tokens drawn from MULTIPLE in-flight
    # prefills (one lane per request, all lanes padded to one shared
    # bucket), instead of one FIFO chunk per step. 0 keeps the legacy
    # single-lane FIFO (bit-exact pre-lane behaviour). Must be >= the
    # largest prefill bucket when set.
    step_token_budget: int = 0
    # Allowed lane counts per mixed step. The packer rounds the number of
    # selected lanes DOWN to the nearest entry, so compiled mixed-step
    # shapes stay O(len(prefill_buckets) * len(chunk_lane_configs)).
    # () derives powers of two up to step_token_budget // max_bucket.
    chunk_lane_configs: tuple = ()
    # A pending prefill skipped by the packer (its chunk didn't fit the
    # remaining budget) more than this many times blocks packing past it:
    # no younger request overtakes it again; it then waits only on older
    # requests draining (oldest-first, bounded overtaking).
    prefill_starvation_bound: int = 4
    # Radix page-hash prompt prefix cache (docs/scheduling.md "Prefix
    # caching"): admission looks up the longest cached page-aligned prefix
    # of the prompt, increfs those pages into the request's BranchBlocks
    # and starts chunking at the first uncached token — warm hits skip
    # both the prefill compute and the K/V page writes for shared tokens
    # (few-shot headers, shared system prompts). Refcount-0 cached pages
    # park on an LRU free-list and are evicted only under page pressure.
    # Off by default: enabling changes admission *timing* (fewer chunk
    # steps on hits), though tokens/logits stay bit-exact.
    prefix_cache: bool = False


@dataclasses.dataclass(eq=False)    # identity equality: the admission
# queue holds several states at once and `in`/`remove` must never confuse
# two requests that happen to share a prompt
class ChunkedPrefillState:
    """A partially-prefilled request: pages fill chunk-by-chunk while the
    decode batch keeps stepping. ``done`` flips once the final chunk has
    been written and the last-position logits are available for
    ``spawn_branch``. For ssm/hybrid configs ``ssm_state`` carries the
    running per-layer (conv, ssd) state between chunks; it ends up holding
    exactly what the exact-length path returns. ``harvested`` flips in
    ``finish_prefill`` — from then on the pages belong to the caller and
    ``abort_prefill`` must not release them. With the prefix cache,
    ``next_pos`` starts at the cached page-aligned boundary
    (``cached_tokens``) and ``ssm_snaps`` collects (conv, ssd) snapshots
    at page-aligned chunk boundaries for cache insertion."""
    prompt: List[int]
    blocks: BranchBlocks
    next_pos: int = 0                # prompt tokens written so far
    last_logits: object = None
    ssm_state: object = None         # [L,1,...] (conv, ssd) running state
    done: bool = False
    harvested: bool = False
    passed_over: int = 0             # consecutive packer skips (starvation)
    cached_tokens: int = 0           # prefix tokens served from the cache
    ssm_snaps: Optional[dict] = None  # {token boundary: (conv, ssd)}

    @property
    def remaining(self) -> int:
        """Prompt tokens still to prefill (0 once the state is done)."""
        return len(self.prompt) - self.next_pos


def pack_chunk_lanes(pending: List[ChunkedPrefillState], *, budget: int,
                     chunk_bucket: Callable[[ChunkedPrefillState], int],
                     lane_configs: Sequence[int], starvation_bound: int):
    """Select which pending prefills contribute a chunk lane to the next
    mixed step (shared by ``Engine`` and ``SimEngine``).

    Token-budget packing: walk the admission queue oldest-first, adding one
    lane per request while the padded row count — ``shared_bucket x
    n_lanes`` — fits ``budget``. All selected lanes pad to ONE shared
    bucket (the max any selected chunk needs) and the lane count is
    rounded down to the nearest entry of ``lane_configs``, so the compiled
    mixed-step shapes stay O(buckets x lane-configs) instead of exploding
    over bucket mixtures.

    A request whose chunk would overflow the remaining budget is skipped —
    later, smaller tail chunks may still fit — but each skip increments its
    ``passed_over`` counter, and once that reaches ``starvation_bound`` the
    packer refuses to pack anything *behind* it in the queue. The
    guarantee is an ordering bound, not a latency one: no younger request
    ever overtakes a starved one, so from then on it waits only on
    requests older than itself draining (oldest-first with a bounded
    overtaking window).

    ``budget <= 0`` is the legacy single-lane FIFO: exactly one chunk — the
    oldest — per step, padded to its own bucket.

    Returns ``(selected, bucket)``: the states whose next chunk rides this
    step, in queue order, and the shared bucket each lane pads to.
    """
    if not pending:
        return [], 0
    if budget <= 0:
        st = pending[0]
        st.passed_over = 0
        return [st], chunk_bucket(st)
    max_lanes = max(lane_configs)
    selected: List[ChunkedPrefillState] = []
    shared = 0
    for st in pending:
        if len(selected) == max_lanes:
            break
        b = max(shared, chunk_bucket(st))
        if b * (len(selected) + 1) <= budget:
            selected.append(st)
            shared = b
        elif st.passed_over >= starvation_bound:
            break                     # nothing may overtake a starved lane
        else:
            st.passed_over += 1
    n = max((c for c in lane_configs if c <= len(selected)), default=0)
    for st in selected[n:]:           # rounded off this step: counts as a skip
        st.passed_over += 1
    selected = selected[:n]
    for st in selected:
        st.passed_over = 0
    bucket = max((chunk_bucket(st) for st in selected), default=0)
    return selected, bucket


def derive_lane_configs(configs: Sequence[int], budget: int,
                        max_bucket: int) -> tuple:
    """Resolve the allowed per-step lane counts for a token budget.

    Explicit ``configs`` are validated (must contain 1 — the packer rounds
    lane counts down, so some entry must always be reachable). The default
    is powers of two up to ``budget // max_bucket`` plus that maximum
    itself, keeping the set O(log(budget / bucket)) small.
    """
    if 0 < budget < max_bucket:
        raise ValueError(
            f"step_token_budget={budget} cannot carry even one full "
            f"prefill bucket of {max_bucket} tokens")
    max_lanes = max(budget // max_bucket, 1) if budget > 0 else 1
    if configs:
        lanes = tuple(sorted(set(int(c) for c in configs)))
        if not lanes or lanes[0] != 1:
            raise ValueError(
                f"chunk_lane_configs {configs} must include 1: the packer "
                "rounds lane counts down to an allowed configuration")
        if lanes[-1] > max_lanes:
            # a config the packer can never fill would make
            # admission_capacity over-reserve prompts' pages (admitted
            # requests whose chunks can't ride any step)
            raise ValueError(
                f"chunk_lane_configs {configs} exceed the "
                f"{max_lanes} lane(s) step_token_budget={budget} can "
                f"carry at bucket {max_bucket}")
        return lanes
    lanes, c = {1, max_lanes}, 1
    while c < max_lanes:
        c = min(c * 2, max_lanes)
        lanes.add(c)
    return tuple(sorted(lanes))


@dataclasses.dataclass
class BranchHandle:
    branch_id: int
    request_id: int
    slot: int
    blocks: BranchBlocks
    tokens: List[int]                # generated tokens (after the prompt)
    prompt_len: int
    done: bool = False
    last_reward: float = 0.0
    scored: bool = False              # has the PRM ever scored this branch?
    saved_ssm: object = None          # host snapshot while suspended
    # generated-prefix insertion (prefix cache on): the prompt tokens key
    # the branch's full trajectory into the radix, and page-aligned decode
    # boundaries snapshot (conv, ssd) so ssm/hybrid resamples can seed
    prompt_tokens: Optional[List[int]] = None
    ssm_snaps: Optional[dict] = None  # {token boundary: (conv, ssd)}


@dataclasses.dataclass(frozen=True)
class StepVariant:
    """One reachable traced shape of ``Engine._step_fn``.

    ``name`` is ``"decode"`` for the pure-decode shape or
    ``"mixed:b{bucket}xl{lanes}"`` for a mixed step; ``lane_buckets`` is
    the static argument that selects it. ``args`` holds
    ``jax.ShapeDtypeStruct``s for the dynamic arguments *after*
    ``(params, state)`` — everything ``tools/stepcheck`` needs to trace
    the variant with ``jax.eval_shape``/``jax.make_jaxpr`` without a
    device. ``SimEngine.step_variants`` mirrors the enumeration with
    ``args=None`` (it has no step program).
    """

    name: str
    lane_buckets: Tuple[int, ...]
    args: Optional[tuple] = None


class Engine:
    def __init__(self, model: Model, params, cfg: EngineConfig,
                 prm_params: Optional[dict] = None):
        self.model = model
        self.params = params
        self.cfg = cfg
        mc = model.cfg
        if mc.uses_attention:
            assert not mc.sliding_window, \
                "paged engine serves full-attention configs; sliding-window" \
                " long-context is exercised via the dense dry-run path"
        assert cfg.mixed_step_kernel in ("fused", "decode"), \
            cfg.mixed_step_kernel
        assert cfg.decode_kernel in ("paged", "tree"), cfg.decode_kernel
        if cfg.decode_kernel == "tree" and cfg.mixed_step_kernel == "decode":
            raise ValueError(
                "decode_kernel='tree' requires mixed_step_kernel='fused' — "
                "the 'decode' fallback runs decode slots and chunk rows "
                "through one per-branch call, which the tree dedup map "
                "cannot cover (its row axis is the decode slots only)")
        self.allocator = PageAllocator(cfg.num_pages, cfg.page_size)
        self._rng = jax.random.PRNGKey(cfg.seed)
        self._next_branch_id = 0

        B, L = cfg.max_slots, mc.num_layers
        kv, hd = mc.num_kv_heads, mc.resolved_head_dim
        self.state: Dict[str, jax.Array] = {}
        if mc.uses_attention:
            shape = (L, kv, cfg.num_pages, cfg.page_size, hd)
            self.state["k_pages"] = jnp.zeros(shape, model.dtype)
            self.state["v_pages"] = jnp.zeros(shape, model.dtype)
        if mc.uses_ssm:
            conv, ssd = init_mamba2_state(mc, B, model.dtype)
            self.state["conv"] = jnp.zeros((L,) + conv.shape, model.dtype)
            self.state["ssd"] = jnp.zeros((L,) + ssd.shape, model.dtype)

        # host-side per-slot bookkeeping
        self.slots: List[Optional[BranchHandle]] = [None] * B
        self._tokens = np.zeros((B,), np.int32)
        self._positions = np.zeros((B,), np.int32)
        self._block_tables = np.full((B, cfg.max_pages_per_branch),
                                     cfg.num_pages, np.int32)  # OOB sentinel
        self._lengths = np.zeros((B,), np.int32)
        self._active = np.zeros((B,), bool)
        self._last_hidden = jnp.zeros((B, mc.d_model), model.dtype)
        self.prm_params = prm_params

        self._step_jit = jax.jit(self._step_fn,
                                 static_argnames=("lane_buckets",))
        self._prefill_cache: Dict[int, callable] = {}
        self.decode_steps_executed = 0
        self.prefill_chunk_steps = 0

        # chunked prefill: every family rides the bucketed path. Attention
        # pad rows are idempotent re-writes of the last valid row; ssm/hybrid
        # pad rows get dt masked to zero (identity state transition), with
        # the running (conv, ssd) state carried on the ChunkedPrefillState.
        self._chunked_ok = cfg.chunked_prefill
        buckets = tuple(sorted(set(cfg.prefill_buckets))) or tuple(sorted(
            {max(cfg.prefill_chunk // 2, 1), cfg.prefill_chunk}))
        if buckets[-1] < cfg.prefill_chunk:
            raise ValueError(
                f"largest prefill bucket {buckets[-1]} must cover a full "
                f"prefill_chunk of {cfg.prefill_chunk} tokens — otherwise "
                "chunk rows would alias (see Engine._bucket_for)")
        self._buckets = buckets
        self._buckets_used: set = set()   # (bucket, n_lanes) shapes traced
        self._pending_prefills: List[ChunkedPrefillState] = []
        if cfg.step_token_budget > 0 and not cfg.chunked_prefill:
            raise ValueError(
                "step_token_budget requires chunked_prefill=True — "
                "synchronous exact-length admission has no chunk lanes to "
                "budget (and a capacity > 1 would let the scheduler drain "
                "its whole arrival queue in one tick)")
        self._lane_configs = derive_lane_configs(
            cfg.chunk_lane_configs, cfg.step_token_budget, buckets[-1])
        self.mixed_steps_executed = 0     # decode steps carrying >= 1 lane
        if cfg.prefix_cache and not cfg.chunked_prefill:
            raise ValueError(
                "prefix_cache requires chunked_prefill=True — the exact-"
                "length path writes every page via the dense scatter and "
                "has no chunk-start offset to resume from")
        self.prefix_cache = (PrefixCache(self.allocator)
                             if cfg.prefix_cache else None)
        # cached no-CoW (src, dst) sentinel pair (see _cow_arrays)
        self._cow_sentinel: Optional[tuple] = None
        # cached all-ungrouped tree map (see _tree_map)
        self._tree_sentinel: Optional[dict] = None

    # ------------------------------------------------------------------ util
    @property
    def free_slots(self) -> List[int]:
        """Unoccupied decode-slot indices, ascending."""
        return [i for i, s in enumerate(self.slots) if s is None]

    @property
    def num_active(self) -> int:
        """Occupied decode slots (forces the host-side active mask)."""
        return int(self._active.sum())

    def live_tokens(self) -> int:
        """Total tokens currently resident in the KV pool (paper Fig. 3)."""
        return sum(s.blocks.length for s in self.slots if s is not None)

    def _next_rng(self):
        self._rng, k = jax.random.split(self._rng)
        return k

    # --------------------------------------------------------------- prefill
    def prefill(self, prompt: List[int], exact: Optional[bool] = None):
        """Run prefill for one request to completion (synchronous
        convenience API). Returns (prefix_blocks, last_logits, ssm_state or
        None). The prefix pages are NOT yet shared — call ``spawn_branch``
        N times to fork branches off them.

        All families default to the chunked-bucketed path (same compiled
        shapes as the serving mixed step); ``exact=True`` forces the legacy
        exact-length program (one compile per distinct prompt length).
        ssm/hybrid chunks run the masked-dt scan, so right padding is an
        identity state transition rather than recurrence pollution.
        """
        if not self._chunked_ok:
            exact = True     # chunked admission disabled by config
        if not exact:
            st = self._new_chunked_state(prompt)
            while not st.done:
                self._advance_chunks([st], piggyback=False)
            return st.blocks, st.last_logits, st.ssm_state
        cfg, mc = self.cfg, self.model.cfg
        s = len(prompt)
        if s not in self._prefill_cache:
            self._prefill_cache[s] = self._make_prefill(s)
        run = self._prefill_cache[s]

        logits, cache = run(self.params,
                            jnp.asarray(np.asarray(prompt, np.int32))[None],
                            s)

        blocks = self._alloc_prompt_pages(s)
        ssm_state = None
        if mc.uses_attention:
            self._write_prefix_pages(cache, blocks)
        if mc.uses_ssm:
            ssm_state = (cache["conv"], cache["ssd"])  # [L,1,...]
        return blocks, logits, ssm_state

    def _check_prompt_width(self, s: int) -> None:
        assert self.allocator.pages_for(max(s, 1)) <= \
            self.cfg.max_pages_per_branch, "prompt exceeds block-table width"

    def _alloc_prompt_pages(self, s: int) -> BranchBlocks:
        self._check_prompt_width(s)
        return self.allocator.alloc_prefix(s)

    # ------------------------------------------------- chunked prefill (new)
    def _new_chunked_state(self, prompt: List[int]) -> ChunkedPrefillState:
        """Allocate a prompt's pages and, for ssm/hybrid configs, the
        per-layer running (conv, ssd) state its chunks thread through the
        mixed step. With the prefix cache, the longest cached page-aligned
        prefix is increfed into the block list and chunking starts at the
        first uncached token (ssm/hybrid reuse is gated on a cached
        boundary state to seed the recurrence); an OutOfPagesError on the
        tail allocation rolls the acquired references back, so admission
        stays all-or-nothing."""
        mc = self.model.cfg
        cached, cached_ssm = 0, None
        if self.prefix_cache is None:
            blocks = self._alloc_prompt_pages(len(prompt))
        else:
            # width check BEFORE acquire: an oversized prompt must fail
            # without acquiring references it would then leak
            self._check_prompt_width(len(prompt))
            blocks, cached_ssm = self.prefix_cache.admit(
                prompt, need_state=mc.uses_ssm)
            cached = blocks.num_shared * self.cfg.page_size
        st = ChunkedPrefillState(prompt=list(prompt), blocks=blocks,
                                 next_pos=cached, cached_tokens=cached)
        if self.prefix_cache is not None:
            st.ssm_snaps = {}
        if mc.uses_ssm:
            if cached_ssm is not None:
                st.ssm_state = cached_ssm
                st.ssm_snaps[cached] = cached_ssm
            else:
                conv, ssd = init_mamba2_state(mc, 1, self.model.dtype)
                L = mc.num_layers
                st.ssm_state = (
                    jnp.zeros((L,) + conv.shape, self.model.dtype),
                    jnp.zeros((L,) + ssd.shape, self.model.dtype))
        return st

    def begin_prefill(self, prompt: List[int]) -> ChunkedPrefillState:
        """Admit a request without stalling decode. The returned state is
        queued and its prompt chunks piggyback on subsequent ``decode_step``
        calls (one FIFO chunk per step, or up to ``step_token_budget``
        chunk-row tokens across concurrent lanes when the budget is set);
        poll ``state.done`` and harvest with ``finish_prefill``. With ``chunked_prefill=False`` the prompt
        prefills synchronously and the state returns already done. Raises
        OutOfPagesError (allocating nothing) when the KV pool cannot hold
        the prompt."""
        if not self._chunked_ok:
            blocks, logits, ssm = self.prefill(prompt, exact=True)
            return ChunkedPrefillState(
                prompt=list(prompt), blocks=blocks, next_pos=len(prompt),
                last_logits=logits, ssm_state=ssm, done=True)
        st = self._new_chunked_state(prompt)
        self._pending_prefills.append(st)
        return st

    def finish_prefill(self, st: ChunkedPrefillState):
        """Harvest a completed prefill: (prefix_blocks, last_logits, ssm).
        Ownership of the pages passes to the caller."""
        assert st.done, "prefill still has pending chunks"
        st.harvested = True
        return st.blocks, st.last_logits, st.ssm_state

    def abort_prefill(self, st: ChunkedPrefillState) -> None:
        """Drop a queued prefill and release its pages. A state already
        harvested via ``finish_prefill`` no longer owns its pages (they back
        live branches), so aborting it only detaches it from the queue —
        releasing would double-decref shared pages and corrupt refcounts."""
        if st in self._pending_prefills:
            self._pending_prefills.remove(st)
        if not st.harvested:
            self.allocator.release(st.blocks)
        st.done = True

    @property
    def has_pending_prefill(self) -> bool:
        """True while any admitted prompt still has chunks to run."""
        return bool(self._pending_prefills)

    def prefix_cache_stats(self) -> Optional[Dict]:
        """Radix-cache hit/eviction counters, or None with the cache off
        (surfaced by the serve CLI and ``Scheduler.metrics``)."""
        return (self.prefix_cache.stats()
                if self.prefix_cache is not None else None)

    def match_cached_tokens(self, prompt: List[int]) -> int:
        """Non-mutating probe for LPM admission ordering: prompt tokens a
        warm admission would serve from the radix cache right now (0 with
        the cache off). Applies the same SSM-boundary gating a real
        ``begin_prefill`` would, so the probe never over-promises."""
        if self.prefix_cache is None:
            return 0
        return self.prefix_cache.match_tokens(
            prompt, need_state=self.model.cfg.uses_ssm)

    @property
    def prefill_compile_count(self) -> int:
        """Distinct mixed-step chunk shapes traced so far — (bucket,
        lane-count) pairs, O(num_buckets x num_lane_configs) by
        construction (the packer pads all lanes of a step to one shared
        bucket and rounds lane counts to ``chunk_lane_configs``), vs
        O(distinct prompt lengths) for the exact path."""
        return len(self._buckets_used)

    def step_variants(self) -> List[StepVariant]:
        """Enumerate every ``_step_fn`` shape this engine can dispatch.

        Returns the pure-decode variant plus one mixed variant per
        (bucket, lane-count) pair — exactly the O(prefill_buckets ×
        chunk_lane_configs) compile bound the engine documents
        (docs/scheduling.md). The enumeration is the engine's own
        declaration of its trace surface: ``tools/stepcheck`` traces each
        variant abstractly and ratchets the signatures against its
        committed manifest, and a drift test asserts every shape
        ``decode_step`` actually traced (``_buckets_used``) is declared
        here. Each variant's ``args`` are ``ShapeDtypeStruct``s for the
        dynamic arguments after ``(params, state)``.
        """
        cfg, mc = self.cfg, self.model.cfg
        B = cfg.max_slots
        sds = jax.ShapeDtypeStruct

        def dyn(n_lanes: int, bucket: int) -> tuple:
            rows = B + n_lanes * bucket
            chunk_state: dict = {}
            if mc.uses_ssm and n_lanes:
                conv, ssd = jax.eval_shape(
                    lambda: init_mamba2_state(mc, 1, self.model.dtype))
                L = mc.num_layers
                chunk_state = {
                    "conv": sds((L, n_lanes) + conv.shape[1:], conv.dtype),
                    "ssd": sds((L, n_lanes) + ssd.shape[1:], ssd.dtype)}
            tree: dict = {}
            if cfg.decode_kernel == "tree" and mc.uses_attention:
                w = cfg.max_pages_per_branch
                tree = {"branch_bt": sds((B, w), jnp.int32),
                        "row_group": sds((B,), jnp.int32),
                        "shared_bt": sds((B, w), jnp.int32),
                        "shared_lens": sds((B,), jnp.int32)}
            return (sds((rows,), jnp.int32), sds((rows,), jnp.int32),
                    sds((rows, cfg.max_pages_per_branch), jnp.int32),
                    sds((rows,), jnp.int32),
                    sds(self._rng.shape, self._rng.dtype), chunk_state,
                    sds((n_lanes,), jnp.int32), sds((B,), jnp.bool_),
                    sds((B,), jnp.int32), sds((B,), jnp.int32), tree)

        variants = [StepVariant("decode", (), dyn(0, 0))]
        for bucket in self._buckets:
            for n in self._lane_configs:
                variants.append(StepVariant(f"mixed:b{bucket}xl{n}",
                                            (bucket,) * n, dyn(n, bucket)))
        return variants

    def _bucket_for(self, n: int) -> int:
        for b in self._buckets:
            if b >= n:
                return b
        # silently returning the largest bucket would alias chunk rows
        # (several prompt positions mapped onto one step row)
        raise ValueError(
            f"chunk of {n} tokens exceeds the largest prefill bucket "
            f"{self._buckets[-1]}; configure prefill_buckets to cover "
            f"prefill_chunk={self.cfg.prefill_chunk}")

    def _chunk_bucket(self, st: ChunkedPrefillState) -> int:
        return self._bucket_for(min(self.cfg.prefill_chunk, st.remaining))

    def _pack_lanes(self):
        """One packer call per decode step (it mutates the starvation
        counters of skipped states)."""
        return pack_chunk_lanes(
            self._pending_prefills, budget=self.cfg.step_token_budget,
            chunk_bucket=self._chunk_bucket,
            lane_configs=self._lane_configs,
            starvation_bound=self.cfg.prefill_starvation_bound)

    @property
    def admission_capacity(self) -> int:
        """Max prefills worth keeping in flight: the packer can serve at
        most this many chunk lanes per mixed step."""
        return self._lane_configs[-1]

    def _chunk_inputs(self, st: ChunkedPrefillState, bucket: int):
        """Build one lane's extra step rows for the next chunk of ``st``,
        padded to the step's shared ``bucket``.

        Rows past the chunk's true length shadow the last valid row (same
        token/position) so their positions/lengths stay in range, but they
        are otherwise pure padding: ``_step_fn`` drops their K/V page
        writes (``write_ok`` → OOB sentinel — from layer 2 on a pad row's
        activations can diverge from the row it shadows, so re-writing the
        same slot would clobber valid state) and the masked-dt SSM lane
        treats them as identity transitions via the lane's chunk length."""
        cfg = self.cfg
        s = len(st.prompt)
        chunk_len = min(cfg.prefill_chunk, s - st.next_pos)
        idx = np.minimum(st.next_pos + np.arange(bucket), s - 1)
        tokens = np.asarray(st.prompt, np.int32)[idx]
        row = np.full((cfg.max_pages_per_branch,), cfg.num_pages, np.int32)
        row[:len(st.blocks.pages)] = st.blocks.pages
        block_tables = np.broadcast_to(row, (bucket, row.shape[0]))
        # the step attends over lengths+1 tokens: row i covers positions
        # 0..next_pos+i inclusive, i.e. prefix + causal within the chunk
        return (tokens, idx.astype(np.int32), block_tables,
                idx.astype(np.int32), chunk_len)

    def _advance_chunks(self, sts: List[ChunkedPrefillState],
                        piggyback: bool, bucket: int = 0,
                        cows: Sequence[tuple] = (),
                        tree: Optional[dict] = None):
        """Run one chunk of each state in ``sts`` through the step program
        as concurrent lanes (``sts`` comes from ``pack_chunk_lanes``; the
        legacy path passes a single state). With ``piggyback`` the caller
        (``decode_step``) supplies the live decode rows plus the step's
        CoW page copies (``cows``, folded into the same dispatch as the
        chunk K/V writes — see ``_cow_arrays``); standalone draining pads
        with inert rows (sentinel block tables drop their page writes,
        and the slot-validity mask freezes the per-slot SSM states) so
        active branches are never advanced.

        ssm/hybrid configs thread each lane's running per-layer (conv,
        ssd) state through the step (``chunk_*`` keys, stacked along a
        lane axis) and get it back advanced by exactly that lane's chunk
        length — pad rows are identity transitions under the masked-dt
        scan. With the prefix cache, each lane snapshots its SSM state at
        page-aligned chunk boundaries and a finished prompt's full pages
        are inserted into the radix."""
        cfg, mc = self.cfg, self.model.cfg
        B = cfg.max_slots
        if not bucket:
            bucket = max(self._chunk_bucket(st) for st in sts)
        lanes = [self._chunk_inputs(st, bucket) for st in sts]
        chunk_lens = np.asarray([ln[4] for ln in lanes], np.int32)
        if piggyback:
            d_tokens, d_positions = self._tokens, self._positions
            d_bt, d_lengths = self._block_tables, self._lengths
            slot_valid = self._active
        else:
            d_tokens = np.zeros((B,), np.int32)
            d_positions = np.zeros((B,), np.int32)
            d_bt = np.full((B, cfg.max_pages_per_branch), cfg.num_pages,
                           np.int32)
            d_lengths = np.zeros((B,), np.int32)
            slot_valid = np.zeros((B,), bool)
        chunk_state = {}
        if mc.uses_ssm:
            chunk_state = {
                "conv": jnp.concatenate([st.ssm_state[0] for st in sts], 1),
                "ssd": jnp.concatenate([st.ssm_state[1] for st in sts], 1)}
        lane_buckets = (bucket,) * len(sts)
        self._buckets_used.add((bucket, len(sts)))
        cow_src, cow_dst = self._cow_arrays(cows)
        if tree is None:
            tree = self._tree_map()     # sentinel: decode rows are inert
        next_tokens, hidden, logits, new_state = self._step_jit(
            self.params, self.state,
            jnp.asarray(np.concatenate([d_tokens] + [ln[0] for ln in lanes])),
            jnp.asarray(np.concatenate([d_positions]
                                       + [ln[1] for ln in lanes])),
            jnp.asarray(np.concatenate([d_bt] + [ln[2] for ln in lanes])),
            jnp.asarray(np.concatenate([d_lengths]
                                       + [ln[3] for ln in lanes])),
            self._next_rng(), chunk_state, jnp.asarray(chunk_lens),
            jnp.asarray(slot_valid), cow_src, cow_dst, tree,
            lane_buckets=lane_buckets)
        new_state = dict(new_state)
        if mc.uses_ssm:
            c_conv = new_state.pop("chunk_conv")      # [L, n_lanes, ...]
            c_ssd = new_state.pop("chunk_ssd")
            for i, st in enumerate(sts):
                st.ssm_state = (c_conv[:, i:i + 1], c_ssd[:, i:i + 1])
        self.state.update(new_state)
        self.prefill_chunk_steps += len(sts)
        self.mixed_steps_executed += 1
        ps = cfg.page_size
        for i, st in enumerate(sts):
            cl = int(chunk_lens[i])
            st.next_pos += cl
            if (mc.uses_ssm and st.ssm_snaps is not None
                    and st.next_pos % ps == 0):
                # a chunk boundary on a page boundary: this state can seed
                # a future request resuming at exactly next_pos tokens
                st.ssm_snaps[st.next_pos] = st.ssm_state
            if st.next_pos >= len(st.prompt):
                st.done = True
                st.last_logits = logits[B + i * bucket + cl - 1]
                if st in self._pending_prefills:
                    self._pending_prefills.remove(st)
                if self.prefix_cache is not None:
                    self.prefix_cache.insert(st.prompt, st.blocks.pages,
                                             st.ssm_snaps)
        return next_tokens, hidden

    def _make_prefill(self, s_pad: int):
        model = self.model

        @jax.jit
        def run(params, tokens, true_len):
            positions = jnp.arange(s_pad)[None]
            # mask padding by clamping positions (outputs past true_len unused)
            logits_all, cache = _prefill_all(model, params, tokens, positions)
            logits = logits_all[0, true_len - 1]
            return logits, cache

        return run

    def _write_prefix_pages(self, cache, blocks: BranchBlocks):
        """Scatter dense prefill K/V into the allocated pages (the dense
        tensors are padded up to the page boundary; the pad region is never
        attended because block lengths mask it)."""
        ps = self.cfg.page_size
        n_pages = len(blocks.pages)
        page_ids = jnp.asarray(blocks.pages, jnp.int32)
        k = cache["k"][:, 0]                  # [L, s, kv, hd]
        v = cache["v"][:, 0]
        pad = n_pages * ps - k.shape[1]
        if pad > 0:
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        self.state["k_pages"], self.state["v_pages"] = _scatter_pages(
            self.state["k_pages"], self.state["v_pages"], k, v, page_ids,
            page_size=ps)

    # --------------------------------------------------------------- branches
    def spawn_branch(self, request_id: int, prefix_blocks: BranchBlocks,
                     last_logits, ssm_state, prompt_len: int,
                     first_fork: bool = False,
                     prompt_tokens: Optional[List[int]] = None
                     ) -> Optional[BranchHandle]:
        """Fork one branch off a prefilled prefix and seat it in a free slot.

        Samples the branch's own first token from the prefill logits (the
        stochastic divergence point between siblings). Returns None if no
        slot is free (caller queues the branch). ``prompt_tokens`` (the
        request's prompt) keys the branch's generated full pages into the
        prefix cache at completion and page-aligned decode boundaries —
        without it the branch generates normally but inserts nothing.
        """
        free = self.free_slots
        if not free:
            return None
        slot = free[0]
        blocks = self.allocator.fork(prefix_blocks)
        first = int(sample(self._next_rng(), last_logits,
                           self.cfg.sampling))
        handle = BranchHandle(
            branch_id=self._next_branch_id, request_id=request_id, slot=slot,
            blocks=blocks, tokens=[first], prompt_len=prompt_len,
            prompt_tokens=(list(prompt_tokens)
                           if prompt_tokens is not None else None),
            ssm_snaps={} if self.prefix_cache is not None else None)
        self._next_branch_id += 1
        self.slots[slot] = handle

        if self.model.cfg.uses_ssm and ssm_state is not None:
            conv, ssd = ssm_state
            self.state["conv"] = self.state["conv"].at[:, slot].set(conv[:, 0])
            self.state["ssd"] = self.state["ssd"].at[:, slot].set(ssd[:, 0])

        self._seat(handle)
        return handle

    def _seat(self, h: BranchHandle):
        """Load a branch's host-side decode state into its slot row."""
        slot = h.slot
        self._tokens[slot] = h.tokens[-1]
        self._positions[slot] = h.blocks.length  # next write position
        self._refresh_block_table(h)
        self._lengths[slot] = h.blocks.length
        self._active[slot] = True

    def _refresh_block_table(self, h: BranchHandle):
        row = np.full((self.cfg.max_pages_per_branch,), self.cfg.num_pages,
                      np.int32)
        assert len(h.blocks.pages) <= self.cfg.max_pages_per_branch, \
            "branch exceeded max_pages_per_branch"
        row[:len(h.blocks.pages)] = h.blocks.pages
        self._block_tables[h.slot] = row

    def fork_branch(self, parent: BranchHandle) -> Optional[BranchHandle]:
        """Mid-generation fork (Rebase tree expansion): the child shares all
        of the parent's pages (CoW on next append) and copies its SSM state.
        Divergence comes from per-slot sampling rngs on the next step."""
        free = self.free_slots
        if not free:
            return None
        slot = free[0]
        blocks = self.allocator.fork(parent.blocks)
        handle = BranchHandle(
            branch_id=self._next_branch_id, request_id=parent.request_id,
            slot=slot, blocks=blocks, tokens=list(parent.tokens),
            prompt_len=parent.prompt_len,
            prompt_tokens=(list(parent.prompt_tokens)
                           if parent.prompt_tokens is not None else None),
            ssm_snaps=(dict(parent.ssm_snaps)
                       if parent.ssm_snaps is not None else None))
        self._next_branch_id += 1
        self.slots[slot] = handle
        if self.model.cfg.uses_ssm:
            for key in ("conv", "ssd"):
                self.state[key] = self.state[key].at[:, slot].set(
                    self.state[key][:, parent.slot])
        self._seat(handle)
        return handle

    def suspend_branch(self, h: BranchHandle) -> None:
        """Beyond-paper (the paper lists preemptible scheduling as future
        work): vacate a branch's slot while KEEPING its pages/state, so it
        can be reseated later via ``resume_branch``. SSM state is snapshot
        to host (slot rows get reused by the next occupant)."""
        assert self.slots[h.slot] is h
        if self.model.cfg.uses_ssm:
            h_saved = (np.asarray(self.state["conv"][:, h.slot]),
                       np.asarray(self.state["ssd"][:, h.slot]))
            h.saved_ssm = h_saved
        slot = h.slot
        self.slots[slot] = None
        self._active[slot] = False
        self._block_tables[slot] = self.cfg.num_pages
        self._lengths[slot] = 0
        h.slot = -1

    def resume_branch(self, h: BranchHandle) -> bool:
        """Reseat a suspended branch. Returns False when no slot is free."""
        free = self.free_slots
        if not free:
            return False
        slot = free[0]
        h.slot = slot
        self.slots[slot] = h
        if self.model.cfg.uses_ssm and getattr(h, "saved_ssm", None):
            conv, ssd = h.saved_ssm
            self.state["conv"] = self.state["conv"].at[:, slot].set(
                jnp.asarray(conv))
            self.state["ssd"] = self.state["ssd"].at[:, slot].set(
                jnp.asarray(ssd))
            h.saved_ssm = None
        self._seat(h)
        return True

    def pages_needed_for_step(self) -> int:
        """Pages the next decode step will allocate (boundary + CoW)."""
        ps = self.cfg.page_size
        need = 0
        for h in self.slots:
            if h is None:
                continue
            b = h.blocks
            if self.allocator.needs_cow(b):
                need += 1
            if b.length % ps == 0 and b.length // ps == len(b.pages):
                need += 1
        return need

    def _insert_generated(self, h: BranchHandle) -> None:
        """Insert a branch's generated full pages into the prefix cache,
        keyed by prompt + generated tokens (the trailing partial page
        keeps private CoW semantics; ``insert`` skips it). Released pages
        then park on the LRU instead of freeing, so a resample of the
        same trajectory — or any follow-up sharing the generated prefix —
        admits warm. ``ssm_snaps`` attaches (conv, ssd) snapshots to the
        page-aligned boundaries that have one, preserving the
        ``acquire(need_state=True)`` seedable-boundary gate for
        ssm/hybrid. Gated on attention: pure-ssm decode allocates no
        generated pages to insert."""
        if (self.prefix_cache is None or h.prompt_tokens is None
                or not self.model.cfg.uses_attention):
            return
        written = h.blocks.length - h.prompt_len
        if written <= 0:
            return
        key = list(h.prompt_tokens) + h.tokens[:written]
        self.prefix_cache.insert(key, h.blocks.pages, h.ssm_snaps)

    def free_branch(self, h: BranchHandle):
        """Release a branch's slot and eagerly free its pages (inserting
        its generated full pages into the prefix cache first, so they park
        warm on the LRU instead of freeing)."""
        self._insert_generated(h)
        self.allocator.release(h.blocks)
        slot = h.slot
        if slot >= 0:                 # suspended branches hold no slot
            self.slots[slot] = None
            self._active[slot] = False
            self._block_tables[slot] = self.cfg.num_pages
            self._lengths[slot] = 0
        h.done = True

    def release_prefix(self, prefix_blocks: BranchBlocks):
        """Drop the scheduler's own reference to a request's prefix."""
        self.allocator.release(prefix_blocks)

    # ----------------------------------------------------------------- decode
    def _cow_arrays(self, cows: Sequence[tuple]):
        """Pack a step's (old, new) CoW page pairs into the fixed-shape
        [max_slots] index arrays ``_step_fn`` consumes (each decode slot
        CoWs at most once per step). Unused entries hold the OOB sentinel:
        the fused gather/scatter drops them, so the pure-decode and mixed
        shapes stay identical whether or not any copy happens.

        Most steps CoW nothing, so the all-sentinel pair is built and
        transferred once and reused — no per-step host->device copy for
        the common case."""
        if not cows:
            if self._cow_sentinel is None:
                empty = np.full((self.cfg.max_slots,), self.cfg.num_pages,
                                np.int32)
                self._cow_sentinel = (jnp.asarray(empty), jnp.asarray(empty))
            return self._cow_sentinel
        src = np.full((self.cfg.max_slots,), self.cfg.num_pages, np.int32)
        dst = np.full((self.cfg.max_slots,), self.cfg.num_pages, np.int32)
        for j, (old, new) in enumerate(cows):
            src[j], dst[j] = old, new
        return jnp.asarray(src), jnp.asarray(dst)

    def _tree_map(self, blocks: Optional[List[Optional[BranchBlocks]]]
                  = None) -> dict:
        """The decode rows' branch×page dedup map for the tree kernel,
        as the ``tree`` step argument. Empty dict with the per-branch
        kernel (zero pytree leaves — the traced shapes are unchanged);
        ``blocks=None`` returns the cached all-ungrouped sentinel map
        (standalone chunk drains: every decode row is inert)."""
        cfg = self.cfg
        if cfg.decode_kernel != "tree" or not self.model.cfg.uses_attention:
            return {}
        if blocks is None:
            if self._tree_sentinel is None:
                b, w = cfg.max_slots, cfg.max_pages_per_branch
                sent = np.full((b, w), cfg.num_pages, np.int32)
                self._tree_sentinel = {
                    "branch_bt": jnp.asarray(sent),
                    "row_group": jnp.full((b,), b, jnp.int32),
                    "shared_bt": jnp.asarray(sent),
                    "shared_lens": jnp.zeros((b,), jnp.int32)}
            return self._tree_sentinel
        rg, sbt, sl, bbt = tree_decode_map(
            blocks, pages_per_branch=cfg.max_pages_per_branch,
            num_pages=cfg.num_pages, page_size=cfg.page_size)
        return {"branch_bt": jnp.asarray(bbt), "row_group": jnp.asarray(rg),
                "shared_bt": jnp.asarray(sbt),
                "shared_lens": jnp.asarray(sl)}

    def _step_fn(self, params, state, tokens, positions, block_tables,
                 lengths, rng, chunk_state, chunk_lens, slot_valid,
                 cow_src, cow_dst, tree, lane_buckets: tuple = ()):
        """One batched token step, generic in row count and lane count.

        Rows 0..max_slots-1 are the decode slots; any extra rows are the
        step's prefill chunk *lanes* — ``lane_buckets`` (static) gives the
        padded row count of each lane, ``chunk_lens[i]`` (traced) its true
        chunk length, and each lane's rows belong to one request (same
        math as decode: embed one token, write its K/V at ``positions``
        via the row's block table, attend over ``lengths``+1 tokens).
        Causality inside a chunk falls out of the length mask: all rows
        scatter K/V before attention, and row i's length covers only
        positions <= its own. The packer emits only uniform lane tuples
        with lane counts drawn from a small allowed set, so the compiled
        shapes stay O(buckets x lane-configs): the pure decode shape plus
        one mixed shape per (bucket, lane-count) pair.

        With ``mixed_step_kernel="fused"`` (the default) each lane's
        attention runs as one paged flash-prefill pass over its request's
        block table instead of per-token flash-decode calls — same masking
        semantics (row i sees absolute positions <= pos0 + i), one
        O(context) HBM stream per q block instead of one per row.
        ``"decode"`` keeps the legacy unified call for fallback and
        equivalence testing.

        The SSM mixer of ssm/hybrid configs is inherently sequential, so
        its chunk rows can't be independent like attention's: each lane
        runs as ONE [1, bucket, D] sequence through the masked-dt chunked
        scan instead, seeded by its slice of ``chunk_state`` (per-layer
        (conv, ssd) stacked along a lane axis, carried across chunks on
        each ChunkedPrefillState) with only the first ``chunk_lens[i]``
        rows valid — pad rows are exact identity transitions.
        ``slot_valid`` masks the per-slot SSM state update of decode rows
        the same way, so inert rows (standalone chunk draining, empty
        slots) never perturb suspended or future occupants.

        ``cow_src``/``cow_dst`` ([max_slots], OOB-sentinel padded) are the
        step's copy-on-write page pairs, applied as ONE fused
        gather/scatter inside this program before any K/V write — so a
        mixed step's chunk page writes and its CoW copies all ride a
        single device dispatch, however many lanes it carries (the
        batching mirror of the old host-side ``cows`` loop).

        ``tree`` is the decode rows' branch×page dedup map
        (``decode_kernel="tree"``: row_group / shared_bt / shared_lens /
        branch_bt from ``repro.kv.tree_decode_map``, built host-side from
        the slots' post-accounting fork topology) — the decode-slot
        attention then streams each fork group's shared ancestor pages
        once for all members and covers only post-fork suffixes
        per-branch. Empty dict with the per-branch kernel: zero pytree
        leaves, so that path's traced shapes are byte-identical to
        before the map existed. CoW runs before attention, so no row's
        shared page is written mid-step and the map stays sound.
        """
        model, mc, cfg = self.model, self.model.cfg, self.cfg
        B = tokens.shape[0]
        nS = cfg.max_slots
        if mc.uses_attention:
            # CoW before any write: sentinel dst rows drop (mode="drop");
            # their src gathers clamp to a resident page (explicitly — OOB
            # gather is backend-defined) and the garbage is discarded
            src = jnp.minimum(cow_src, cfg.num_pages - 1)
            state = dict(state)
            state["k_pages"] = state["k_pages"].at[:, :, cow_dst].set(
                state["k_pages"][:, :, src], mode="drop")
            state["v_pages"] = state["v_pages"].at[:, :, cow_dst].set(
                state["v_pages"][:, :, src], mode="drop")
        # static: lane row offsets into the step's row axis
        lane_off = []
        off = nS
        for bk in lane_buckets:
            lane_off.append(off)
            off += bk
        # static: does this shape carry SSM chunk lanes?
        ssm_chunk_lane = bool(chunk_state) and mc.uses_ssm
        # static: chunk rows take the fused paged flash-prefill path (one
        # flash pass per lane over its request's block table) instead of
        # riding the per-token flash-decode loop — O(context) vs
        # O(chunk · context) HBM reads per layer
        fused_chunk = (B > nS and mc.uses_attention
                       and cfg.mixed_step_kernel == "fused")
        # static: decode-slot attention rides the tree dedup map (an empty
        # dict means the per-branch kernel — dict-ness is static under jit)
        tree_decode = bool(tree)
        on_tpu = jax.default_backend() == "tpu"
        x = embed_tokens(mc, params["embed"], tokens[:, None])
        if mc.pos_embedding == "sinusoidal":
            x = x + sinusoidal_embedding(positions, mc.d_model)[:, None].astype(x.dtype)

        page_of = block_tables[jnp.arange(B), positions // cfg.page_size]
        slot_in_page = positions % cfg.page_size
        if B > nS:
            # chunk rows past a lane's chunk length are pure padding: route
            # their K/V writes to the OOB sentinel (mode="drop"). Shadowing
            # the last valid row is NOT idempotent for hybrid configs —
            # from layer 2 on, pad-row inputs differ (the masked SSM lane
            # leaves unspecified values at pad positions) and would clobber
            # the valid row's K/V.
            write_ok = jnp.concatenate(
                [jnp.ones((nS,), bool)]
                + [jnp.arange(bk) < chunk_lens[i]
                   for i, bk in enumerate(lane_buckets)])
            page_of = jnp.where(write_ok, page_of, cfg.num_pages)

        def layer(carry, scanned):
            x = carry
            layer_p = scanned["p"]
            h = apply_norm(mc, layer_p["norm1"], x)
            mix = jnp.zeros_like(x)
            outs = {}
            if mc.uses_attention:
                kp, vp = scanned["k_pages"], scanned["v_pages"]
                q, k, v = _project_qkv(mc, layer_p["attn"], h)
                pos_in = positions[:, None]
                if mc.pos_embedding == "mrope":
                    pos_in = jnp.broadcast_to(pos_in[..., None], (B, 1, 3))
                q, k = _rotate(mc, q, k, pos_in)
                # write new token's k/v into pages ([kv, page, slot, hd])
                kp = kp.at[:, page_of, slot_in_page].set(
                    jnp.moveaxis(k[:, 0], 1, 0), mode="drop")
                vp = vp.at[:, page_of, slot_in_page].set(
                    jnp.moveaxis(v[:, 0], 1, 0), mode="drop")
                def slot_attention():
                    """Decode-slot attention; with the tree map, shared
                    ancestor pages stream once per fork group and
                    suffixes run per-branch (bit-exact vs the per-branch
                    call — the map decomposes the same block tables)."""
                    if tree_decode:
                        return paged_tree_attention(
                            q[:nS, 0], kp, vp, tree["row_group"],
                            tree["shared_bt"], tree["shared_lens"],
                            tree["branch_bt"], lengths[:nS] + 1,
                            use_kernel=on_tpu)
                    return paged_attention(
                        q[:nS, 0], kp, vp, block_tables[:nS],
                        lengths[:nS] + 1, use_kernel=on_tpu)

                if fused_chunk:
                    # decode rows keep the flash-decode path; each lane's
                    # rows share one block table (they are broadcast rows
                    # of the same request) and run as a single flash pass
                    # with causal masking against absolute positions —
                    # row i at pos0 + i sees the prefix plus the chunk K/V
                    # written above. Bucket-pad rows (>= the lane's chunk
                    # length) emit exact zeros; their writes were already
                    # dropped.
                    att_parts = [slot_attention()]
                    for i, bk in enumerate(lane_buckets):
                        o = lane_off[i]
                        att_parts.append(paged_flash_prefill(
                            q[o:o + bk, 0], kp, vp, block_tables[o],
                            positions[o], chunk_lens[i],
                            use_kernel=on_tpu))
                    att = jnp.concatenate(att_parts, 0)
                elif B > nS:
                    # mixed_step_kernel="decode" fallback: decode slots
                    # and chunk rows ride one per-branch call (the tree
                    # map is rejected for this combination in __init__)
                    att = paged_attention(
                        q[:, 0], kp, vp, block_tables, lengths + 1,
                        use_kernel=on_tpu)
                else:
                    att = slot_attention()
                y = att.reshape(B, 1, -1) @ layer_p["attn"]["wo"]
                mix = mix + y
                outs["k_pages"], outs["v_pages"] = kp, vp
            if mc.uses_ssm:
                y, conv, ssd = mamba2_decode(
                    mc, layer_p["mamba"], h[:nS], scanned["conv"],
                    scanned["ssd"], valid=slot_valid)
                outs["conv"] = conv.astype(scanned["conv"].dtype)
                outs["ssd"] = ssd.astype(scanned["ssd"].dtype)
                if ssm_chunk_lane:
                    # all lanes run as ONE batched masked-dt scan: the
                    # packer only emits uniform lane tuples, the stacked
                    # lane-state axis is the batch axis, and mamba2_forward
                    # takes a per-row valid_len — so lane count adds no
                    # sequential trace depth
                    assert len(set(lane_buckets)) == 1, lane_buckets
                    bk = lane_buckets[0]
                    x_ch = h[nS:, 0].reshape(len(lane_buckets), bk, -1)
                    y_ch, (c_conv, c_ssd) = mamba2_forward(
                        mc, layer_p["mamba"], x_ch,
                        initial=(scanned["chunk_conv"],
                                 scanned["chunk_ssd"]),
                        valid_len=chunk_lens)
                    outs["chunk_conv"] = c_conv.astype(
                        scanned["chunk_conv"].dtype)
                    outs["chunk_ssd"] = c_ssd.astype(
                        scanned["chunk_ssd"].dtype)
                    y = jnp.concatenate(
                        [y, y_ch.reshape(B - nS, 1, -1)], 0)
                mix = mix + y
            if mc.arch_type == "hybrid":
                mix = mix * 0.5
            x = x + mix
            if mc.d_ff:
                h2 = apply_norm(mc, layer_p["norm2"], x)
                if mc.uses_moe:
                    y, _ = apply_moe(mc, layer_p["moe"], h2)
                else:
                    y = apply_mlp(mc, layer_p["mlp"], h2)
                x = x + y
            return x, outs

        scanned_in = {"p": params["layers"]}
        for key in ("k_pages", "v_pages", "conv", "ssd"):
            if key in state:
                scanned_in[key] = state[key]
        if ssm_chunk_lane:
            scanned_in["chunk_conv"] = chunk_state["conv"]
            scanned_in["chunk_ssd"] = chunk_state["ssd"]
        x, new_state = jax.lax.scan(layer, x, scanned_in)
        x = apply_norm(mc, params["final_norm"], x)
        hidden = x[:, 0]
        logits = unembed(mc, params["embed"], hidden)
        keys = jax.random.split(rng, B)
        next_tokens = jax.vmap(lambda r, l: sample(r, l, cfg.sampling))(
            keys, logits)
        # hidden stays in the model compute dtype: its only consumer is
        # the PRM head, whose fp32 weights promote the matmul operand at
        # the use site — an eager upcast here would ship d_model fp32
        # bytes per slot per step for numerically identical rewards
        # (pinned by test_stepcheck.test_prm_reward_dtype_equivalence)
        return next_tokens, hidden, logits, new_state

    def decode_step(self) -> Dict[int, int]:
        """One decode step for all active slots, piggybacking up to
        ``step_token_budget`` chunk-row tokens of pending prefills as
        concurrent lanes (mixed step) — one FIFO chunk when the budget is
        unset (see ``pack_chunk_lanes``).

        Handles host-side page accounting (boundary alloc + CoW) *before* the
        jit'd step, then appends the sampled token to each active branch.
        Returns {slot: new_token}.
        """
        cfg, mc = self.cfg, self.model.cfg
        if not self._active.any() and not self._pending_prefills:
            return {}
        # page accounting for the token about to be written
        if mc.uses_attention:
            cap = cfg.max_pages_per_branch * cfg.page_size
            for h in self.slots:
                if h is not None and h.blocks.length + 1 > cap:
                    # static block table full: surface as memory pressure so
                    # the scheduler's evict-longest path force-completes the
                    # branch instead of the table-refresh assert tripping
                    raise OutOfPagesError(
                        "branch at block-table capacity "
                        f"({cap} tokens)")
            if self.pages_needed_for_step() > self.allocator.free_pages:
                raise OutOfPagesError(
                    "decode step needs more pages than are free")
            cows = []
            # reprolint REP002 baselined: the pages_needed_for_step
            # pre-check above reserves this loop's worst case, so
            # append_token cannot raise mid-way
            for h in self.slots:
                if h is None:
                    continue
                cow = self.allocator.append_token(h.blocks)
                if cow is not None:
                    cows.append(cow)
                self._refresh_block_table(h)
        else:
            cows = []
            for h in self.slots:
                if h is not None:
                    h.blocks.length += 1

        # pack only after the page accounting above: an OutOfPagesError
        # abort must not charge skipped prefills' starvation counters for
        # a step that never ran. The step's CoW copies ride the step
        # program itself (one fused gather/scatter batched with the chunk
        # K/V writes — no separate host dispatch, whatever the lane count)
        lanes, bucket = self._pack_lanes()
        # the tree dedup map reflects POST-accounting topology: CoW and
        # boundary allocation above already diverged any page this step
        # writes, so no fork group's shared span covers a written page
        tree = (self._tree_map([h.blocks if h is not None else None
                                for h in self.slots])
                if self.cfg.decode_kernel == "tree" else None)
        if lanes:
            next_tokens, hidden = self._advance_chunks(
                lanes, piggyback=True, bucket=bucket, cows=cows, tree=tree)
        else:
            cow_src, cow_dst = self._cow_arrays(cows)
            if tree is None:
                tree = self._tree_map()
            next_tokens, hidden, _, new_state = self._step_jit(
                self.params, self.state, jnp.asarray(self._tokens),
                jnp.asarray(self._positions), jnp.asarray(self._block_tables),
                jnp.asarray(self._lengths), self._next_rng(), {},
                jnp.zeros((0,), jnp.int32), jnp.asarray(self._active),
                cow_src, cow_dst, tree, lane_buckets=())
            self.state.update(new_state)
        self._last_hidden = hidden[:cfg.max_slots]
        self.decode_steps_executed += 1

        out: Dict[int, int] = {}
        # the one mandated sync per step: sampled tokens drive host-side
        # branch bookkeeping (EOS detection, page accounting) before the
        # next dispatch can be built
        toks = np.asarray(next_tokens)  # reprolint: disable=REP005
        ps = cfg.page_size
        for slot, h in enumerate(self.slots):
            if h is None:
                continue
            tok = int(toks[slot])
            h.tokens.append(tok)
            out[slot] = tok
            self._tokens[slot] = tok
            self._positions[slot] += 1
            self._lengths[slot] += 1
            if (self.prefix_cache is not None
                    and h.prompt_tokens is not None
                    and h.blocks.length % ps == 0):
                # page-aligned decode boundary: long-running branches
                # publish their generated full pages without waiting for
                # completion. The post-step slot state corresponds to
                # exactly blocks.length consumed tokens, so it can seed
                # an ssm/hybrid resume at this boundary.
                if mc.uses_ssm and h.ssm_snaps is not None:
                    h.ssm_snaps[h.blocks.length] = (
                        self.state["conv"][:, h.slot:h.slot + 1],
                        self.state["ssd"][:, h.slot:h.slot + 1])
                self._insert_generated(h)
        return out

    # --------------------------------------------------------------- scoring
    def score_slots(self) -> np.ndarray:
        """PRM reward per slot from the cached last hidden state."""
        if self.prm_params is None:
            raise RuntimeError("engine has no PRM head")
        from ..core.prm import reward_from_hidden
        r = reward_from_hidden(self.prm_params, self._last_hidden)
        return np.asarray(r)


# --------------------------------------------------------------------- helpers


def _prefill_all(model: Model, params, tokens, positions):
    """Model.prefill but returning logits for all positions (for true-length
    indexing under padding)."""
    mc = model.cfg
    x = model._embed_inputs(params, tokens, None)
    b, s, _ = x.shape
    if mc.pos_embedding == "mrope" and positions.ndim == 2:
        positions = jnp.broadcast_to(positions[..., None], (b, s, 3))
    cache = model.init_cache(b, s)

    def body(x, scanned):
        layer_p, layer_cache = scanned
        return model._layer_prefill(layer_p, layer_cache, x, positions, s)

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    x = apply_norm(mc, params["final_norm"], x)
    logits = unembed(mc, params["embed"], x)
    return logits, new_cache


@functools.partial(jax.jit, static_argnames=("page_size",))
def _scatter_pages(k_pages, v_pages, k, v, page_ids, page_size):
    """k, v: [L, n_pages*ps, kv, hd] -> scatter into [L, kv, P, ps, hd]."""
    l, s, kvh, hd = k.shape
    n = s // page_size
    kk = k.reshape(l, n, page_size, kvh, hd).transpose(0, 3, 1, 2, 4)
    vv = v.reshape(l, n, page_size, kvh, hd).transpose(0, 3, 1, 2, 4)
    k_pages = k_pages.at[:, :, page_ids].set(kk.astype(k_pages.dtype))
    v_pages = v_pages.at[:, :, page_ids].set(vv.astype(v_pages.dtype))
    return k_pages, v_pages
