"""Paged KV cache with ref-counted prefix sharing.

This is SART's memory substrate (paper §4, last paragraph): all N branches of
a request share the prompt-prefix KV pages; a branch's own generated pages
are private. Pages are released *eagerly* when a branch is pruned,
early-stopped, or completed; the shared prefix pages are released when the
last sibling terminates. This eager release is what lets the scheduler batch
more requests (the paper's queuing-delay reduction).

Layout (TPU-friendly, consumed by ``repro.kernels.paged_attention``):
  k_pages, v_pages: [num_layers, kv_heads, num_pages, page_size, head_dim]

The allocator itself is plain Python (it runs on the host between jit'd decode
steps, exactly like vLLM's block manager runs on the CPU between CUDA steps).

Public contracts (documented in docs/architecture.md, which deep-links
here):

  * **Refcount conservation**: every page is either free or has refcount
    >= 1, never both, and the two sets partition the pool —
    ``check_invariants`` asserts it; ``tests/test_kv_properties.py``
    drives random op interleavings against it.
  * **All-or-nothing reservation**: ``extend`` (and ``alloc_prefix`` built
    on it) either allocates every page the growth needs or raises
    ``OutOfPagesError`` having allocated none, so callers never roll back
    partial state.
  * **Fork shares, append copies**: ``fork`` increfs all parent pages
    (including a trailing partial page); writers must ``cow_last_page``
    (or let ``append_token`` do it) before writing into a shared partial
    page. Release is eager and idempotent on an emptied block list.
  * **Decref-to-LRU vs decref-to-free**: with a ``PrefixCache`` attached
    (``attach_cache``), a cache-tracked page whose refcount drops to 0 is
    parked on the cache's LRU free-list — K/V resident, resurrectable on
    hash hit — instead of the free list; releasing a ``BranchBlocks``
    holding shared prefix pages therefore never recycles (and lets the
    engine overwrite) pages the cache still references. The partition
    invariant becomes live + free + LRU == all pages, and ``free_pages``
    counts LRU pages as reclaimable because ``alloc`` evicts them under
    pressure.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, TYPE_CHECKING

import numpy as np

if TYPE_CHECKING:  # pragma: no cover - import cycle guard, types only
    from .prefix_cache import PrefixCache


class OutOfPagesError(RuntimeError):
    """Raised when an allocation cannot be covered by free + evictable
    pages. Acquiring paths must leave refcounts unchanged when it
    propagates (the all-or-nothing contract; reprolint REP002)."""


@dataclasses.dataclass
class BranchBlocks:
    """Block table for one branch: shared prefix pages + private pages."""
    pages: List[int]              # all pages, in sequence order
    num_shared: int               # leading pages that are ref-shared
    length: int = 0               # valid tokens

    def copy(self) -> "BranchBlocks":
        """Shallow copy: a new page list, the same page ids. Refcounts are
        untouched — use ``PageAllocator.fork`` to share pages."""
        return BranchBlocks(list(self.pages), self.num_shared, self.length)


class PageAllocator:
    """Ref-counted page allocator (host-side)."""

    def __init__(self, num_pages: int, page_size: int) -> None:
        assert num_pages > 0 and page_size > 0
        self.num_pages = num_pages
        self.page_size = page_size
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self._refs: Dict[int, int] = {}
        self._cache: Optional["PrefixCache"] = None

    def attach_cache(self, cache: "PrefixCache") -> None:
        """Attach a ``PrefixCache`` (called by its constructor): decrefs
        of tracked pages park on the cache's LRU free-list, and ``alloc``
        evicts from it when the true free list runs dry."""
        assert self._cache is None, "allocator already has a prefix cache"
        self._cache = cache

    # ----------------------------------------------------------- primitives
    @property
    def free_pages(self) -> int:
        """Pages an allocation can draw on: the free list plus the prefix
        cache's refcount-0 LRU pages, which ``alloc`` evicts on demand."""
        return len(self._free) + \
            (self._cache.evictable if self._cache is not None else 0)

    @property
    def used_pages(self) -> int:
        """Pages referenced by live block tables (cached-idle LRU pages
        are warm *free* capacity, not usage — a drained system reports 0
        even while the cache keeps pages resident)."""
        return self.num_pages - self.free_pages

    def alloc(self) -> int:
        """Take one fresh page at refcount 1, evicting a cache-idle LRU
        page if the free list is dry. Raises ``OutOfPagesError`` (state
        unchanged) when neither source can supply a page."""
        if not self._free:
            if self._cache is not None and self._cache.evictable:
                self._cache.evict_one()    # LRU page -> self._free
            else:
                raise OutOfPagesError("KV pool exhausted")
        pid = self._free.pop()
        self._refs[pid] = 1
        return pid

    def incref(self, pid: int) -> None:
        """Add one reference to a *live* page (KeyError on a dead one —
        sharing can only extend lifetimes, never revive; reviving a cached
        refcount-0 page is ``resurrect``'s job)."""
        self._refs[pid] += 1

    def decref(self, pid: int) -> None:
        """Drop one reference; at zero the page leaves the live set — to
        the prefix cache's LRU list if the cache tracks it (K/V stay
        resident for resurrection), else to the free list."""
        self._refs[pid] -= 1
        assert self._refs[pid] >= 0, f"page {pid} double-free"
        if self._refs[pid] == 0:
            del self._refs[pid]
            # decref-to-LRU vs decref-to-free: a cache-tracked page keeps
            # its K/V resident for resurrection; recycling it through the
            # free list would let the next allocation overwrite state the
            # cache still maps
            if self._cache is not None and self._cache.retain(pid):
                return
            self._free.append(pid)

    def resurrect(self, pid: int) -> None:
        """Revive a refcount-0 cached page off the cache's LRU list (hash
        hit): it re-enters the live set with one reference, K/V intact —
        the zero-recompute, zero-rewrite path warm admission hits. (No
        free-list membership assert here: that would be an O(num_pages)
        scan on the warm path; ``check_invariants`` covers the partition.)
        """
        assert pid not in self._refs, f"page {pid} already live"
        self._refs[pid] = 1

    def reclaim(self, pid: int) -> None:
        """Return an unreferenced cache-evicted page to the free list
        (the write half of the cache's eviction valve — symmetric with
        ``resurrect``, so the free list is only ever grown through
        allocator methods that can assert the page is dead)."""
        assert pid not in self._refs, f"page {pid} still referenced"
        self._free.append(pid)

    def refcount(self, pid: int) -> int:
        """Current reference count; 0 for free and cached-idle pages."""
        return self._refs.get(pid, 0)

    # ------------------------------------------------------- branch helpers
    def pages_for(self, num_tokens: int) -> int:
        """Pages needed to hold ``num_tokens`` (ceiling division)."""
        return -(-num_tokens // self.page_size)

    def alloc_prefix(self, num_tokens: int) -> BranchBlocks:
        """Allocate pages for a freshly prefilled prompt."""
        b = BranchBlocks(pages=[], num_shared=0, length=0)
        self.extend(b, max(num_tokens, 1))
        b.length = num_tokens
        return b

    def extend(self, b: BranchBlocks, new_length: int) -> List[int]:
        """Grow a branch's page list to cover ``new_length`` tokens,
        appending fresh (refcount-1) pages only. ``alloc_prefix`` is built
        on this; chunked prefill reserves a prompt's pages in one extend at
        admission (fail-fast, so an OutOfPagesError leaves nothing to roll
        back). All-or-nothing: raises OutOfPagesError without allocating
        anything if the pool cannot cover the growth; returns the new page
        ids. Like ``append_token``, it does NOT CoW a shared trailing
        partial page — callers writing into one must ``cow_last_page``
        first.
        """
        assert new_length >= b.length, "extend cannot shrink a branch"
        n = self.pages_for(new_length) - len(b.pages)
        if n > self.free_pages:
            raise OutOfPagesError(f"need {n} pages, {self.free_pages} free")
        new: List[int] = []
        try:
            for _ in range(max(n, 0)):
                new.append(self.alloc())
        except OutOfPagesError:
            # all-or-nothing structurally, not just via the pre-check:
            # return the pages already taken before re-raising
            for pid in reversed(new):
                self.decref(pid)
            raise
        b.pages.extend(new)
        b.length = new_length
        return new

    def fork(self, parent: BranchBlocks) -> BranchBlocks:
        """Fork a branch off `parent`, sharing all its pages.

        All parent pages (including a trailing partial page) become shared;
        the engine performs copy-on-write when a branch needs to append into
        a shared partial page (see ``needs_cow``).

        The fork adds exactly one reference per page for the child. A
        parent page that idled onto the prefix cache's LRU list (its
        holder released while this ``BranchBlocks`` — e.g. a ``copy`` kept
        by the scheduler — still names it) is revived off the LRU at
        refcount 1 rather than incref'd: incref only extends live
        lifetimes and would KeyError on the parked page.
        """
        # reprolint REP002 is baselined here: incref on a live parent page
        # cannot raise OutOfPagesError, so the loop cannot partially fail
        for pid in parent.pages:
            if (pid not in self._refs and self._cache is not None
                    and self._cache.revive(pid)):
                continue                   # child holds the single new ref
            self.incref(pid)
        return BranchBlocks(pages=list(parent.pages),
                            num_shared=len(parent.pages),
                            length=parent.length)

    def needs_cow(self, b: BranchBlocks) -> bool:
        """True if appending one token would write into a shared page."""
        if b.length % self.page_size == 0:
            return False  # next token opens a fresh page
        last_idx = len(b.pages) - 1
        return last_idx < b.num_shared and self.refcount(b.pages[last_idx]) > 1

    def cow_last_page(self, b: BranchBlocks) -> Tuple[int, int]:
        """Copy-on-write the trailing shared partial page.

        Returns (old_pid, new_pid) so the engine can copy device data.
        """
        old = b.pages[-1]
        new = self.alloc()
        self.decref(old)
        b.pages[-1] = new
        b.num_shared = len(b.pages) - 1
        return old, new

    def append_token(self, b: BranchBlocks) -> Optional[Tuple[int, int]]:
        """Account for one more token; allocates a page on boundary.

        Returns (old_pid, new_pid) if a CoW copy is required, else None.
        The caller must perform the device copy before the next decode step.
        """
        cow = None
        if self.needs_cow(b):
            cow = self.cow_last_page(b)
        if b.length % self.page_size == 0:
            if b.length // self.page_size == len(b.pages):
                b.pages.append(self.alloc())
        b.length += 1
        return cow

    def release(self, b: BranchBlocks) -> None:
        """Eagerly release a terminated branch's pages (shared pages only
        drop a reference; freed once all siblings terminate). Pages are
        decref'd leaf-first so cache-tracked chains idle onto the LRU list
        deepest-page-first — eviction then reclaims leaves before their
        parents and keeps surviving chains walkable."""
        for pid in reversed(b.pages):
            self.decref(pid)
        b.pages = []
        b.length = 0
        b.num_shared = 0

    # ------------------------------------------------------------ invariants
    def check_invariants(self) -> None:
        """Assert the pool partition: live + free + cached-idle LRU pages
        cover every page exactly once, and all refcounts are positive.
        O(num_pages); tests call it after every mutation."""
        live = set(self._refs)
        free = set(self._free)
        lru = set(self._cache.lru_pages) if self._cache is not None else set()
        assert not (live & free), "page both live and free"
        assert not (live & lru), "page both live and cached-idle"
        assert not (free & lru), "page both free and cached-idle"
        assert len(free) == len(self._free), "duplicate free pages"
        assert live | free | lru == set(range(self.num_pages)), "page leak"
        assert all(r > 0 for r in self._refs.values())
        if self._cache is not None:
            self._cache.check_invariants()


def tree_decode_map(
    blocks: Sequence[Optional[BranchBlocks]],
    *,
    pages_per_branch: int,
    num_pages: int,
    page_size: int,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Build the branch×page dedup map the tree-decode attention kernel
    consumes (``repro.kernels.paged_tree_attention``) from the slots'
    fork topology.

    Rows sharing their first page id form a fork group (page ids are
    refcount-shared on fork, so a common ``pages[0]`` — whether from
    ``fork`` or from cross-request prefix-cache admission — means
    physically identical leading KV); the group's shared span is the raw
    longest common page-id prefix across its members. Spans are whole
    pages by construction (page lists diverge at CoW/alloc boundaries
    after per-step accounting), and the kernel's per-row attend mask
    (``kpos < min(length, span)``) keeps a row whose context ends inside
    the span from reading past its own written extent.

    Returns ``(row_group, shared_bt, shared_lens, branch_bt)`` —
    ``row_group`` [B] int32 mapping each row to its group (``B`` = the
    ungrouped sentinel: singletons, empty slots, page-less rows keep
    their full table in ``branch_bt``); ``shared_bt`` [B,
    pages_per_branch] int32 per-group shared page tables; ``shared_lens``
    [B] int32 shared token spans; ``branch_bt`` [B, pages_per_branch]
    int32 post-fork suffix tables. Unused entries hold the ``num_pages``
    OOB sentinel (tables) / 0 (spans); the group axis is padded to B so
    the map's shapes are static per engine config.
    """
    b = len(blocks)
    row_group = np.full((b,), b, np.int32)
    shared_bt = np.full((b, pages_per_branch), num_pages, np.int32)
    shared_lens = np.zeros((b,), np.int32)
    branch_bt = np.full((b, pages_per_branch), num_pages, np.int32)
    groups: Dict[int, List[int]] = {}
    for i, blk in enumerate(blocks):
        if blk is not None and blk.pages:
            groups.setdefault(blk.pages[0], []).append(i)
    gid = 0
    for members in groups.values():
        if len(members) < 2:
            continue
        lists = [blocks[i].pages for i in members]  # type: ignore[union-attr]
        depth = 0
        for cols in zip(*lists):
            if len(set(cols)) != 1:
                break
            depth += 1
        shared_bt[gid, :depth] = lists[0][:depth]
        shared_lens[gid] = depth * page_size
        for i, pages in zip(members, lists):
            row_group[i] = gid
            suffix = pages[depth:]
            branch_bt[i, :len(suffix)] = suffix
        gid += 1
    for i, blk in enumerate(blocks):
        if row_group[i] == b and blk is not None and blk.pages:
            branch_bt[i, :len(blk.pages)] = blk.pages
    return row_group, shared_bt, shared_lens, branch_bt
