from .paged import BranchBlocks, OutOfPagesError, PageAllocator

__all__ = ["BranchBlocks", "OutOfPagesError", "PageAllocator"]
