from .paged import BranchBlocks, OutOfPagesError, PageAllocator
from .prefix_cache import CacheNode, PrefixCache, default_page_hash

__all__ = ["BranchBlocks", "OutOfPagesError", "PageAllocator",
           "CacheNode", "PrefixCache", "default_page_hash"]
