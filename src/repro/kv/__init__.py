from .paged import (BranchBlocks, OutOfPagesError, PageAllocator,
                    tree_decode_map)
from .prefix_cache import CacheNode, PrefixCache, default_page_hash

__all__ = ["BranchBlocks", "OutOfPagesError", "PageAllocator",
           "CacheNode", "PrefixCache", "default_page_hash",
           "tree_decode_map"]
