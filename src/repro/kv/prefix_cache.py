"""Radix page-hash prompt prefix cache over the paged KV allocator.

SART's redundant sampling already shares a request's prompt pages across
its N branches (``PageAllocator.fork``); this module extends the sharing
*across requests*: realistic reasoning workloads repeat long prompt
prefixes (few-shot math headers, shared system prompts), and recomputing
and re-storing those pages per request wastes exactly the admission FLOPs
chunked prefill made cheap and the HBM pages branch pruning frees.

Design (SGLang-style radix reuse, adapted to page granularity):

  * **Nodes are full pages.** The cache is a radix tree whose edges are
    ``page_size``-token chunks; a node owns exactly one KV page whose
    contents are the K/V of those tokens at those absolute positions.
    Only *page-aligned* prefixes are ever reused, so a hit needs no
    partial-page copies.
  * **Rolling hashes key the walk.** Each node is registered under
    ``hash_fn(parent_hash, page_tokens)``; lookup walks the prompt one
    page at a time through a flat hash→candidates dict. Candidates are
    verified against the stored tokens AND the parent node's identity, so
    hash collisions degrade to misses, never to wrong pages
    (``tests/test_kv_properties.py`` injects colliding ``hash_fn``s).
  * **Refcount-0 pages park on an LRU free-list.** The cache holds no
    refcount of its own: while any request/branch references a cached
    page it is simply a shared live page. When the last reference drops,
    ``PageAllocator.decref`` routes the page *here* instead of the free
    list (``retain``): its K/V stays resident, a later hash hit
    resurrects it at zero recompute/rewrite cost, and only allocation
    pressure (``evict_one``, called by ``PageAllocator.alloc`` when the
    true free list runs dry) actually frees it.
  * **SSM state gates reuse for ssm/hybrid.** Attention K/V is position-
    addressable, but the masked-dt chunked scan needs the running per-
    layer (conv, ssd) state *at the resume boundary*. Nodes optionally
    carry that state (snapshotted when a chunk boundary lands on a page
    boundary); ``acquire(need_state=True)`` truncates the match to the
    deepest node that has one, so dense configs reuse every matched page
    while ssm/hybrid reuse exactly as far as a seedable boundary exists.

Invariants (asserted by ``PageAllocator.check_invariants`` +
``check_invariants`` here, driven by random interleavings in
``tests/test_kv_properties.py``):

  * every cache-tracked page has refcount >= 1 or sits on the LRU
    free-list (never both, never the allocator's free list);
  * live + free + LRU partition the pool (conservation under
    admit/fork/release/evict interleavings);
  * evicting a node never frees a page a live branch still references
    (only refcount-0 LRU pages are eviction candidates).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import (Callable, Dict, KeysView, List, Optional, Sequence,
                    Tuple)

from .paged import BranchBlocks, OutOfPagesError, PageAllocator

# rolling-hash seed for the radix root (any constant works; the chain is
# (seed, page0) -> (h0, page1) -> ...)
_ROOT_HASH = 0x9E3779B9


def default_page_hash(parent_hash: int, tokens: tuple) -> int:
    """Rolling page hash: chain the parent's hash with this page's
    tokens. Pluggable (collisions are verified away by ``_match_child``,
    so a weak hash degrades to misses, never wrong pages)."""
    return hash((parent_hash, tokens))


@dataclasses.dataclass(eq=False)           # identity equality: two nodes can
class CacheNode:                           # legally share (hash, tokens)
    """One cached page: ``tokens`` at absolute positions
    ``[(depth-1)*ps, depth*ps)``, K/V resident in ``page_id``."""
    key: int                               # rolling hash at this node
    tokens: tuple                          # the page's page_size tokens
    page_id: int
    parent: Optional["CacheNode"]          # None = child of the root
    depth: int                             # pages from the root, 1-based
    ssm_state: object = None               # per-layer (conv, ssd) at this
    #                                        page boundary, or None


class PrefixCache:
    """Radix page-hash cache; attaches itself to a ``PageAllocator``."""

    def __init__(self, allocator: PageAllocator,
                 hash_fn: Callable[[int, tuple], int] = default_page_hash
                 ) -> None:
        self.allocator = allocator
        self.page_size = allocator.page_size
        self.hash_fn = hash_fn
        self._nodes: Dict[int, List[CacheNode]] = {}   # hash -> candidates
        self._by_page: Dict[int, CacheNode] = {}       # page id -> node
        # refcount-0 cached pages, oldest-idle first (the "LRU free-list")
        self._lru: "OrderedDict[int, CacheNode]" = OrderedDict()
        # counters (surfaced via stats() -> serve CLI / benchmarks)
        self.lookups = 0
        self.hits = 0                      # lookups matching >= 1 page
        self.hit_tokens = 0
        self.lookup_tokens = 0
        self.inserted_pages = 0
        self.evictions = 0
        self.resurrections = 0
        allocator.attach_cache(self)

    # ------------------------------------------------------------- internals
    def _match_child(self, parent: Optional[CacheNode], h: int,
                     tokens: tuple) -> Optional[CacheNode]:
        """Resolve the next node of a walk, verifying tokens + parent
        identity so hash collisions never alias two prefixes."""
        for cand in self._nodes.get(h, ()):
            if cand.parent is parent and cand.tokens == tokens:
                return cand
        return None

    def _walk(self, prompt: Sequence[int],
              max_pages: int) -> List[CacheNode]:
        """Longest chain of cached nodes covering ``prompt``'s pages."""
        matched: List[CacheNode] = []
        h, node = _ROOT_HASH, None
        ps = self.page_size
        for i in range(max_pages):
            tokens = tuple(prompt[i * ps:(i + 1) * ps])
            h = self.hash_fn(h, tokens)
            node = self._match_child(node, h, tokens)
            if node is None:
                break
            matched.append(node)
        return matched

    # ------------------------------------------------------------ public API
    @property
    def evictable(self) -> int:
        """Pages reclaimable under allocation pressure (the LRU list)."""
        return len(self._lru)

    @property
    def tracked_pages(self) -> int:
        """Pages the radix tree currently maps (live + idle)."""
        return len(self._by_page)

    @property
    def lru_pages(self) -> "KeysView[int]":
        """Ids of refcount-0 cached pages, oldest-idled first (a live
        view — the allocator's partition check iterates it)."""
        return self._lru.keys()

    def match_tokens(self, prompt: Sequence[int],
                     need_state: bool = False) -> int:
        """Non-mutating probe: how many of ``prompt``'s leading tokens an
        ``acquire`` would serve from cache *right now*. Same match rule as
        ``acquire`` — page-aligned, capped so the last prompt token is
        always recomputed, truncated to a seedable SSM boundary when
        ``need_state`` — but takes **no** page references, leaves the LRU
        order untouched, and pollutes no hit/lookup counters. This is the
        lookup the LPM admission policy runs over every queued request
        each admission opportunity, so it must be observationally free."""
        matched = self._walk(prompt, max(0, (len(prompt) - 1))
                             // self.page_size)
        if need_state:
            while matched and matched[-1].ssm_state is None:
                matched.pop()
        return len(matched) * self.page_size

    def acquire(self, prompt: Sequence[int], need_state: bool = False
                ) -> Tuple[List[int], object]:
        """Look up the longest cached page-aligned prefix of ``prompt`` and
        take one reference on each matched page (resurrecting refcount-0
        pages off the LRU list).

        The match is capped at ``(len(prompt) - 1) // page_size`` pages so
        at least one prompt token is always recomputed — the admission
        path needs the last position's logits to sample the first branch
        token, and the recomputed tail then starts on a page boundary of
        an uncached page (no partial-page CoW at admission).

        ``need_state=True`` (ssm/hybrid) additionally truncates the match
        to the deepest node carrying an SSM boundary state — reuse without
        a seedable (conv, ssd) state would corrupt the recurrence.

        Returns ``(page_ids, ssm_state_or_None)``; the caller owns one
        reference per returned page and must decref them on failure paths
        (see ``Engine._new_chunked_state``).
        """
        self.lookups += 1
        self.lookup_tokens += len(prompt)
        matched = self._walk(prompt, max(0, (len(prompt) - 1))
                             // self.page_size)
        if need_state:
            while matched and matched[-1].ssm_state is None:
                matched.pop()
        taken: List[int] = []
        try:
            for node in matched:
                pid = node.page_id
                if self.allocator.refcount(pid) == 0:
                    # resurrect BEFORE the LRU pop: if it raises, the
                    # page is still parked (live/free/LRU partition
                    # intact) instead of stranded in neither set
                    self.allocator.resurrect(pid)
                    self._lru.pop(pid)
                    self.resurrections += 1
                else:
                    self.allocator.incref(pid)
                taken.append(pid)
        except Exception:
            # all-or-nothing like admit: give back the references already
            # taken (decref re-idles resurrected pages onto the LRU via
            # retain, so conservation holds) before propagating
            for pid in reversed(taken):
                self.allocator.decref(pid)
            raise
        if matched:
            self.hits += 1
            self.hit_tokens += len(matched) * self.page_size
        state = matched[-1].ssm_state if (need_state and matched) else None
        return [node.page_id for node in matched], state

    def admit(self, prompt: Sequence[int], need_state: bool = False
              ) -> Tuple[BranchBlocks, object]:
        """The warm-admission dance, shared by ``Engine`` and
        ``SimEngine``: ``acquire`` the cached prefix, lead the block list
        with it (shared pages), and reserve the uncached tail
        all-or-nothing — rolling the acquired references back (leaf-first,
        re-idling them onto the LRU) if the tail allocation fails, so
        admission under pressure leaves no trace. Returns a
        ``BranchBlocks`` covering the whole prompt plus the boundary SSM
        state (or None); ``blocks.num_shared * page_size`` is the resume
        offset."""
        pages, state = self.acquire(prompt, need_state)
        b = BranchBlocks(pages=list(pages), num_shared=len(pages),
                         length=len(pages) * self.page_size)
        try:
            self.allocator.extend(b, max(len(prompt), 1))
        except OutOfPagesError:
            for pid in reversed(pages):
                self.allocator.decref(pid)
            raise
        b.length = len(prompt)
        return b, state

    def insert(self, prompt: Sequence[int], pages: Sequence[int],
               ssm_states: Optional[Dict[int, object]] = None) -> int:
        """Register a finished prefill's full pages as cache nodes.

        Walks the existing radix chain; pages whose (prefix, tokens) are
        already cached — e.g. the very pages ``acquire`` handed out, or a
        concurrent request that inserted first — are skipped (the
        request's own duplicate page simply stays untracked and frees
        normally). Only *full* pages are inserted: the trailing partial
        page keeps private CoW semantics. ``ssm_states`` maps page-aligned
        token boundaries to (conv, ssd) snapshots; they attach to the node
        at that depth so later ssm/hybrid lookups can resume there.
        Returns the number of newly tracked pages.
        """
        ps = self.page_size
        h, node = _ROOT_HASH, None
        new = 0
        for i in range(len(prompt) // ps):
            tokens = tuple(prompt[i * ps:(i + 1) * ps])
            h = self.hash_fn(h, tokens)
            nxt = self._match_child(node, h, tokens)
            if nxt is None:
                pid = pages[i]
                if pid in self._by_page:   # page already owned by another
                    break                  # chain — never alias it
                nxt = CacheNode(key=h, tokens=tokens, page_id=pid,
                                parent=node, depth=i + 1)
                self._nodes.setdefault(h, []).append(nxt)
                self._by_page[pid] = nxt
                new += 1
            if ssm_states and nxt.ssm_state is None:
                nxt.ssm_state = ssm_states.get((i + 1) * ps)
            node = nxt
        self.inserted_pages += new
        return new

    def revive(self, pid: int) -> bool:
        """Called by ``PageAllocator.fork`` when a parent page is parked
        on the LRU free-list (refcount 0, K/V resident): resurrect it so
        the fork's child holds the single new reference. Returns False
        for pages this cache has not parked — the allocator then treats
        the page as live and increfs (KeyError on a genuinely dead page,
        as before)."""
        if pid not in self._lru:
            return False
        # resurrect BEFORE the LRU pop, mirroring ``acquire``
        self.allocator.resurrect(pid)
        self._lru.pop(pid)
        self.resurrections += 1
        return True

    def retain(self, pid: int) -> bool:
        """Called by ``PageAllocator.decref`` when a page's refcount hits
        0: park tracked pages on the LRU free-list (K/V stays resident for
        resurrection) instead of freeing them. Returns False for untracked
        pages, which free normally."""
        node = self._by_page.get(pid)
        if node is None:
            return False
        self._lru[pid] = node              # most-recently idled at the end
        return True

    def evict_one(self) -> int:
        """Reclaim the least-recently-idled refcount-0 page for the
        allocator (called under ``OutOfPagesError`` pressure only). The
        node is unregistered; its descendants become unreachable orphans
        (the walk verifies parent identity) and drain off the LRU in
        turn. Returns the freed page id."""
        if not self._lru:
            raise KeyError("prefix cache has no evictable pages")
        pid, node = self._lru.popitem(last=False)
        self._nodes[node.key].remove(node)
        if not self._nodes[node.key]:
            del self._nodes[node.key]
        del self._by_page[pid]
        # drop the device-state snapshot and the parent link: orphaned
        # descendants still referencing this node must not pin its
        # (conv, ssd) arrays (or a chain of evicted ancestors) in memory
        node.ssm_state = None
        node.parent = None
        self.allocator.reclaim(pid)
        self.evictions += 1
        return pid

    def drop(self) -> None:
        """Evict every idle page (testing / explicit cache reset)."""
        while self._lru:
            self.evict_one()

    # ------------------------------------------------------------ diagnostics
    def stats(self) -> Dict[str, float]:
        """Counter snapshot for the serve CLI and benchmarks: lookups,
        hits, token-weighted hit rate, insert/evict/resurrect totals, and
        current tracked/LRU page counts."""
        return {
            "lookups": self.lookups,
            "hits": self.hits,
            "hit_tokens": self.hit_tokens,
            "lookup_tokens": self.lookup_tokens,
            "hit_rate": (self.hit_tokens / self.lookup_tokens
                         if self.lookup_tokens else 0.0),
            "inserted_pages": self.inserted_pages,
            "tracked_pages": len(self._by_page),
            "lru_pages": len(self._lru),
            "evictions": self.evictions,
            "resurrections": self.resurrections,
        }

    def check_invariants(self) -> None:
        """Cache half of the conservation contract (the allocator asserts
        the live/free/LRU partition): every tracked page has refcount >= 1
        or sits on the LRU free-list; every LRU page is tracked; node
        registration is consistent."""
        for pid, node in self._by_page.items():
            assert node.page_id == pid
            assert node in self._nodes.get(node.key, ()), \
                f"page {pid}: node missing from hash bucket"
            assert self.allocator.refcount(pid) >= 1 or pid in self._lru, \
                f"cached page {pid} neither referenced nor on the LRU list"
        for pid in self._lru:
            assert pid in self._by_page, f"LRU page {pid} untracked"
            assert self.allocator.refcount(pid) == 0, \
                f"LRU page {pid} still referenced"
