"""Logical activation-sharding constraints (MaxText-style).

GSPMD propagation alone loses the batch sharding inside the scanned layer
body (measured: attention scores materialized at *global* batch and
all-reduced — 120 GB/device — see EXPERIMENTS.md §Perf iteration 0). Model
code therefore pins activations to logical axes at layer boundaries via
``constrain(x, name)``; the launcher binds logical names to mesh
PartitionSpecs with ``activation_rules(...)`` for the duration of tracing.

Outside any ``activation_rules`` context (CPU unit tests, the live serving
engine) ``constrain`` is the identity — model code stays mesh-agnostic.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec

_STATE = threading.local()


def _current():
    return getattr(_STATE, "rules", None)


# --------------------------------------------------------------------------
# Analysis mode: XLA's cost_analysis counts a while-loop body ONCE, not
# times its trip count (measured: train flops identical for L=1,2,3). For
# the roofline pass the dry-run therefore compiles small-L model variants
# with EVERY lax.scan fully unrolled (layers, attention q-chunks, SSD
# chunks) and extrapolates per-layer deltas. Model code asks scan_unroll()
# for its `unroll=` argument.
# --------------------------------------------------------------------------


@contextlib.contextmanager
def analysis_mode():
    prev = getattr(_STATE, "analysis", False)
    _STATE.analysis = True
    try:
        yield
    finally:
        _STATE.analysis = prev


def scan_unroll():
    return bool(getattr(_STATE, "analysis", False))


def moe_dp_chunks() -> int:
    """Perf-iteration lever (EXPERIMENTS.md §Perf iteration 2): number of
    data shards for shard-local MoE dispatch. 0/1 = global dispatch
    (baseline). Set through the activation_rules map under "_moe_dp"."""
    cur = _current()
    if cur is None:
        return 0
    return int(cur[1].get("_moe_dp", 0) or 0)


@contextlib.contextmanager
def activation_rules(mesh, rules: Dict[str, PartitionSpec]):
    prev = _current()
    _STATE.rules = (mesh, dict(rules))
    try:
        yield
    finally:
        _STATE.rules = prev


def constrain(x, name: str):
    cur = _current()
    if cur is None:
        return x
    mesh, rules = cur
    spec = rules.get(name)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def standard_rules(dp, tp="model", *, replicate_batch: bool = False
                   ) -> Dict[str, PartitionSpec]:
    """Logical-axis map used by the launchers.

    dp: tuple of data-parallel axis names (('pod','data') or ('data',)).
    ``replicate_batch``: long_500k mode (global_batch=1).
    """
    b = None if replicate_batch else dp
    return {
        "btd": PartitionSpec(b, None, None),   # token activations [B,S,D]
        "bshd": PartitionSpec(b, None, tp, None),  # per-head q/k/v [B,S,H,hd]
        "btv": PartitionSpec(b, None, tp),     # logits [B,S,V]
        "bv": PartitionSpec(b, tp),            # decode logits [B,V]
        "ecd": PartitionSpec(tp, None, None),  # MoE dispatch buffer [E,C,D]
        "ecf": PartitionSpec(tp, None, None),  # MoE expert hidden [E,C,F]
        # shard-local MoE dispatch (perf lever): group axis = data shards
        "gtd": PartitionSpec(b, None, None),   # regrouped tokens [G,T/G,D]
        "gecd": PartitionSpec(b, tp, None, None),  # local buffers [G,E,C,D]
    }
