"""Sharding rules: parameter/optimizer/activation PartitionSpecs.

Scheme (DESIGN.md §5):
  * tp   = 'model' — tensor/expert parallel: attention heads, FFN hidden,
           experts, vocab.
  * fsdp = 'data'  — weight/optimizer-state sharding along the *other*
           matrix dim (ZeRO-3-style); XLA SPMD inserts the per-layer
           all-gathers during compute.
  * batch axes: ('pod', 'data') when multi-pod, else ('data',).

Dims that do not divide the mesh axis (e.g. kv_heads=2 over model=16,
vocab=50280 over 16) rely on GSPMD's implicit padding — correct, with a
memory/compute overhead that the roofline analysis surfaces and the perf
iterations attack (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import re
from typing import Any, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

TP = "model"
FSDP = "data"

# (path regex, spec WITHOUT the leading layer-stack axis)
_PARAM_RULES = [
    # Vocab-parallel embeddings with d_model replicated: keeping the logits
    # contraction dim local avoids GSPMD partial-summing [B,S,V]-sized
    # tensors over 'data' (measured 410 GB/device of all-reduce with
    # P(TP, FSDP) — see EXPERIMENTS.md §Perf iteration 0).
    (r"embed/embedding$",        P(TP, None)),
    (r"embed/lm_head$",          P(None, TP)),
    (r"attn/w[qkv]$",            P(FSDP, TP)),
    (r"attn/wo$",                P(TP, FSDP)),
    (r"attn/b[qkv]$",            P(TP)),
    (r"mlp/w_(up|gate)$",        P(FSDP, TP)),
    (r"mlp/w_down$",             P(TP, FSDP)),
    (r"mlp/b_up$",               P(TP)),
    (r"mlp/b_down$",             P(None)),
    (r"moe/router$",             P(FSDP, None)),
    (r"moe/w_(up|gate)$",        P(TP, FSDP, None)),   # experts on tp
    (r"moe/w_down$",             P(TP, None, FSDP)),
    (r"mamba/in_proj$",          P(FSDP, TP)),
    (r"mamba/out_proj$",         P(TP, FSDP)),
    (r"mamba/conv_w$",           P(None, TP)),
    (r"mamba/conv_b$",           P(TP)),
    (r"mamba/norm/(scale|bias)$", P(TP)),
    (r"mamba/(a_log|d_skip|dt_bias)$", P(None)),
    (r"norm\d?/(scale|bias)$",   P(None)),
    (r"final_norm/(scale|bias)$", P(None)),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_spec(path_str: str, ndim: int) -> P:
    stacked = path_str.startswith("layers/")
    for pat, spec in _PARAM_RULES:
        if re.search(pat, path_str):
            parts = tuple(spec)
            if stacked:
                parts = (None,) + parts
            assert len(parts) <= ndim, (path_str, parts, ndim)
            parts = parts + (None,) * (ndim - len(parts))
            return P(*parts)
    raise KeyError(f"no sharding rule for param {path_str!r} (ndim={ndim})")


def param_pspecs(params_shape) -> Any:
    """Map a params shape-pytree (from jax.eval_shape) to PartitionSpecs."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_spec(_path_str(path), leaf.ndim),
        params_shape)


def drop_fsdp(spec_tree) -> Any:
    """Perf-iteration lever: pure-TP parameter layout for decode.

    Replaces the FSDP ('data') axis in every param spec with replication,
    leaving tensor/expert parallelism intact. Decode is memory-bound and
    latency-critical: with 2D (FSDP+TP) weights, XLA all-gathers every
    layer's weights over 'data' on every single-token step; pure TP keeps
    weights resident. Only valid when params/TP fit HBM — callers check
    via ``fits_tp`` (EXPERIMENTS.md §Perf iteration 1).
    """
    def fix(spec):
        if not isinstance(spec, P):
            return spec
        out = []
        for axes in spec:
            if axes is None:
                out.append(None)
            elif isinstance(axes, tuple):
                kept = tuple(a for a in axes if a != FSDP)
                out.append(kept if kept else None)
            else:
                out.append(None if axes == FSDP else axes)
        return P(*out)

    return jax.tree.map(fix, spec_tree, is_leaf=lambda x: isinstance(x, P))


def opt_pspecs(params_shape) -> Any:
    ps = param_pspecs(params_shape)
    return {"mu": ps, "nu": ps, "step": P()}


def batch_axes(multi_pod: bool) -> Tuple[str, ...]:
    return ("pod", "data") if multi_pod else ("data",)


def cache_pspecs(cache_shape, dp, *, shard_seq: bool = False,
                 tp_size: int = 16) -> Any:
    """Decode-cache specs, shape-aware.

    KV cache [L,B,S,kv,hd]: the 'model' axis lands on kv_heads when they
    divide it (stablelm/gemma), otherwise on the *sequence* axis — the
    flash-decode layout where each model-shard holds a KV slab and SPMD
    merges partial softmax stats. ``shard_seq``: long_500k mode — batch=1 is
    replicated and sequence takes the data axis too.

    SSM states: conv channels and SSD head_dim take the model axis (SSD head
    counts like 24 rarely divide it).
    """
    specs = {}
    for key, leaf in cache_shape.items():
        if key in ("k", "v"):          # [L, B, S, kv, hd]
            kv = leaf.shape[3]
            if kv % tp_size == 0:
                specs[key] = (P(None, None, dp, TP, None) if shard_seq
                              else P(None, dp, None, TP, None))
            else:
                seq_axes = (tuple(dp) + (TP,)) if shard_seq else TP
                specs[key] = (P(None, None, seq_axes, None, None)
                              if shard_seq
                              else P(None, dp, TP, None, None))
        elif key == "conv":            # [L, B, W-1, C]
            specs[key] = P(None, None if shard_seq else dp, None, TP)
        elif key == "ssd":             # [L, B, H, P, N]
            specs[key] = P(None, None if shard_seq else dp, None, TP, None)
        else:
            raise KeyError(key)
    return specs


def sanitize_pspecs(spec_tree, shape_tree, mesh):
    """Drop any sharded dim whose size does not divide its mesh axes.

    pjit *input* shardings require exact divisibility (GSPMD pads only
    intermediates); e.g. vocab=50280 or kv_heads=2 cannot take a 16-way
    axis — those dims fall back to replication.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def fix(spec, leaf):
        if not isinstance(spec, P):
            return spec
        parts = list(spec) + [None] * (leaf.ndim - len(spec))
        out = []
        for dim, axes in enumerate(parts[:leaf.ndim]):
            if axes is None:
                out.append(None)
                continue
            names = axes if isinstance(axes, tuple) else (axes,)
            total = 1
            for n in names:
                total *= sizes[n]
            out.append(axes if leaf.shape[dim] % total == 0 else None)
        return P(*out)

    return jax.tree.map(fix, spec_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, P))


def shardings(mesh, tree_of_pspecs):
    return jax.tree.map(
        lambda spec: NamedSharding(mesh, spec), tree_of_pspecs,
        is_leaf=lambda x: isinstance(x, P))
