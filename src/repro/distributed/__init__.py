from .sharding import (batch_axes, cache_pspecs, opt_pspecs, param_pspecs,
                       param_spec, shardings, FSDP, TP)

__all__ = ["batch_axes", "cache_pspecs", "opt_pspecs", "param_pspecs",
           "param_spec", "shardings", "FSDP", "TP"]
