"""Orchestration: build targets, trace variants, run every analyzer.

Kept separate from the CLI so tests can call ``run_all`` (or the
individual pieces) directly and so the expensive part — tracing — runs
exactly once per target.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from tools.reprolint.framework import Finding

from . import bounds, harness, jaxpr_rules, manifest


@dataclasses.dataclass
class RunResult:
    """Everything one stepcheck pass produced."""

    findings: List[Finding]
    per_target: Dict[str, Dict[str, dict]]   # cache-off signature records
    manifest: dict                           # freshly built (not committed)
    targets_analyzed: int
    variants_traced: int


def run_all(committed_manifest: Optional[dict] = None,
            include_cache: bool = True) -> RunResult:
    """Full analysis. ``committed_manifest=None`` loads the repo file;
    pass ``{}`` to skip the STEP002 ratchet (tests do)."""
    findings: List[Finding] = []
    targets = harness.build_targets(include_cache=include_cache)
    per_target: Dict[str, Dict[str, dict]] = {}
    cache_sigs: Dict[str, Tuple[str, Dict[str, dict]]] = {}
    variants_traced = 0

    for target in targets:
        traced = [(v, harness.trace_variant(target.engine, v))
                  for v in target.variants]
        variants_traced += len(traced)
        sigs = manifest.signatures_for(target, traced)
        findings.extend(manifest.check_bound(target, traced))
        if target.cache:
            cache_sigs[target.family] = (target.name, sigs)
        else:
            per_target[target.name] = sigs
            # the jaxpr walkers run on cache-off targets only: the
            # cache-on twin is the same step program by construction
            # (asserted below via signature equality)
            findings.extend(jaxpr_rules.run_jaxpr_rules(target, traced))

    for family, (on_name, on_sigs) in sorted(cache_sigs.items()):
        off_sigs = per_target.get(f"engine[{family}]", {})
        findings.extend(manifest.check_cache_invariance(
            off_sigs, on_sigs, on_name))

    engine_names = [v.name
                    for t in targets if t.name == "engine[dense]"
                    for v in t.variants]
    findings.extend(manifest.check_sim_projection(
        engine_names, harness.sim_variant_names()))

    built = manifest.build_manifest(per_target)
    if committed_manifest is None:
        # load_manifest returns {} when the file is missing, which
        # check_manifest reports as a STEP002 finding
        findings.extend(manifest.check_manifest(
            per_target, manifest.load_manifest()))
    elif committed_manifest:
        findings.extend(manifest.check_manifest(per_target,
                                                committed_manifest))
    # committed_manifest == {} passed explicitly: skip the ratchet

    findings.extend(bounds.run_bounds_lattice())

    findings.sort(key=lambda f: (f.path, f.rule, f.symbol))
    return RunResult(findings=findings, per_target=per_target,
                     manifest=built, targets_analyzed=len(targets),
                     variants_traced=variants_traced)
