"""The compile-count manifest: STEP001 (bound) and STEP002 (ratchet).

``tools/stepcheck/manifest.json`` commits, per cache-off engine target,
every reachable step variant's traced shape signature. The check is a
ratchet in both directions: a traced variant missing from the manifest
(new shape → silent retrace risk) and a manifest entry no longer traced
(stale manifest) are both findings. ``--write-manifest`` regenerates the
file after an intentional change — the diff is then reviewed like any
code.

STEP001 is the bound itself, independent of the committed file:

  * variants per target == 1 + len(buckets) × len(lane_configs), with
    the mixed names exactly the bucket × lane-config product;
  * cache-on twins trace to bit-identical signatures (the prefix cache
    is admission plumbing and must never add a compiled shape);
  * the simulator's enumeration is a projection (subset) of the real
    engine's.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Sequence, Tuple

from tools.reprolint.framework import Finding, repo_root

from .tracing import variant_signature

MANIFEST_PATH = repo_root() / "tools" / "stepcheck" / "manifest.json"


def signatures_for(target, traced) -> Dict[str, dict]:
    """variant name -> signature record for one target."""
    out: Dict[str, dict] = {}
    for variant, closed in traced:
        digest, in_avals, out_avals = variant_signature(closed)
        out[variant.name] = {
            "sig": digest,
            "lane_buckets": list(variant.lane_buckets),
            "num_in": len(in_avals),
            "out": out_avals,
        }
    return out


def build_manifest(per_target: Dict[str, Dict[str, dict]]) -> dict:
    some = next(iter(per_target.values()))
    return {
        "_doc": ("stepcheck compile-count manifest — traced shape "
                 "signatures of every reachable Engine._step_fn variant. "
                 "Regenerate with `python -m tools.stepcheck "
                 "--write-manifest` and review the diff; an unreviewed "
                 "signature change is exactly the silent retrace this "
                 "file exists to catch."),
        "variants_per_target": len(some),
        "targets": per_target,
    }


def load_manifest(path: Path = MANIFEST_PATH) -> dict:
    if not path.exists():
        return {}
    return json.loads(path.read_text(encoding="utf-8"))


def write_manifest(manifest: dict, path: Path = MANIFEST_PATH) -> None:
    path.write_text(json.dumps(manifest, indent=2, sort_keys=True) + "\n",
                    encoding="utf-8")


def check_bound(target, traced) -> List[Finding]:
    """STEP001 for one engine target: count and name-set of variants."""
    findings: List[Finding] = []
    engine = target.engine
    buckets = engine._buckets
    lanes = engine._lane_configs
    expected = {"decode"} | {f"mixed:b{b}xl{n}"
                             for b in buckets for n in lanes}
    actual = {v.name for (v, _c) in traced}
    bound = 1 + len(buckets) * len(lanes)
    if len(traced) != bound or actual != expected:
        missing = sorted(expected - actual)
        extra = sorted(actual - expected)
        findings.append(Finding(
            path=target.name, line=0, rule="STEP001", symbol="variants",
            message=(f"step_variants() enumerates {len(traced)} shapes, "
                     f"bound is {bound} = 1 + {len(buckets)} buckets × "
                     f"{len(lanes)} lane-configs"
                     + (f"; missing {missing}" if missing else "")
                     + (f"; extra {extra}" if extra else ""))))
    return findings


def check_cache_invariance(off_sigs: Dict[str, dict],
                           on_sigs: Dict[str, dict],
                           on_name: str) -> List[Finding]:
    """STEP001: the prefix cache must not change any traced signature."""
    findings: List[Finding] = []
    for name in sorted(set(off_sigs) | set(on_sigs)):
        off = off_sigs.get(name, {}).get("sig")
        on = on_sigs.get(name, {}).get("sig")
        if off != on:
            findings.append(Finding(
                path=on_name, line=0, rule="STEP001", symbol=name,
                message=(f"variant `{name}` signature differs from the "
                         f"cache-off twin ({on} != {off}) — the prefix "
                         "cache is admission plumbing and must not add "
                         "compiled shapes")))
    return findings


def check_sim_projection(engine_names: Sequence[str],
                         sim_names: Sequence[str]) -> List[Finding]:
    """STEP001: SimEngine's enumeration ⊆ the real engine's."""
    extra = sorted(set(sim_names) - set(engine_names))
    if not extra:
        return []
    return [Finding(
        path="simulator", line=0, rule="STEP001", symbol="step_variants",
        message=(f"SimEngine.step_variants() declares shapes the engine "
                 f"does not: {extra} — the simulator drifted from the "
                 "engine contract"))]


def check_manifest(per_target: Dict[str, Dict[str, dict]],
                   manifest: dict) -> List[Finding]:
    """STEP002: ratchet traced signatures against the committed file."""
    findings: List[Finding] = []
    if not manifest:
        findings.append(Finding(
            path="manifest", line=0, rule="STEP002", symbol="<missing>",
            message=("tools/stepcheck/manifest.json is missing — run "
                     "`python -m tools.stepcheck --write-manifest` and "
                     "commit it")))
        return findings
    recorded: Dict[str, Dict[str, dict]] = manifest.get("targets", {})
    for tname in sorted(set(per_target) | set(recorded)):
        traced = per_target.get(tname, {})
        known = recorded.get(tname, {})
        for vname in sorted(set(traced) | set(known)):
            have = traced.get(vname)
            want = known.get(vname)
            key = f"{tname}/{vname}"
            if want is None:
                findings.append(Finding(
                    path=tname, line=0, rule="STEP002", symbol=vname,
                    message=(f"variant `{vname}` traced but absent from "
                             "the manifest — a new compiled shape; "
                             "review and --write-manifest")))
            elif have is None:
                findings.append(Finding(
                    path=tname, line=0, rule="STEP002", symbol=vname,
                    message=(f"manifest lists `{vname}` but it is no "
                             "longer reachable — stale manifest; "
                             "--write-manifest")))
            elif have["sig"] != want["sig"]:
                findings.append(Finding(
                    path=tname, line=0, rule="STEP002", symbol=vname,
                    message=(f"variant `{vname}` signature changed "
                             f"({want['sig']} -> {have['sig']}) — the "
                             "step now traces different shapes/dtypes "
                             f"(out: {want.get('out')} -> "
                             f"{have.get('out')}); review and "
                             "--write-manifest")))
    return findings


def manifest_diff(per_target: Dict[str, Dict[str, dict]],
                  manifest: dict) -> List[str]:
    """Human-readable diff lines for the CI artifact."""
    return [f.render() for f in check_manifest(per_target, manifest)]
