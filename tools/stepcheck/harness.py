"""Engine targets stepcheck traces — tiny configs, abstract params.

One target per (model family × prefix-cache setting). Params are
``jax.eval_shape`` results, never real arrays: constructing an
``Engine`` only *stores* params, so the whole harness runs without
materializing a single weight, and ``jax.make_jaxpr`` over
``Engine._step_fn`` stays pure CPU tracing.

Models are built in bfloat16 deliberately: every silent fp32 upcast in
the step program becomes a visible ``convert_element_type`` for the
STEP005 dtype audit (an fp32 model would hide them all).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.models import Model, ModelConfig
from repro.serving import Engine, EngineConfig, StepVariant
from repro.serving.simulator import SimEngine, SimEngineConfig, SimWorkload

#: the three assigned architecture families, smoke-sized (2 layers keeps
#: tracing sub-second; heads/kv-heads exercise GQA in the paged kernels)
FAMILY_CONFIGS: Dict[str, dict] = {
    "dense": dict(d_ff=128),
    "ssm": dict(ssm_state=16, ssm_head_dim=32, ssm_chunk=8, d_ff=0),
    "hybrid": dict(ssm_state=16, ssm_head_dim=32, ssm_chunk=8, d_ff=128),
}

#: engine geometry shared by every target: two buckets (4, 8) × two lane
#: configs (1, 2) under a 16-token budget -> 1 + 2×2 = 5 variants each
ENGINE_KW = dict(page_size=4, num_pages=64, max_slots=4,
                 max_pages_per_branch=12, prefill_chunk=8,
                 step_token_budget=16)


@dataclasses.dataclass
class EngineTarget:
    """One engine under analysis plus its enumerated variants."""

    name: str                      # "engine[hybrid]" / "engine[hybrid+cache]"
    family: str
    cache: bool
    engine: Engine
    variants: List[StepVariant]
    tree: bool = False


def model_config(family: str) -> ModelConfig:
    return ModelConfig(name=f"stepcheck-{family}", arch_type=family,
                       num_layers=2, d_model=64, vocab_size=97,
                       num_heads=4, num_kv_heads=2,
                       **FAMILY_CONFIGS[family])


def build_engine(family: str, cache: bool = False,
                 tree: bool = False) -> Engine:
    """An engine with abstract (eval_shape'd) params — no weights exist."""
    model = Model(model_config(family), dtype=jnp.bfloat16)
    params = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    cfg = EngineConfig(prefix_cache=cache,
                       decode_kernel="tree" if tree else "paged",
                       **ENGINE_KW)
    return Engine(model, params, cfg)


def build_targets(include_cache: bool = True) -> List[EngineTarget]:
    """All engine targets, cache-off first (the jaxpr-rule set runs on
    cache-off targets; cache-on twins only pin signature invariance).
    One tree-decode target per attention-bearing family traces the
    ``decode_kernel="tree"`` step so the tree dispatch and its extra
    argument group stay under the jaxpr rules."""
    out: List[EngineTarget] = []
    for cache in ([False, True] if include_cache else [False]):
        for family in FAMILY_CONFIGS:
            eng = build_engine(family, cache)
            suffix = "+cache" if cache else ""
            out.append(EngineTarget(
                name=f"engine[{family}{suffix}]", family=family,
                cache=cache, engine=eng, variants=eng.step_variants()))
    for family in ("dense", "hybrid"):   # "ssm" has no attention: tree
        eng = build_engine(family, cache=False, tree=True)  # is a no-op
        out.append(EngineTarget(
            name=f"engine[{family}+tree]", family=family, cache=False,
            engine=eng, variants=eng.step_variants(), tree=True))
    return out


def trace_variant(engine: Engine, variant: StepVariant):
    """ClosedJaxpr of one step variant — abstract, no device work."""
    fn = functools.partial(engine._step_fn, lane_buckets=variant.lane_buckets)
    return jax.make_jaxpr(fn)(engine.params, engine.state, *variant.args)


def sim_variant_names() -> List[str]:
    """Variant names of a SimEngine matched to ``ENGINE_KW``'s budget and
    chunk — STEP001 asserts these are a projection (subset) of the real
    engine's enumeration."""
    cfg = SimEngineConfig(page_size=4, num_pages=64, max_slots=4,
                          prefill_chunk=8, step_token_budget=16)
    sim = SimEngine(cfg, SimWorkload())
    return [v.name for v in sim.step_variants()]
