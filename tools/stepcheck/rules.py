"""The STEP rule registry: codes, names, one-line summaries.

Unlike reprolint's AST rules, stepcheck analyzers are not independent
plug-ins — they share traced jaxprs and the engine harness — so the
registry is a plain table used by ``--list-rules``, docs and tests.
"""
from __future__ import annotations

from typing import Dict, Tuple

#: code -> (name, summary)
RULES: Dict[str, Tuple[str, str]] = {
    "STEP001": (
        "compile-count-bound",
        "step_variants() must enumerate exactly 1 + buckets × "
        "lane-configs traced shapes per engine target, invariant under "
        "the prefix cache, with the simulator a projection of it"),
    "STEP002": (
        "manifest-ratchet",
        "every traced variant signature must match "
        "tools/stepcheck/manifest.json — a new/changed/missing shape is "
        "a loud diff, not a silent retrace"),
    "STEP003": (
        "single-dispatch",
        "no sub-jit inside the traced step beyond the whitelisted "
        "kernel wrappers and known jax-internal helpers"),
    "STEP004": (
        "host-sync-taint",
        "no callback/infeed/outfeed primitive reachable in the step "
        "program — the one host sync per step lives at the call site"),
    "STEP005": (
        "dtype-promotion",
        "no unaudited small-float → fp32 upcast in the step program "
        "(kernel operands, KV-page writes, hidden-state plumbing)"),
    "STEP006": (
        "dead-surface",
        "no wholly-unused step argument and no pass-through/constant "
        "step output"),
    "STEP007": (
        "index-map-bounds",
        "every Pallas BlockSpec index map, evaluated over its full grid "
        "for a lattice of representative shapes, stays in-bounds"),
}
