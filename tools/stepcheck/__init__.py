"""stepcheck: trace-level semantic verifier for the serving step.

reprolint (``tools/reprolint``) checks the *syntactic shadows* of the
serving stack's compiled-program invariants; stepcheck checks the traced
artifacts themselves, on CPU, with no device execution:

  * **STEP001 / STEP002** — the compile-count manifest: every reachable
    ``Engine._step_fn`` variant (family × bucket × lane-config, cache
    on/off) is enumerated via ``Engine.step_variants()`` and traced with
    ``jax.make_jaxpr`` on ``ShapeDtypeStruct``s; the count must equal the
    documented O(buckets × lane-configs) bound and the traced shape
    signatures ratchet against ``tools/stepcheck/manifest.json``.
  * **STEP003–STEP006** — jaxpr walkers: single-dispatch proof (no
    sub-jit beyond the whitelisted kernel wrappers and known jnp
    internals), host-sync taint (no callback primitives), dtype-promotion
    audit (silent fp32 upcasts), dead-surface detection (unused
    arguments, pass-through outputs).
  * **STEP007** — the Pallas index-map bounds verifier: each kernel's
    ``KernelGrid`` (``repro.kernels.introspect``) is evaluated concretely
    over its entire grid for a lattice of representative shapes, proving
    every block access in-bounds given the OOB-sentinel clamps.

CLI (mirrors reprolint's conventions — findings render as
``target · STEP0xx · message``, committed baseline with justification
comments, exit 1 only on findings not in the baseline):

    python -m tools.stepcheck                # full run
    python -m tools.stepcheck --json
    python -m tools.stepcheck --write-manifest
    python -m tools.stepcheck --self-test    # seeded-violation negative test

See docs/analysis.md ("stepcheck: trace-level rules") for the rule
catalog and the manifest/ratchet workflow.
"""
from __future__ import annotations

import sys

from tools.reprolint.framework import repo_root as _repo_root

# stepcheck imports the repro package (it traces the real engine); make
# ``src`` importable when invoked as ``python -m tools.stepcheck`` from
# the repo root without PYTHONPATH.
_SRC = str(_repo_root() / "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

from .rules import RULES  # noqa: E402  (needs _SRC on sys.path)

__all__ = ["RULES"]
