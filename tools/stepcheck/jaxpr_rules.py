"""jaxpr walkers over the traced step: STEP003–STEP006.

Each analyzer takes one engine target plus its traced variants
(``[(StepVariant, ClosedJaxpr), ...]``) and yields ``Finding``s with
``path`` = the target name and ``line`` = 0 (trace findings have no
source line; the symbol carries the site). Findings are deduplicated
across variants of a target — the baseline key is
``target::STEPxxx::site`` and must not churn when a bucket is added.
"""
from __future__ import annotations

from typing import Dict, Iterator, List, Set, Tuple

from tools.reprolint.framework import Finding

from .tracing import (float_width, is_float_dtype, iter_eqns, leaf_groups,
                      param_leaf_paths, source_symbol)

#: sub-jit names allowed inside the step program. Two classes:
#:   * the repo's jit'd kernel wrappers — the whitelisted Pallas/ref
#:     dispatch points of the mixed step;
#:   * jax-internal helper jits that jnp/jax.nn emit under a pjit of
#:     their own name (they inline at lowering; listed so anything NEW
#:     — e.g. a separately-jitted repo function sneaking into the step —
#:     is a loud finding to review, not a silent extra dispatch).
ALLOWED_SUB_JITS: Set[str] = {
    # repo kernel wrappers (src/repro/kernels/*/ops.py)
    "paged_attention", "paged_tree_attention", "paged_flash_prefill",
    "flash_attention", "ssd",
    # jax internals observed in the traced step across all families
    "_take", "take_along_axis", "_where", "_one_hot", "_pad",
    "floor_divide", "remainder", "clip",
    "silu", "softplus", "gelu", "relu", "sigmoid", "cumsum", "tril",
    "sort", "_gumbel", "_uniform", "_threefry_split", "fold_in",
    "_softmax", "logsumexp", "top_k", "isnan", "nan_to_num",
}

#: primitives that force host interaction — none may appear in the step
#: program (REP005's one-sync-per-step contract, made semantic)
_HOST_SYNC_FRAGMENTS = ("callback", "infeed", "outfeed", "host_local")

#: dispatch-bearing primitives: a sub-computation the XLA program calls
#: out to. ``pjit`` carries a name we check against the whitelist.
_DISPATCH_PRIMS = ("pjit", "custom_call", "pallas_call")


def check_single_dispatch(target, traced) -> Iterator[Finding]:
    """STEP003: every dispatch-bearing primitive in the step jaxpr must
    be whitelisted. A new sub-jit name means someone routed part of the
    step through a separately-jitted callable — review it (it may be
    legitimate, like a new kernel wrapper) and extend the whitelist or
    the baseline deliberately."""
    seen: Dict[str, Set[str]] = {}
    for variant, closed in traced:
        for eqn in iter_eqns(closed.jaxpr):
            prim = eqn.primitive.name
            if prim not in _DISPATCH_PRIMS:
                continue
            name = str(eqn.params.get("name", f"<{prim}>"))
            if prim == "pjit" and name in ALLOWED_SUB_JITS:
                continue
            seen.setdefault(f"{prim}:{name}", set()).add(variant.name)
    for site, variants in sorted(seen.items()):
        yield Finding(
            path=target.name, line=0, rule="STEP003", symbol=site,
            message=(f"non-whitelisted sub-dispatch `{site}` inside the "
                     f"step program (variants: "
                     f"{', '.join(sorted(variants))}) — the mixed step "
                     "must stay one device dispatch"))


def check_host_sync(target, traced) -> Iterator[Finding]:
    """STEP004: no callback/infeed/outfeed primitive anywhere in the
    step program — the single mandated host sync per decode step lives
    at the call site (``decode_step``'s token readback), never inside
    the compiled step."""
    seen: Dict[str, Set[str]] = {}
    for variant, closed in traced:
        for eqn in iter_eqns(closed.jaxpr):
            prim = eqn.primitive.name
            if any(frag in prim for frag in _HOST_SYNC_FRAGMENTS):
                site = f"{prim}@{source_symbol(eqn)}"
                seen.setdefault(site, set()).add(variant.name)
    for site, variants in sorted(seen.items()):
        yield Finding(
            path=target.name, line=0, rule="STEP004", symbol=site,
            message=(f"host-sync primitive `{site}` reachable in the "
                     f"step program (variants: "
                     f"{', '.join(sorted(variants))}) — blocks dispatch "
                     "pipelining on every step"))


def check_dtype_promotion(target, traced) -> Iterator[Finding]:
    """STEP005: flag every small-float → wider-float
    ``convert_element_type`` in the step program, attributed to the repo
    source site that emitted it. The harness traces bf16 models, so each
    silent fp32 upcast — on kernel operands, KV-page writes, or
    hidden-state plumbing — is visible. Load-bearing upcasts (fp32
    softmax accumulation, RMSNorm statistics) are baselined with
    justifications; anything new must be triaged, not shipped."""
    seen: Dict[Tuple[str, str], Set[str]] = {}
    for variant, closed in traced:
        for eqn in iter_eqns(closed.jaxpr):
            if eqn.primitive.name != "convert_element_type":
                continue
            old = eqn.invars[0].aval.dtype
            new = eqn.params["new_dtype"]
            if not (is_float_dtype(old) and is_float_dtype(new)):
                continue
            if float_width(new) <= float_width(old):
                continue
            site = source_symbol(eqn)
            seen.setdefault((site, f"{old}->{new}"), set()).add(variant.name)
    for (site, widen), variants in sorted(seen.items()):
        yield Finding(
            path=target.name, line=0, rule="STEP005", symbol=site,
            message=(f"silent {widen} upcast at {site} (variants: "
                     f"{', '.join(sorted(variants))}) — justify in the "
                     "baseline or compute in the narrow dtype"))


def check_dead_surface(target, traced) -> Iterator[Finding]:
    """STEP006: dead inputs and dead outputs of the step program.

    * an *argument group* (a whole top-level ``_step_fn`` parameter —
      every flat leaf of it) that no equation and no output consumes is
      dead weight on the dispatch;
    * individual ``params`` leaves nothing consumes indicate a model
      surface the step silently ignores;
    * an output that is a compile-time literal or an unmodified alias of
      an input is a pass-through the caller could read directly.

    Zero-size leaves (e.g. the decode variant's ``(0,)`` chunk_lens) are
    vacuously live and skipped.
    """
    dead_groups: Dict[str, Set[str]] = {}
    dead_params: Dict[str, Set[str]] = {}
    passthrough: Dict[str, Set[str]] = {}
    for variant, closed in traced:
        jaxpr = closed.jaxpr
        used = set()
        for eqn in jaxpr.eqns:
            used.update(id(v) for v in eqn.invars)
        used.update(id(v) for v in jaxpr.outvars)
        invars = jaxpr.invars
        groups = leaf_groups(target.engine, variant)
        assert sum(n for _, n in groups) == len(invars), \
            (target.name, variant.name, groups, len(invars))
        pos = 0
        for name, count in groups:
            leaves = invars[pos:pos + count]
            pos += count
            live = [v for v in leaves
                    if 0 not in getattr(v.aval, "shape", ())]
            if not live:
                continue
            if all(id(v) not in used for v in live):
                dead_groups.setdefault(name, set()).add(variant.name)
            elif name == "params":
                paths = param_leaf_paths(target.engine.params)
                for path, v in zip(paths, leaves):
                    if 0 in getattr(v.aval, "shape", ()):
                        continue
                    if id(v) not in used:
                        dead_params.setdefault(path, set()).add(variant.name)
        invar_ids = {id(v) for v in invars}
        for i, out in enumerate(jaxpr.outvars):
            if hasattr(out, "val"):             # jax.core.Literal output
                passthrough.setdefault(f"out[{i}]=const", set()).add(
                    variant.name)
            elif id(out) in invar_ids:
                passthrough.setdefault(f"out[{i}]=input", set()).add(
                    variant.name)
    for name, variants in sorted(dead_groups.items()):
        yield Finding(
            path=target.name, line=0, rule="STEP006", symbol=name,
            message=(f"step argument `{name}` is dead in variants "
                     f"{', '.join(sorted(variants))} — transferred every "
                     "dispatch, never read"))
    for path, variants in sorted(dead_params.items()):
        yield Finding(
            path=target.name, line=0, rule="STEP006",
            symbol=f"params{path}",
            message=(f"params leaf `{path}` is never read by the step "
                     f"(variants: {', '.join(sorted(variants))})"))
    for site, variants in sorted(passthrough.items()):
        yield Finding(
            path=target.name, line=0, rule="STEP006", symbol=site,
            message=(f"step output `{site}` is a pass-through "
                     f"(variants: {', '.join(sorted(variants))}) — the "
                     "caller could read it without a round-trip"))


JAXPR_CHECKS = (check_single_dispatch, check_host_sync,
                check_dtype_promotion, check_dead_surface)


def run_jaxpr_rules(target, traced) -> List[Finding]:
    """All four jaxpr walkers over one target's traced variants."""
    out: List[Finding] = []
    for check in JAXPR_CHECKS:
        out.extend(check(target, traced))
    return out
