"""CLI: ``python -m tools.stepcheck [options]`` — reprolint conventions.

Exit code 1 only for findings not covered by the committed baseline
(``tools/stepcheck/baseline.txt``); ``--write-baseline`` regenerates it
(re-add justification comments by hand), ``--write-manifest``
regenerates the compile-count manifest after an intentional shape
change. ``--self-test`` seeds violations (an un-clamped index map, a
tampered manifest) and exits 0 only if stepcheck catches both — the CI
step that proves the checker itself works.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from tools.reprolint.framework import Baseline, render_json, repo_root

from . import RULES

BASELINE_PATH = repo_root() / "tools" / "stepcheck" / "baseline.txt"


def self_test() -> int:
    """Negative controls: stepcheck must catch seeded violations."""
    import numpy as np

    from repro.kernels import paged_attention_grid
    from repro.kernels.introspect import BlockMapping

    from . import bounds, manifest

    failures = []

    # 1) un-clamp flash-decode's KV index map: the sentinel-table case
    #    must produce a STEP007 out-of-bounds finding
    num_pages, page_size, pps = 16, 4, 5
    kg = paged_attention_grid(3, 4, 8, 2, num_pages, page_size, pps)
    import dataclasses
    unclamped = tuple(
        dataclasses.replace(
            m, index_map=lambda b, h, i, bt, ln: (h, bt[b, i], 0, 0))
        if m.name in ("k_pages", "v_pages") else m
        for m in kg.in_mappings)
    broken = dataclasses.replace(kg, in_mappings=unclamped)
    cases = bounds.paged_attention_cases(num_pages, page_size, pps, 3)
    caught = bounds.verify_kernel_grid(broken, cases)
    if not any(f.rule == "STEP007" for f in caught):
        failures.append("un-clamped index map NOT caught by STEP007")
    if bounds.verify_kernel_grid(kg, cases):
        failures.append("clamped index map wrongly flagged by STEP007")

    # 2) tamper a manifest signature: the ratchet must flag the change,
    #    an off-manifest variant, and a stale entry
    per_target = {"engine[t]": {"decode": {"sig": "aaaa", "out": []},
                                "mixed:b8xl1": {"sig": "bbbb", "out": []}}}
    tampered = {"targets": {"engine[t]": {
        "decode": {"sig": "XXXX", "out": []},
        "mixed:b8xl2": {"sig": "cccc", "out": []}}}}
    flagged = manifest.check_manifest(per_target, tampered)
    symbols = {(f.rule, f.symbol) for f in flagged}
    for want in [("STEP002", "decode"), ("STEP002", "mixed:b8xl1"),
                 ("STEP002", "mixed:b8xl2")]:
        if want not in symbols:
            failures.append(f"manifest tampering NOT caught: {want}")

    if failures:
        for msg in failures:
            print(f"self-test FAILED: {msg}")
        return 1
    print("self-test OK: seeded violations caught "
          f"({len(caught)} bounds finding(s), "
          f"{len(flagged)} manifest finding(s))")
    return 0


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m tools.stepcheck",
        description="trace-level semantic verifier for the serving step")
    parser.add_argument("--json", action="store_true",
                        help="machine-readable findings (CI artifact)")
    parser.add_argument("--baseline", type=Path, default=BASELINE_PATH,
                        help="baseline file (default: committed)")
    parser.add_argument("--no-baseline", action="store_true",
                        help="report every finding as new")
    parser.add_argument("--write-baseline", action="store_true",
                        help="regenerate the baseline from this run")
    parser.add_argument("--manifest", type=Path, default=None,
                        help="manifest file (default: committed)")
    parser.add_argument("--write-manifest", action="store_true",
                        help="regenerate tools/stepcheck/manifest.json")
    parser.add_argument("--list-rules", action="store_true")
    parser.add_argument("--self-test", action="store_true",
                        help="seed violations; exit 0 iff caught")
    args = parser.parse_args(argv)

    if args.list_rules:
        for code, (name, summary) in sorted(RULES.items()):
            print(f"{code}  {name}: {summary}")
        return 0
    if args.self_test:
        return self_test()

    from . import manifest as manifest_mod
    from .runner import run_all

    committed = None
    if args.manifest is not None:
        committed = manifest_mod.load_manifest(args.manifest)
    result = run_all(committed_manifest=committed)

    if args.write_manifest:
        path = args.manifest or manifest_mod.MANIFEST_PATH
        manifest_mod.write_manifest(result.manifest, path)
        print(f"wrote {path}")
        # findings computed against the stale manifest no longer apply
        result.findings = [f for f in result.findings
                           if f.rule != "STEP002"]

    baseline = (Baseline() if args.no_baseline
                else Baseline.load(args.baseline))
    old, new = baseline.partition(result.findings)

    if args.write_baseline:
        args.baseline.write_text(
            Baseline.render(result.findings).replace(
                "# reprolint baseline", "# stepcheck baseline"),
            encoding="utf-8")
        print(f"wrote {args.baseline} ({len(result.findings)} entries)")
        return 0

    if args.json:
        print(render_json(result.findings, new))
    else:
        new_ids = {id(f) for f in new}
        for f in result.findings:
            marker = "" if id(f) in new_ids else " [baselined]"
            print(f.render() + marker)
        print(f"stepcheck: {len(result.findings)} finding(s) "
              f"({len(old)} baselined, {len(new)} new) over "
              f"{result.targets_analyzed} engine target(s), "
              f"{result.variants_traced} traced variant(s)")
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
