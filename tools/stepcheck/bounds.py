"""STEP007: concrete bounds proof for Pallas BlockSpec index maps.

Each kernel exports its launch geometry as a ``KernelGrid``
(``repro.kernels.introspect``) whose index maps are the exact callables
handed to Pallas. This module evaluates every index map at every grid
point — with concrete integers and numpy scalar-prefetch arrays — and
checks the block containment invariant for each operand dimension ``d``:

    0 <= idx[d] * block[d]  and  idx[d] * block[d] + block[d] <= array[d]

over a lattice of representative shapes: ragged lengths,
page-straddling resumed chunks, sentinel-laden block tables, GQA / MQA /
MHA head counts. REP003's syntactic clamp check made semantic: an
un-clamped sentinel chase fails here on the exact grid point that would
address HBM out of bounds on TPU (the negative self-test seeds one).

``verify_kernel_grid`` is a reusable harness — tests feed it deliberately
broken grids; the engine lattice below is what ``python -m
tools.stepcheck`` runs.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from tools.reprolint.framework import Finding


@dataclasses.dataclass(frozen=True)
class ScalarCase:
    """One scalar-prefetch configuration to sweep a grid under."""

    name: str
    args: Tuple[object, ...] = ()


def verify_kernel_grid(kg, cases: Sequence[ScalarCase] = (ScalarCase("-"),),
                       max_findings_per_mapping: int = 3) -> List[Finding]:
    """Evaluate every index map of ``kg`` over the full grid × cases.

    Returns one STEP007 finding per violating (mapping, case), capped at
    ``max_findings_per_mapping`` grid points each (one out-of-bounds
    access is already a proof failure; thousands are noise).
    """
    findings: List[Finding] = []
    for mapping in kg.mappings:
        reported = 0
        for case in cases:
            for point in itertools.product(*(range(g) for g in kg.grid)):
                if reported >= max_findings_per_mapping:
                    break
                try:
                    idx = mapping.index_map(*point, *case.args)
                except Exception as exc:  # evaluation itself is a failure
                    findings.append(Finding(
                        path=kg.kernel, line=0, rule="STEP007",
                        symbol=mapping.name,
                        message=(f"index map of `{mapping.name}` raised "
                                 f"at grid point {point} "
                                 f"(case {case.name}): {exc!r}")))
                    reported += 1
                    continue
                problem = _containment_violation(
                    tuple(int(i) for i in idx), mapping.block_shape,
                    mapping.array_shape)
                if problem is not None:
                    findings.append(Finding(
                        path=kg.kernel, line=0, rule="STEP007",
                        symbol=mapping.name,
                        message=(f"`{mapping.name}` block access out of "
                                 f"bounds at grid point {point} "
                                 f"(case {case.name}): {problem}")))
                    reported += 1
    return findings


def _containment_violation(idx: Tuple[int, ...], block: Tuple[int, ...],
                           array: Tuple[int, ...]) -> Optional[str]:
    if len(idx) != len(block) or len(block) != len(array):
        return (f"rank mismatch: index {idx}, block {block}, "
                f"array {array}")
    for d, (i, b, a) in enumerate(zip(idx, block, array)):
        start = i * b
        if start < 0 or start + b > a:
            return (f"dim {d}: block index {i} covers elements "
                    f"[{start}, {start + b}) of an axis of size {a}")
    return None


def grid_exhaustive_points(kg) -> int:
    """Number of grid points a full sweep visits (tests pin this so the
    lattice cannot silently stop being exhaustive)."""
    points = 1
    for g in kg.grid:
        points *= g
    return points


# --------------------------------------------------------------- lattice
def _bt(pages: Sequence[int], width: int, sentinel: int) -> np.ndarray:
    row = np.full((width,), sentinel, np.int32)
    row[:len(pages)] = np.asarray(pages, np.int32)
    return row


def paged_prefill_cases(num_pages: int, page_size: int,
                        pages_per_seq: int, t: int) -> List[ScalarCase]:
    """(block_table, info=(pos0, valid_len)) lattice for the fused
    prefill kernel: cold full chunks, page-straddling resumed chunks,
    single-token ragged tails, sentinel-heavy tables."""
    live = _bt(range(pages_per_seq), pages_per_seq, num_pages)
    partial = _bt([3, 1, 4], pages_per_seq, num_pages)
    one = _bt([7], pages_per_seq, num_pages)
    info = lambda p0, vl: np.asarray([p0, vl], np.int32)  # noqa: E731
    return [
        ScalarCase("cold-full", (live, info(0, t))),
        # resumed chunk starting mid-page: rows straddle a page boundary
        ScalarCase("straddle", (partial, info(page_size + 1,
                                              min(t, page_size)))),
        ScalarCase("ragged-1", (one, info(0, 1))),
        # deep context: pos0 near the table's token capacity
        ScalarCase("deep", (live, info((pages_per_seq - 2) * page_size,
                                       t))),
        # sentinel chase: the clamped horizon itself lands on a sentinel
        # entry — only the num_pages-1 clamp keeps the fetch in-bounds
        ScalarCase("all-sentinel", (_bt([], pages_per_seq, num_pages),
                                    info(0, 1))),
    ]


def paged_attention_cases(num_pages: int, page_size: int,
                          pages_per_seq: int,
                          batch: int) -> List[ScalarCase]:
    """(block_tables, lengths) lattice for flash-decode: ragged lengths
    (incl. an empty slot), sentinel-padded and all-sentinel tables."""
    tables = np.stack([
        _bt(range(pages_per_seq), pages_per_seq, num_pages),   # full
        _bt([5, 2], pages_per_seq, num_pages),                 # short
        _bt([], pages_per_seq, num_pages),                     # empty slot
    ][:batch])
    lengths = np.asarray(
        [pages_per_seq * page_size, page_size + 3, 0][:batch], np.int32)
    return [ScalarCase("ragged", (tables, lengths))]


def tree_shared_cases(num_pages: int, page_size: int, pages_per_seq: int,
                      num_groups: int) -> List[ScalarCase]:
    """(shared_bt, shared_lens) lattice for the tree shared-ancestor
    pass: live groups with ragged shared depths, a zero-span group, and
    a fully sentinel (no fork groups this step) table."""
    live = np.stack([
        _bt(range(3), pages_per_seq, num_pages),       # 3 shared pages
        _bt([9], pages_per_seq, num_pages),            # 1 shared page
        _bt([], pages_per_seq, num_pages),             # unused group
    ][:num_groups])
    lens = np.asarray([3 * page_size, page_size, 0][:num_groups],
                      np.int32)
    empty = np.stack([_bt([], pages_per_seq, num_pages)] * num_groups)
    return [
        ScalarCase("ragged-depths", (live, lens)),
        # all-sentinel, zero spans: every iteration parks on entry 0 and
        # clamps the sentinel — the degenerate no-groups step
        ScalarCase("all-sentinel", (empty,
                                    np.zeros((num_groups,), np.int32))),
    ]


def tree_branch_cases(num_pages: int, page_size: int, pages_per_seq: int,
                      batch: int) -> List[ScalarCase]:
    """(branch_bt, branch_lens) lattice for the tree suffix pass:
    ragged suffixes incl. a row fully covered by the shared pass (span
    0, all-sentinel suffix table)."""
    tables = np.stack([
        _bt(range(pages_per_seq), pages_per_seq, num_pages),   # full
        _bt([11, 6], pages_per_seq, num_pages),                # short
        _bt([], pages_per_seq, num_pages),                     # covered
    ][:batch])
    lens = np.asarray(
        [pages_per_seq * page_size, page_size + 2, 0][:batch], np.int32)
    return [ScalarCase("ragged", (tables, lens))]


def engine_lattice() -> List[Tuple[object, List[ScalarCase]]]:
    """The (KernelGrid, scalar cases) pairs ``python -m tools.stepcheck``
    proves in-bounds: all six kernels, swept over GQA (kv < heads), MQA
    (kv = 1) and MHA (kv = heads) head counts plus block-size variations
    that exercise internal padding."""
    from repro.kernels import (flash_prefill_grid, paged_attention_grid,
                               paged_prefill_grid, paged_tree_branch_grid,
                               paged_tree_shared_grid, ssd_scan_grid)

    out: List[Tuple[object, List[ScalarCase]]] = []
    num_pages, page_size, pps = 16, 4, 6
    for kv_heads in (1, 2, 4):          # MQA / GQA / MHA over 4 q heads
        for block_q in (4, 128):        # multi-q-block and single-block
            kg = paged_prefill_grid(8, 4, 8, kv_heads, num_pages,
                                    page_size, pps, block_q=block_q)
            out.append((kg, paged_prefill_cases(num_pages, page_size,
                                                pps, 8)))
        kg = paged_attention_grid(3, 4, 8, kv_heads, num_pages,
                                  page_size, pps)
        out.append((kg, paged_attention_cases(num_pages, page_size,
                                              pps, 3)))
        kg = paged_tree_shared_grid(3, 4, 8, kv_heads, num_pages,
                                    page_size, 3, pps)
        out.append((kg, tree_shared_cases(num_pages, page_size, pps, 3)))
        kg = paged_tree_branch_grid(3, 4, 8, kv_heads, num_pages,
                                    page_size, pps)
        out.append((kg, tree_branch_cases(num_pages, page_size, pps, 3)))
        for s in (12, 16):              # 12 exercises internal padding
            kg = flash_prefill_grid(2, s, 4, 8, kv_heads,
                                    block_q=8, block_k=8)
            out.append((kg, [ScalarCase("-")]))
    out.append((ssd_scan_grid(2, 16, 2, 8, 4, 8), [ScalarCase("-")]))
    return out


def run_bounds_lattice() -> List[Finding]:
    """STEP007 over the full engine lattice."""
    findings: List[Finding] = []
    for kg, cases in engine_lattice():
        findings.extend(verify_kernel_grid(kg, cases))
    return findings
