"""jaxpr plumbing shared by the analyzers: recursive equation walks,
source attribution, aval/signature rendering.

Everything here operates on the ``ClosedJaxpr`` objects the harness
produced — pure data, no device, no re-tracing.
"""
from __future__ import annotations

import hashlib
from typing import Iterator, List, Optional, Tuple

import jax
import numpy as np

try:  # jax internal, stable across the 0.4.x line this repo pins
    from jax._src import source_info_util as _siu
except Exception:  # pragma: no cover - defensive: attribution degrades
    _siu = None


def iter_eqns(jaxpr) -> Iterator[object]:
    """Every equation in ``jaxpr`` (a ``Jaxpr``), recursing into the
    sub-jaxprs carried by pjit/scan/cond/while/remat params."""
    for eqn in jaxpr.eqns:
        yield eqn
        for value in eqn.params.values():
            values = value if isinstance(value, (list, tuple)) else [value]
            for sub in values:
                inner = getattr(sub, "jaxpr", None)  # ClosedJaxpr -> Jaxpr
                if inner is not None and hasattr(inner, "eqns"):
                    yield from iter_eqns(inner)
                elif hasattr(sub, "eqns"):           # bare Jaxpr
                    yield from iter_eqns(sub)


def source_symbol(eqn) -> str:
    """``file:function`` of the innermost repo frame that emitted ``eqn``
    (paths shortened to be src-relative), or ``<jax>:fn`` when every
    frame is library code. Line-number-free on purpose: the string is a
    baseline key and must survive unrelated edits."""
    frames = []
    if _siu is not None:
        try:
            frames = list(_siu.user_frames(eqn.source_info))
        except Exception:
            frames = []
    for fr in frames:
        file_name = fr.file_name or ""
        if "/repro/" in file_name or file_name.startswith("repro/"):
            short = (file_name.split("/src/", 1)[-1]
                     if "/src/" in file_name else file_name)
            return f"{short}:{fr.function_name}"
    if frames:
        return f"<jax>:{frames[0].function_name}"
    return "<unknown>"


def aval_str(aval) -> str:
    """Canonical short form, e.g. ``f32[4,64]`` / ``bf16[2,8,128]``."""
    dtype = np.dtype(aval.dtype) if hasattr(aval, "dtype") else None
    name = {"float32": "f32", "float64": "f64", "float16": "f16",
            "bfloat16": "bf16", "int32": "i32", "int64": "i64",
            "uint32": "u32", "bool": "b1"}.get(
        str(aval.dtype) if dtype is not None else "?",
        str(getattr(aval, "dtype", "?")))
    shape = ",".join(str(d) for d in getattr(aval, "shape", ()))
    return f"{name}[{shape}]"


def variant_signature(closed_jaxpr) -> Tuple[str, List[str], List[str]]:
    """(sha256-16 digest, in-aval strings, out-aval strings) of a traced
    variant. The digest covers the full in/out aval lists — any retrace
    with different shapes or dtypes changes it."""
    in_avals = [aval_str(a) for a in closed_jaxpr.in_avals]
    out_avals = [aval_str(a) for a in closed_jaxpr.out_avals]
    payload = "|".join(in_avals) + "->" + "|".join(out_avals)
    digest = hashlib.sha256(payload.encode("utf-8")).hexdigest()[:16]
    return digest, in_avals, out_avals


def is_float_dtype(dtype) -> bool:
    """True for any floating dtype including the ml_dtypes extended ones
    (``np.issubdtype`` does not recognize bfloat16)."""
    return jax.numpy.issubdtype(dtype, jax.numpy.floating)


def float_width(dtype) -> int:
    return np.dtype(dtype).itemsize


def leaf_groups(engine, variant) -> List[Tuple[str, int]]:
    """(top-level argument name, number of flat leaves) in the exact
    order ``jax.make_jaxpr`` flattens ``(params, state, *variant.args)``
    — used to map jaxpr invars back to step arguments."""
    names = ["params", "state", "tokens", "positions", "block_tables",
             "lengths", "rng", "chunk_state", "chunk_lens", "slot_valid",
             "cow_src", "cow_dst", "tree"]
    values = (engine.params, engine.state) + tuple(variant.args)
    assert len(names) == len(values), (len(names), len(values))
    return [(name, len(jax.tree_util.tree_leaves(value)))
            for name, value in zip(names, values)]


def param_leaf_paths(params) -> List[str]:
    """Human-readable path per flat params leaf (for STEP006 messages)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    return [jax.tree_util.keystr(path) for path, _leaf in flat]
