"""reprolint — repo-invariant static analysis for the SART serving stack.

Run ``python -m tools.reprolint src/ tests/`` from the repo root. See
docs/analysis.md for the rule catalog (REP001-REP006), the suppression
and baseline workflow, and how to add a rule.
"""
from .framework import (Baseline, DEFAULT_EXCLUDES, FileContext, Finding,
                        ProjectContext, REGISTRY, Rule, all_rules,
                        register, repo_root, run_paths)

__all__ = ["Baseline", "DEFAULT_EXCLUDES", "FileContext", "Finding",
           "ProjectContext", "REGISTRY", "Rule", "all_rules", "register",
           "repo_root", "run_paths"]
