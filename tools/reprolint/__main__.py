"""CLI: ``python -m tools.reprolint [paths...] [--json] ...``.

Exit status: 0 when every finding is grandfathered in the baseline
(or there are none), 1 when new findings exist, 2 on usage errors.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from .framework import (Baseline, DEFAULT_EXCLUDES, all_rules, changed_files,
                        render_json, repo_root, run_paths)

DEFAULT_BASELINE = Path(__file__).resolve().parent / "baseline.txt"


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m tools.reprolint",
        description="repo-invariant static analysis (see docs/analysis.md)")
    p.add_argument("paths", nargs="*", default=["src", "tests"],
                   help="files or directories to lint (default: src tests)")
    p.add_argument("--json", action="store_true", dest="as_json",
                   help="machine-readable output (all findings + new count)")
    p.add_argument("--baseline", type=Path, default=DEFAULT_BASELINE,
                   help="baseline file of grandfathered findings")
    p.add_argument("--no-baseline", action="store_true",
                   help="ignore the baseline: every finding fails")
    p.add_argument("--write-baseline", action="store_true",
                   help="rewrite the baseline from current findings "
                        "(justifications must then be filled in by hand)")
    p.add_argument("--changed-only", metavar="REF", default=None,
                   help="lint only files changed vs the given git ref "
                        "(plus untracked files) — fast pre-push loop; "
                        "the baseline still applies as usual")
    p.add_argument("--list-rules", action="store_true",
                   help="print the rule catalog and exit")
    p.add_argument("--no-default-excludes", action="store_true",
                   help="also lint paths matching the default excludes "
                        "(e.g. tests/reprolint_fixtures — used by the "
                        "fixture tests themselves)")
    return p


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    if args.list_rules:
        for rule in all_rules():
            scope = ",".join(rule.path_filter) or "all files"
            print(f"{rule.code}  {rule.name:22s} [{scope}]  {rule.summary}")
        return 0
    excludes = () if args.no_default_excludes else DEFAULT_EXCLUDES
    only = None
    if args.changed_only is not None:
        try:
            only = changed_files(args.changed_only)
        except RuntimeError as exc:
            print(f"reprolint: {exc}", file=sys.stderr)
            return 2
    findings = run_paths(args.paths, excludes=excludes, only=only)
    if args.write_baseline:
        args.baseline.write_text(Baseline.render(findings),
                                 encoding="utf-8")
        print(f"reprolint: wrote {len(findings)} baseline entries to "
              f"{args.baseline}")
        return 0
    baseline = (Baseline() if args.no_baseline
                else Baseline.load(args.baseline))
    old, new = baseline.partition(findings)
    if args.as_json:
        print(render_json(findings, new))
    else:
        for f in new:
            print(f.render())
        root = repo_root()
        print(f"reprolint: {len(findings)} finding(s) "
              f"({len(old)} baselined, {len(new)} new) over "
              f"{len(args.paths)} path(s) [root {root.name}]")
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
