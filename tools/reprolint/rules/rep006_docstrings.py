"""REP006 — contract docstrings on the public serving surface.

``docs/architecture.md`` deep-links into ``kv/``, ``core/`` and
``serving/`` docstrings for the load-bearing contracts (harvested
ownership, refcount conservation, decref-to-LRU, slot_valid freezing).
A public function without a docstring there is an undocumented
contract: the next PR can't know what it may rely on. The rule flags
public (non-underscore) functions and methods in those packages that
have no docstring. Nested helper defs are exempt — they are
implementation detail of their enclosing function.
"""
from __future__ import annotations

import ast
from typing import Iterator

from ..framework import (FileContext, Finding, ProjectContext, Rule,
                         register)


@register
class ContractDocstringRule(Rule):
    code = "REP006"
    name = "contract-docstrings"
    summary = ("public functions in kv/, core/, serving/ must state "
               "their contract in a docstring")
    path_filter = ("src/repro/kv", "src/repro/core", "src/repro/serving")

    def check(self, ctx: FileContext,
              project: ProjectContext) -> Iterator[Finding]:
        for fn in ast.walk(ctx.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if fn.name.startswith("_"):
                continue
            parent = ctx.parent(fn)
            if isinstance(parent, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue  # nested helper
            if ast.get_docstring(fn) is None:
                yield ctx.finding(
                    fn, self.code,
                    f"public function `{ctx.qualname(fn)}` has no "
                    "docstring — state the contract callers may rely on "
                    "(see docs/analysis.md REP006)")
