"""REP001 — jit-retrace hazard.

The serving stack's compile-count contract is O(prefill_buckets x
chunk_lane_configs) traced shapes (``Engine.prefill_compile_count``
pins it). Two call-site patterns silently break that class of contract:

  1. Passing a Python ``list`` display / list comprehension to a
     ``jax.jit``'d callable for a parameter *not* named in
     ``static_argnames``: the list becomes a fresh pytree whose length
     is part of the trace signature, so every distinct length (or a
     ``str``/non-array leaf, which fails at trace time) is a silent
     recompile — exactly the hazard the bucketed chunking work existed
     to remove.
  2. ``jnp.asarray([...])`` / ``jnp.array([...])`` of a freshly built
     Python list inside a ``for``/``while`` body in ``serving/``:
     per-step host->device churn on the engine hot path (build the array
     once outside the loop, or keep it numpy until one batched
     transfer).

The rule resolves jit'd callables *within a module*: ``@jax.jit`` /
``@functools.partial(jax.jit, static_argnames=...)`` decorators and
``name = jax.jit(fn, static_argnames=...)`` assignments (including
``self._step_jit = jax.jit(self._step_fn, ...)`` — call sites match on
the attribute's last name). When the wrapped function's def is in the
same module, positional arguments are mapped to parameter names so
``static_argnames`` entries are honored positionally too.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

from ..framework import (FileContext, Finding, ProjectContext, Rule,
                         dotted_name, register)

_VARYING = (ast.List, ast.ListComp, ast.SetComp, ast.DictComp,
            ast.GeneratorExp)


class _JitTarget:
    def __init__(self, name: str, static: Set[str],
                 params: Optional[List[str]]):
        self.name = name            # bare or attribute last-name
        self.static = static        # static_argnames entries
        self.params = params        # wrapped fn's positional params, if known


def _static_argnames(call: ast.Call) -> Set[str]:
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, str):
                return {v.value}
            if isinstance(v, (ast.Tuple, ast.List)):
                return {e.value for e in v.elts
                        if isinstance(e, ast.Constant)
                        and isinstance(e.value, str)}
    return set()


def _is_jax_jit(node: ast.AST) -> bool:
    return dotted_name(node) in ("jax.jit", "jit")


def _jit_call(node: ast.AST) -> Optional[ast.Call]:
    """The ``jax.jit(...)`` call inside ``node``, unwrapping one level of
    ``functools.partial(jax.jit, ...)``."""
    if not isinstance(node, ast.Call):
        return None
    if _is_jax_jit(node.func):
        return node
    if dotted_name(node.func) in ("functools.partial", "partial") and \
            node.args and _is_jax_jit(node.args[0]):
        return node
    return None


def _fn_defs(tree: ast.Module) -> Dict[str, ast.FunctionDef]:
    """Every function def in the module, by bare name (methods included)."""
    return {n.name: n for n in ast.walk(tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}


def _param_names(fn: ast.FunctionDef) -> List[str]:
    names = [a.arg for a in fn.args.args]
    if names and names[0] in ("self", "cls"):
        names = names[1:]
    return names


def _collect_jit_targets(ctx: FileContext) -> Dict[str, _JitTarget]:
    defs = _fn_defs(ctx.tree)
    targets: Dict[str, _JitTarget] = {}
    # decorated defs
    for fn in defs.values():
        for deco in fn.decorator_list:
            call = _jit_call(deco)
            static: Set[str] = set()
            if call is not None:
                static = _static_argnames(call)
            elif not _is_jax_jit(deco):
                continue
            targets[fn.name] = _JitTarget(fn.name, static, _param_names(fn))
            break
    # name = jax.jit(fn, ...) assignments (incl. self.attr targets)
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Assign) or len(node.targets) != 1:
            continue
        call = node.value if isinstance(node.value, ast.Call) else None
        if call is None or not _is_jax_jit(call.func):
            continue
        target = node.targets[0]
        tname = target.id if isinstance(target, ast.Name) else (
            target.attr if isinstance(target, ast.Attribute) else None)
        if tname is None or not call.args:
            continue
        wrapped = dotted_name(call.args[0]).rsplit(".", 1)[-1]
        params = _param_names(defs[wrapped]) if wrapped in defs else None
        targets[tname] = _JitTarget(tname, _static_argnames(call), params)
    return targets


@register
class JitRetraceRule(Rule):
    code = "REP001"
    name = "jit-retrace"
    summary = ("varying-shape Python literals crossing a jax.jit boundary, "
               "or per-step jnp.asarray(list) churn in serving/ loop bodies")

    def check(self, ctx: FileContext,
              project: ProjectContext) -> Iterator[Finding]:
        targets = _collect_jit_targets(ctx)
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            yield from self._check_jit_call(ctx, node, targets)
            yield from self._check_loop_asarray(ctx, node)

    # -------------------------------------------------- pattern 1: jit args
    def _check_jit_call(self, ctx: FileContext, node: ast.Call,
                        targets: Dict[str, _JitTarget]
                        ) -> Iterator[Finding]:
        callee = dotted_name(node.func).rsplit(".", 1)[-1]
        tgt = targets.get(callee)
        if tgt is None:
            return
        hazards: List[Tuple[str, ast.expr]] = []
        for i, arg in enumerate(node.args):
            pname = (tgt.params[i] if tgt.params and i < len(tgt.params)
                     else f"arg{i}")
            if pname not in tgt.static and self._is_varying(arg):
                hazards.append((pname, arg))
        for kw in node.keywords:
            if kw.arg and kw.arg not in tgt.static and \
                    self._is_varying(kw.value):
                hazards.append((kw.arg, kw.value))
        for pname, arg in hazards:
            yield ctx.finding(
                arg, self.code,
                f"Python list passed to jit'd `{callee}` for non-static "
                f"parameter `{pname}` — each distinct length retraces; "
                "pass an array (or name it in static_argnames)")

    @staticmethod
    def _is_varying(node: ast.expr) -> bool:
        return isinstance(node, _VARYING)

    # -------------------------------- pattern 2: per-step asarray in loops
    def _check_loop_asarray(self, ctx: FileContext,
                            node: ast.Call) -> Iterator[Finding]:
        if "/serving/" not in f"/{ctx.path}":
            return
        if dotted_name(node.func) not in ("jnp.asarray", "jnp.array"):
            return
        if not node.args or not self._is_varying(node.args[0]):
            return
        in_loop = any(isinstance(a, (ast.For, ast.While))
                      for a in ctx.ancestors(node))
        if in_loop:
            yield ctx.finding(
                node, self.code,
                "jnp.asarray of a fresh Python list inside a loop body — "
                "per-iteration host->device transfer on the serving hot "
                "path; hoist the conversion or batch it")
