"""REP005 — host synchronization on the engine hot path.

``Engine.decode_step`` is the serving clock: everything between two
jit'd step dispatches is host-side critical path. Forcing a device
value back to the host there (``np.asarray``, ``.item()``, ``float()``,
``int()``) blocks on the device and serializes dispatch — the class of
regression the single-dispatch-per-step work (PR 5) exists to prevent.
One sync per step is load-bearing (the sampled tokens drive branch
bookkeeping); it carries an inline suppression with its justification.
Everything else should stay on device or ride that one sync.

Detection is a per-function taint walk, scoped to ``serving/``:

  * **sources** — names assigned (incl. tuple unpacking) from a call
    whose callee ends in ``_jit`` or is ``_advance_chunks`` /
    ``decode_step`` (the step dispatchers);
  * **propagation** — subscripts/slices of tainted names stay device
    values;
  * **sinks** — ``np.asarray(t)`` / ``np.array(t)`` / ``float(t)`` /
    ``int(t)`` / ``t.item()`` / ``t.tolist()`` on a tainted value.
"""
from __future__ import annotations

import ast
from typing import Iterator, Set

from ..framework import (FileContext, Finding, ProjectContext, Rule,
                         dotted_name, register)

_STEP_CALLEES = ("_advance_chunks", "decode_step")
_SINK_CALLS = ("np.asarray", "np.array", "numpy.asarray", "numpy.array",
               "float", "int", "bool")
_SINK_METHODS = ("item", "tolist", "block_until_ready")


def _is_step_call(node: ast.expr) -> bool:
    if not isinstance(node, ast.Call):
        return False
    name = dotted_name(node.func).rsplit(".", 1)[-1]
    return name.endswith("_jit") or name in _STEP_CALLEES


def _tainted_names(fn: ast.FunctionDef) -> Set[str]:
    tainted: Set[str] = set()
    for node in ast.walk(fn):
        if not isinstance(node, ast.Assign) or not _is_step_call(node.value):
            continue
        for tgt in node.targets:
            elts = tgt.elts if isinstance(tgt, ast.Tuple) else [tgt]
            for el in elts:
                if isinstance(el, ast.Name):
                    tainted.add(el.id)
    return tainted


def _is_tainted_expr(node: ast.expr, tainted: Set[str]) -> bool:
    if isinstance(node, ast.Name):
        return node.id in tainted
    if isinstance(node, ast.Subscript):
        return _is_tainted_expr(node.value, tainted)
    return False


@register
class HostSyncRule(Rule):
    code = "REP005"
    name = "hot-path-host-sync"
    summary = ("np.asarray/.item()/float() on a jit-step result inside "
               "serving loop bodies — blocks the device between steps")
    path_filter = ("serving/",)

    def check(self, ctx: FileContext,
              project: ProjectContext) -> Iterator[Finding]:
        for fn in ast.walk(ctx.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            tainted = _tainted_names(fn)
            if not tainted:
                continue
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                if ctx.enclosing_function(node) is not fn:
                    continue
                callee = dotted_name(node.func)
                if callee in _SINK_CALLS and node.args and \
                        _is_tainted_expr(node.args[0], tainted):
                    yield ctx.finding(
                        node, self.code,
                        f"`{callee}(...)` forces the jit-step result "
                        f"`{ast.unparse(node.args[0])}` to host inside "
                        f"`{fn.name}` — a device sync on the decode hot "
                        "path; keep it on device or justify with an "
                        "inline suppression")
                elif isinstance(node.func, ast.Attribute) and \
                        node.func.attr in _SINK_METHODS and \
                        _is_tainted_expr(node.func.value, tainted):
                    yield ctx.finding(
                        node, self.code,
                        f"`.{node.func.attr}()` on the jit-step result "
                        f"`{ast.unparse(node.func.value)}` in "
                        f"`{fn.name}` — a device sync on the decode hot "
                        "path")
