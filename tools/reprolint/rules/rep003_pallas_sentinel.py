"""REP003 — Pallas OOB-sentinel and pad-row discipline.

Two bug classes from PR 3, both silent on CPU interpret mode and
catastrophic on TPU:

  1. **Unclamped block-table chase in an index map.** The engine pads
     block tables with the OOB sentinel (``num_pages``); a
     ``BlockSpec`` index map that returns a raw table entry addresses
     HBM out of bounds when the grid visits a sentinel page. The fix
     shape (now in both paged kernels) clamps the chased entry:
     ``jnp.minimum(bt[...], num_pages - 1)``. The rule flags any
     return-tuple element of an index-map callable containing a
     subscript of a parameter that is not wrapped in
     ``jnp.minimum``/``jnp.maximum``/``jnp.clip``.
  2. **Pad path without a validity mask on the output write.** A kernel
     that carries a row-validity scalar (a name matching ``valid``) has
     bucket-pad rows; its ``out*_ref`` store must pass through a
     ``jnp.where`` validity gate or pad rows emit
     ``exp(-inf - -inf) = 1`` mis-normalized residue instead of the
     exact zeros the mixed step's equivalence contract requires.

Scoped to ``kernels/`` sources.
"""
from __future__ import annotations

import ast
from typing import Iterator, List, Optional

from ..framework import (FileContext, Finding, ProjectContext, Rule,
                         dotted_name, register)

_CLAMPS = ("jnp.minimum", "jnp.maximum", "jnp.clip", "min", "max")


def _index_map_callables(ctx: FileContext) -> List[ast.AST]:
    """Callables passed to ``pl.BlockSpec`` (2nd positional arg or
    ``index_map=``) or to the ``BlockMapping`` introspection descriptor
    (4th positional arg or ``index_map=``): lambdas inline, or local defs
    resolved by name."""
    defs = {n.name: n for n in ast.walk(ctx.tree)
            if isinstance(n, ast.FunctionDef)}
    out: List[ast.AST] = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        callee = dotted_name(node.func).rsplit(".", 1)[-1]
        if callee not in ("BlockSpec", "BlockMapping"):
            continue
        cands: List[ast.expr] = []
        if callee == "BlockSpec" and len(node.args) >= 2:
            cands.append(node.args[1])
        if callee == "BlockMapping" and len(node.args) >= 4:
            cands.append(node.args[3])
        cands.extend(kw.value for kw in node.keywords
                     if kw.arg == "index_map")
        for c in cands:
            if isinstance(c, ast.Lambda):
                out.append(c)
            elif isinstance(c, ast.Name) and c.id in defs:
                out.append(defs[c.id])
    return out


def _params_of(fn: ast.AST) -> set:
    args = fn.args  # both Lambda and FunctionDef carry .args
    return {a.arg for a in args.args}


def _return_exprs(fn: ast.AST) -> List[ast.expr]:
    if isinstance(fn, ast.Lambda):
        return [fn.body]
    return [r.value for r in ast.walk(fn)
            if isinstance(r, ast.Return) and r.value is not None]


def _unclamped_subscripts(ctx: FileContext, element: ast.expr,
                          params: set) -> List[ast.Subscript]:
    """Subscripts of an index-map parameter inside ``element`` with no
    enclosing clamp call (within the element)."""
    bad: List[ast.Subscript] = []
    for sub in ast.walk(element):
        if not isinstance(sub, ast.Subscript):
            continue
        if not (isinstance(sub.value, ast.Name)
                and sub.value.id in params):
            continue
        clamped = False
        cur: Optional[ast.AST] = sub
        while cur is not None and cur is not element:
            parent = ctx.parent(cur)
            if isinstance(parent, ast.Call) and \
                    dotted_name(parent.func) in _CLAMPS:
                clamped = True
                break
            cur = parent
        # the element itself may BE the clamp call
        if not clamped and isinstance(element, ast.Call) and \
                dotted_name(element.func) in _CLAMPS:
            clamped = True
        if not clamped:
            bad.append(sub)
    return bad


def _is_kernel_fn(fn: ast.FunctionDef) -> bool:
    return any(a.arg.endswith("_ref") for a in fn.args.args)


def _mentions_validity(fn: ast.FunctionDef) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.Name) and "valid" in node.id.lower():
            return True
        if isinstance(node, ast.arg) and "valid" in node.arg.lower():
            return True
    return False


def _out_stores(fn: ast.FunctionDef) -> List[ast.stmt]:
    """Statements writing an output ref: ``out*_ref[...] = rhs`` or
    ``pl.store(out*_ref, ...)``."""
    stores: List[ast.stmt] = []
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Subscript) and \
                        isinstance(tgt.value, ast.Name) and \
                        tgt.value.id.startswith("out") and \
                        tgt.value.id.endswith("_ref"):
                    stores.append(node)
        elif isinstance(node, ast.Expr) and isinstance(node.value, ast.Call):
            call = node.value
            if dotted_name(call.func).rsplit(".", 1)[-1] == "store" and \
                    call.args and isinstance(call.args[0], ast.Name) and \
                    call.args[0].id.startswith("out"):
                stores.append(node)
    return stores


def _scope_has_validity_where(ctx: FileContext, store: ast.stmt,
                              kernel: ast.FunctionDef) -> bool:
    """A ``jnp.where`` whose condition mentions a validity name, in the
    innermost function enclosing the store (``@pl.when`` epilogues are
    nested defs) — the mask may gate a temp assigned just before the
    store."""
    scope = ctx.enclosing_function(store) or kernel
    for node in ast.walk(scope):
        if isinstance(node, ast.Call) and \
                dotted_name(node.func) in ("jnp.where", "where") and \
                node.args:
            for n in ast.walk(node.args[0]):
                if isinstance(n, ast.Name) and "valid" in n.id.lower():
                    return True
    return False


@register
class PallasSentinelRule(Rule):
    code = "REP003"
    name = "pallas-sentinel"
    summary = ("unclamped block-table entries in Pallas index maps, and "
               "pad-path kernels writing outputs without a validity mask")
    path_filter = ("kernels",)

    def check(self, ctx: FileContext,
              project: ProjectContext) -> Iterator[Finding]:
        seen = set()
        for fn in _index_map_callables(ctx):
            if id(fn) in seen:
                continue
            seen.add(id(fn))
            params = _params_of(fn)
            for ret in _return_exprs(fn):
                elements = (ret.elts if isinstance(ret, ast.Tuple)
                            else [ret])
                for el in elements:
                    for sub in _unclamped_subscripts(ctx, el, params):
                        yield ctx.finding(
                            sub, self.code,
                            "index map returns a block-table entry "
                            f"`{ast.unparse(sub)}` without a clamp — "
                            "sentinel entries address HBM out of bounds "
                            "on TPU; wrap in jnp.minimum(..., "
                            "num_pages - 1)")
        for fn in ast.walk(ctx.tree):
            if not isinstance(fn, ast.FunctionDef) or not _is_kernel_fn(fn):
                continue
            if not _mentions_validity(fn):
                continue
            for store in _out_stores(fn):
                if not _scope_has_validity_where(ctx, store, fn):
                    yield ctx.finding(
                        store, self.code,
                        f"kernel `{fn.name}` has a row-validity pad path "
                        "but this output write is not gated by a "
                        "jnp.where(validity, ...) — pad rows emit "
                        "mis-normalized residue instead of exact zeros")
