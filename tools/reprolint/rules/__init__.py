"""Rule modules register themselves on import (``@register``)."""
from . import (rep001_jit_retrace, rep002_alloc_discipline,  # noqa: F401
               rep003_pallas_sentinel, rep004_queue_identity,
               rep005_host_sync, rep006_docstrings,
               rep007_swallowed_except)
