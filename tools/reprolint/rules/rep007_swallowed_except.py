"""REP007 — swallowed broad exception in the serving failure domain.

The scheduler's failure-domain contract (docs/robustness.md) is that
every fault is *accounted*: re-raised, quarantined against a request,
or routed to the engine-restart path. A ``except Exception:`` / bare
``except:`` handler in ``core/`` or ``serving/`` whose body neither
re-raises nor calls into a recovery path silently deletes a failure
from that accounting — the exact bug class the pre-fix
``Scheduler._admit_one`` had (the admitted request was popped and the
exception dropped it on the floor).

Detection: for each broad handler (bare, ``Exception`` or
``BaseException``, possibly inside a tuple), the handler body must
contain a ``raise`` or a call whose dotted name mentions a recovery
route (``quarantine`` / ``requeue`` / ``restart`` / ``fault``).
Narrow handlers (``except OutOfPagesError:``) are out of scope — they
are part of documented control flow. Intentional swallows carry an
inline ``# reprolint: disable=REP007`` with a justification or a
baseline entry, like every other rule.
"""
from __future__ import annotations

import ast
from typing import Iterator

from ..framework import (FileContext, Finding, ProjectContext, Rule,
                         dotted_name, register)

_BROAD = ("Exception", "BaseException")
_RECOVERY_MARKERS = ("quarantine", "requeue", "restart", "fault")


def _is_broad(handler: ast.ExceptHandler) -> bool:
    if handler.type is None:           # bare `except:`
        return True
    types = (handler.type.elts if isinstance(handler.type, ast.Tuple)
             else [handler.type])
    for t in types:
        if dotted_name(t).rsplit(".", 1)[-1] in _BROAD:
            return True
    return False


def _routes_or_reraises(handler: ast.ExceptHandler) -> bool:
    for node in ast.walk(handler):
        if isinstance(node, ast.Raise):
            return True
        if isinstance(node, ast.Call):
            callee = dotted_name(node.func).lower()
            if any(marker in callee for marker in _RECOVERY_MARKERS):
                return True
    return False


@register
class SwallowedExceptRule(Rule):
    code = "REP007"
    name = "swallowed-broad-except"
    summary = ("bare `except:`/`except Exception:` in core/+serving/ that "
               "neither re-raises nor routes to a recovery path "
               "(quarantine/requeue/restart/fault) — failures vanish from "
               "the failure-domain accounting")
    path_filter = ("core/", "serving/")

    def check(self, ctx: FileContext,
              project: ProjectContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if not _is_broad(node):
                continue
            if _routes_or_reraises(node):
                continue
            caught = ("bare except" if node.type is None
                      else f"except {ast.unparse(node.type)}")
            yield ctx.finding(
                node, self.code,
                f"`{caught}` swallows the failure: the handler neither "
                "re-raises nor routes it to a recovery path "
                "(quarantine/requeue/restart/fault) — every fault in the "
                "serving failure domain must stay accounted "
                "(docs/robustness.md)")
