"""REP002 — allocator discipline on error paths.

PR 2 shipped the ``abort_prefill`` double-decref fix; PR 5's
``PrefixCache.admit`` established the required shape for multi-page
acquisition: *acquire, then grow inside a try whose handler rolls the
acquired references back* (decref leaf-first) before re-raising — so an
``OutOfPagesError`` mid-sequence leaves refcounts conserved
(``PageAllocator.check_invariants``' live/free/LRU partition).

The rule flags functions that acquire page references more than once —
two or more acquiring calls, or one inside a loop/comprehension (a loop
is "many") — where some acquisition after the first is not covered by a
``try`` whose handler releases (``decref``/``release``/``reclaim``).
The first acquisition needs no guard: if *it* raises, nothing was
acquired yet (all-or-nothing primitives like ``extend`` fail before
mutating).

Acquiring calls are attribute calls named ``alloc`` / ``alloc_prefix`` /
``extend`` / ``fork`` / ``incref`` / ``resurrect`` / ``acquire`` /
``admit`` / ``append_token`` whose receiver is allocator-shaped: the
dotted receiver mentions ``alloc`` or ``cache``, or the call is on
``self`` inside a class whose name mentions Allocator/Cache. Scoped to
``src/`` — tests drive failure paths on purpose.

Known limitation (documented in docs/analysis.md): the analysis is
intra-procedural. A guard that lives in the caller (e.g. a capacity
pre-check like ``Engine.pages_needed_for_step``) is invisible — those
findings are baselined with a justification rather than suppressed.
"""
from __future__ import annotations

import ast
from typing import Iterator, List, Tuple

from ..framework import (FileContext, Finding, ProjectContext, Rule,
                         dotted_name, register)

ACQUIRING = ("alloc", "alloc_prefix", "extend", "fork", "incref",
             "resurrect", "acquire", "admit", "append_token")
RELEASING = ("decref", "release", "reclaim", "drop")
_LOOPS = (ast.For, ast.While, ast.ListComp, ast.SetComp, ast.DictComp,
          ast.GeneratorExp, ast.comprehension)


def _receiver_is_allocatorish(ctx: FileContext, call: ast.Call) -> bool:
    if not isinstance(call.func, ast.Attribute):
        return False
    recv = dotted_name(call.func.value).lower()
    if "alloc" in recv or "cache" in recv:
        return True
    if recv == "self":
        for anc in ctx.ancestors(call):
            if isinstance(anc, ast.ClassDef):
                return ("allocator" in anc.name.lower()
                        or "cache" in anc.name.lower())
    return False


def _is_acquiring(ctx: FileContext, node: ast.AST) -> bool:
    return (isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr in ACQUIRING
            and _receiver_is_allocatorish(ctx, node))


def _handler_releases(handler: ast.ExceptHandler) -> bool:
    for node in ast.walk(handler):
        if isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute) and \
                node.func.attr in RELEASING:
            return True
    return False


def _guarded(ctx: FileContext, call: ast.Call,
             fn: ast.FunctionDef) -> bool:
    """True if an enclosing try (within ``fn``) has a handler that rolls
    references back."""
    for anc in ctx.ancestors(call):
        if anc is fn:
            return False
        if isinstance(anc, ast.Try) and any(
                _handler_releases(h) for h in anc.handlers):
            return True
    return False


def _in_loop(ctx: FileContext, call: ast.Call,
             fn: ast.FunctionDef) -> bool:
    for anc in ctx.ancestors(call):
        if anc is fn:
            return False
        if isinstance(anc, _LOOPS):
            return True
    return False


@register
class AllocDisciplineRule(Rule):
    code = "REP002"
    name = "alloc-discipline"
    summary = ("multi-page acquisition without a try/decref rollback — an "
               "OutOfPagesError mid-sequence leaks references")
    path_filter = ("src/",)

    def check(self, ctx: FileContext,
              project: ProjectContext) -> Iterator[Finding]:
        for fn in ast.walk(ctx.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            # only direct statements of THIS function (nested defs are
            # analyzed as their own functions)
            calls: List[Tuple[ast.Call, bool]] = []
            for node in ast.walk(fn):
                if _is_acquiring(ctx, node) and \
                        ctx.enclosing_function(node) is fn:
                    calls.append((node, _in_loop(ctx, node, fn)))
            if not calls:
                continue
            effective = sum(2 if lp else 1 for _, lp in calls)
            if effective < 2:
                continue
            calls.sort(key=lambda c: (c[0].lineno, c[0].col_offset))
            for i, (call, lp) in enumerate(calls):
                first_single = (i == 0 and not lp)
                if first_single or _guarded(ctx, call, fn):
                    continue
                yield ctx.finding(
                    call, self.code,
                    f"`{fn.name}` acquires pages via "
                    f"`{dotted_name(call.func)}` "
                    + ("inside a loop " if lp else "after earlier "
                       "acquisitions ")
                    + "with no enclosing try/rollback-decref — an "
                    "OutOfPagesError here leaks the references already "
                    "taken (required shape: PrefixCache.admit)")
                break  # one finding per function keeps the signal readable
