"""REP004 — identity-based queue membership for dataclasses.

PR 4's lane-packer bug: ``ChunkedPrefillState`` carried the generated
dataclass ``__eq__``, so ``in``/``.remove`` on the admission queue
confused two requests that happened to share a prompt — the fix was
``@dataclasses.dataclass(eq=False)`` (identity equality). Scheduler and
engine queues hold *requests*, not values: two states are never
interchangeable just because their fields compare equal (and value-eq
on fields holding jax arrays can even raise on truthiness).

The rule cross-references, project-wide:

  * dataclass definitions that keep the generated ``__eq__`` (no
    ``eq=False``, no hand-written ``__eq__``) — collected by the
    framework's ``ProjectContext`` pre-pass so imported classes resolve;
  * container attributes/params annotated ``List[T]`` / ``Deque[T]`` /
    ``Sequence[T]`` (including string annotations);
  * membership (``x in self.queue``) or removal (``self.queue.remove(x)``)
    on those containers.

A finding fires at the usage site when ``T`` is a generated-``__eq__``
dataclass. Declare ``eq=False`` on the class (one finding per
(container, function) pair).
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, Optional

from ..framework import (FileContext, Finding, ProjectContext, Rule,
                         dotted_name, register)

_CONTAINERS = ("List", "list", "Deque", "deque", "Sequence",
               "MutableSequence", "Set", "set")


def _element_type(annotation: ast.expr) -> Optional[str]:
    """T from ``List[T]``-shaped annotations (string annotations too)."""
    if isinstance(annotation, ast.Constant) and \
            isinstance(annotation.value, str):
        try:
            annotation = ast.parse(annotation.value, mode="eval").body
        except SyntaxError:
            return None
    if not isinstance(annotation, ast.Subscript):
        return None
    base = dotted_name(annotation.value).rsplit(".", 1)[-1]
    if base not in _CONTAINERS:
        return None
    inner = annotation.slice
    if isinstance(inner, ast.Constant) and isinstance(inner.value, str):
        return inner.value
    name = dotted_name(inner)
    return name.rsplit(".", 1)[-1] if name else None


def _collect_container_types(ctx: FileContext) -> Dict[str, str]:
    """attr/param last-name -> element type name, from annotations."""
    out: Dict[str, str] = {}
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.AnnAssign):
            el = _element_type(node.annotation)
            tgt = node.target
            name = (tgt.id if isinstance(tgt, ast.Name)
                    else tgt.attr if isinstance(tgt, ast.Attribute)
                    else None)
            if el and name:
                out[name] = el
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for arg in node.args.args + node.args.kwonlyargs:
                if arg.annotation is not None:
                    el = _element_type(arg.annotation)
                    if el:
                        out[arg.arg] = el
    return out


def _container_name(node: ast.expr) -> Optional[str]:
    """Last name of a container expression (``self.prefilling`` ->
    "prefilling")."""
    name = dotted_name(node)
    return name.rsplit(".", 1)[-1] if name else None


@register
class QueueIdentityRule(Rule):
    code = "REP004"
    name = "queue-identity"
    summary = ("`in`/.remove on queues of dataclasses with generated "
               "__eq__ — declare eq=False so equal-valued requests can't "
               "be confused")

    def check(self, ctx: FileContext,
              project: ProjectContext) -> Iterator[Finding]:
        containers = _collect_container_types(ctx)
        reported = set()

        def maybe_finding(node: ast.AST, cname: Optional[str]
                          ) -> Optional[Finding]:
            if cname is None:
                return None
            el = containers.get(cname)
            if el is None:
                return None
            info = project.dataclasses.get(el)
            if info is None or info.identity_eq:
                return None
            key = (cname, ctx.qualname(node))
            if key in reported:
                return None
            reported.add(key)
            return ctx.finding(
                node, self.code,
                f"membership/remove on `{cname}` holding dataclass "
                f"`{el}` ({info.path}:{info.line}) with generated "
                "__eq__ — two equal-valued instances alias; declare "
                "@dataclass(eq=False) for identity semantics")

        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Compare) and any(
                    isinstance(op, (ast.In, ast.NotIn)) for op in node.ops):
                f = maybe_finding(node,
                                  _container_name(node.comparators[-1]))
                if f:
                    yield f
            elif isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Attribute) and \
                    node.func.attr in ("remove", "index", "count"):
                f = maybe_finding(node, _container_name(node.func.value))
                if f:
                    yield f
