"""reprolint core: rule registry, AST pipeline, suppressions, baseline.

The serving stack's correctness rests on invariants no type checker sees:
page refcount conservation under error paths, O(buckets x lane-configs)
compile counts, OOB-sentinel discipline inside Pallas index maps,
identity-based queue membership. Three of the last six PRs fixed exactly
these recurring bug classes by hand (see docs/analysis.md for the
rule-by-rule history); this module is the machinery that checks them on
every run:

  * ``Rule`` subclasses register themselves via ``@register`` at import
    time (``tools.reprolint.rules`` imports every rule module for the
    side effect); each declares a code (``REP0xx``), a one-line summary
    and an optional path filter, and yields ``Finding``s from its
    ``check``.
  * ``FileContext`` wraps one parsed file: source lines, AST, a
    parent/qualname map (so findings can name their enclosing function —
    the line-number-independent baseline key), and the inline
    suppressions (``# reprolint: disable=REP0xx``).
  * ``ProjectContext`` is the cross-file pre-pass: today it carries the
    project-wide dataclass registry (name -> eq semantics) that
    REP004 resolves imported queue element types against.
  * ``Baseline`` grandfathers intentional findings: entries are
    ``path::RULE::qualname`` (line numbers shift; enclosing symbols
    rarely do), counted as a multiset so a *second* finding of the same
    shape in the same function still fails the build. Every committed
    entry carries a one-line justification after ``#``.

Exact-finding fixtures live in ``tests/reprolint_fixtures/`` and
``tests/test_reprolint.py`` pins each rule's positive/negative behavior.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import re
import subprocess
from collections import Counter
from pathlib import Path
from typing import (Dict, Iterable, Iterator, List, Optional, Sequence, Set,
                    Tuple)

#: directory-name fragments never scanned unless --no-default-excludes:
#: the lint fixtures are *deliberate* violations.
DEFAULT_EXCLUDES = ("reprolint_fixtures", ".git", "__pycache__")

_SUPPRESS_RE = re.compile(r"#\s*reprolint:\s*disable=([A-Za-z0-9_,\s]+)")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a specific line.

    ``symbol`` is the enclosing function/class qualname ("<module>" at
    top level) — together with path and rule code it forms the baseline
    key, which survives unrelated line-number churn.

    ``tools.stepcheck`` reuses this record for trace-level findings:
    there ``path`` is an analysis *target* (an engine family or kernel
    name, not a file) and ``line`` is 0, which renders without the
    ``:line`` suffix — same baseline machinery, same JSON shape.
    """
    path: str            # repo-relative posix path (or stepcheck target)
    line: int
    rule: str            # "REP002"
    message: str
    symbol: str = "<module>"

    @property
    def baseline_key(self) -> str:
        return f"{self.path}::{self.rule}::{self.symbol}"

    def render(self) -> str:
        loc = self.path if self.line == 0 else f"{self.path}:{self.line}"
        return f"{loc} · {self.rule} · {self.message}"

    def to_json(self) -> Dict[str, object]:
        return {"path": self.path, "line": self.line, "rule": self.rule,
                "symbol": self.symbol, "message": self.message}


class FileContext:
    """One parsed source file plus the per-line lint metadata rules need."""

    def __init__(self, path: str, source: str, tree: ast.Module):
        self.path = path
        self.source = source
        self.lines = source.splitlines()
        self.tree = tree
        self._parents: Dict[ast.AST, ast.AST] = {}
        self._qualnames: Dict[ast.AST, str] = {}
        self._suppressed: Dict[int, set] = {}
        self._index()

    def _index(self) -> None:
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                self._parents[child] = node
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                parts = [node.name]
                cur = self._parents.get(node)
                while cur is not None:
                    if isinstance(cur, (ast.FunctionDef,
                                        ast.AsyncFunctionDef, ast.ClassDef)):
                        parts.append(cur.name)
                    cur = self._parents.get(cur)
                self._qualnames[node] = ".".join(reversed(parts))
        for lineno, text in enumerate(self.lines, start=1):
            m = _SUPPRESS_RE.search(text)
            if m:
                codes = {c.strip().upper() for c in m.group(1).split(",")
                         if c.strip()}
                self._suppressed[lineno] = codes

    # ------------------------------------------------------------- helpers
    def parent(self, node: ast.AST) -> Optional[ast.AST]:
        return self._parents.get(node)

    def ancestors(self, node: ast.AST) -> Iterator[ast.AST]:
        """Walk from ``node``'s parent up to the module root."""
        cur = self._parents.get(node)
        while cur is not None:
            yield cur
            cur = self._parents.get(cur)

    def enclosing_function(self, node: ast.AST
                           ) -> Optional[ast.FunctionDef]:
        for anc in self.ancestors(node):
            if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return anc
        return None

    def qualname(self, node: ast.AST) -> str:
        """Qualname of the innermost function/class enclosing ``node``
        (or of ``node`` itself when it is a def)."""
        if node in self._qualnames:
            return self._qualnames[node]
        for anc in self.ancestors(node):
            if anc in self._qualnames:
                return self._qualnames[anc]
        return "<module>"

    def is_suppressed(self, finding: Finding) -> bool:
        codes = self._suppressed.get(finding.line)
        if codes is None:
            return False
        return finding.rule in codes or "ALL" in codes

    def finding(self, node: ast.AST, rule: str, message: str) -> Finding:
        return Finding(path=self.path, line=getattr(node, "lineno", 1),
                       rule=rule, message=message,
                       symbol=self.qualname(node))


@dataclasses.dataclass
class DataclassInfo:
    """Cross-file record of one ``@dataclass`` definition (REP004)."""
    name: str
    path: str
    line: int
    identity_eq: bool      # eq=False (or frozen custom __eq__) declared


class ProjectContext:
    """Cross-file pre-pass state shared by every rule invocation."""

    def __init__(self, files: Sequence[FileContext]):
        self.files = list(files)
        self.dataclasses: Dict[str, DataclassInfo] = {}
        for ctx in self.files:
            self._collect_dataclasses(ctx)

    def _collect_dataclasses(self, ctx: FileContext) -> None:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            deco = _dataclass_decorator(node)
            if deco is None:
                continue
            identity = _dataclass_opts_out_of_eq(deco) or any(
                isinstance(b, (ast.FunctionDef, ast.AsyncFunctionDef))
                and b.name == "__eq__" for b in node.body)
            # last definition wins on bare-name collisions; the repo has
            # none today and fixtures never collide with src names
            self.dataclasses[node.name] = DataclassInfo(
                name=node.name, path=ctx.path, line=node.lineno,
                identity_eq=identity)


def _dataclass_decorator(node: ast.ClassDef) -> Optional[ast.expr]:
    for deco in node.decorator_list:
        target = deco.func if isinstance(deco, ast.Call) else deco
        if dotted_name(target) in ("dataclass", "dataclasses.dataclass"):
            return deco
    return None


def _dataclass_opts_out_of_eq(deco: ast.expr) -> bool:
    if not isinstance(deco, ast.Call):
        return False
    for kw in deco.keywords:
        if kw.arg == "eq" and isinstance(kw.value, ast.Constant):
            return kw.value.value is False
    return False


def dotted_name(node: ast.AST) -> str:
    """Best-effort dotted name of an expression (``self.allocator.alloc``
    -> "self.allocator.alloc"); "" for non-name shapes."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted_name(node.value)
        return f"{base}.{node.attr}" if base else node.attr
    return ""


# ---------------------------------------------------------------- registry
class Rule:
    """Base class: subclass, set ``code``/``summary``, implement ``check``.

    ``path_filter`` is a tuple of substrings — the rule only runs on
    files whose repo-relative posix path contains one of them (empty =
    every file). Substring (not glob) keeps filters obvious in docs.
    """
    code = "REP000"
    name = "unnamed"
    summary = ""
    path_filter: Tuple[str, ...] = ()

    def applies(self, path: str) -> bool:
        if not self.path_filter:
            return True
        return any(part in path for part in self.path_filter)

    def check(self, ctx: FileContext,
              project: ProjectContext) -> Iterator[Finding]:
        raise NotImplementedError
        yield  # pragma: no cover


REGISTRY: Dict[str, Rule] = {}


def register(cls: type) -> type:
    """Class decorator: instantiate and register a rule by its code."""
    rule = cls()
    if rule.code in REGISTRY:
        raise ValueError(f"duplicate rule code {rule.code}")
    REGISTRY[rule.code] = rule
    return cls


def all_rules() -> List[Rule]:
    # ensure the bundled rules are imported (registration side effect)
    from . import rules  # noqa: F401
    return [REGISTRY[c] for c in sorted(REGISTRY)]


# ---------------------------------------------------------------- baseline
class Baseline:
    """Grandfathered findings: ``path::RULE::symbol  # justification``
    lines, matched as a multiset (a second same-shaped finding in the
    same function is NEW and fails)."""

    def __init__(self, counts: Optional[Counter] = None):
        self.counts: Counter = counts or Counter()

    @classmethod
    def load(cls, path: Path) -> "Baseline":
        counts: Counter = Counter()
        if path.exists():
            for raw in path.read_text(encoding="utf-8").splitlines():
                entry = raw.split("#", 1)[0].strip()
                if entry:
                    counts[entry] += 1
        return cls(counts)

    def partition(self, findings: Sequence[Finding]
                  ) -> Tuple[List[Finding], List[Finding]]:
        """Split findings into (grandfathered, new)."""
        remaining = Counter(self.counts)
        old: List[Finding] = []
        new: List[Finding] = []
        for f in findings:
            if remaining[f.baseline_key] > 0:
                remaining[f.baseline_key] -= 1
                old.append(f)
            else:
                new.append(f)
        return old, new

    @staticmethod
    def render(findings: Sequence[Finding]) -> str:
        lines = ["# reprolint baseline — grandfathered findings.",
                 "# Format: path::RULE::symbol  # one-line justification",
                 "# New findings (not listed here) fail the build.", ""]
        for f in sorted(findings, key=lambda f: f.baseline_key):
            lines.append(f"{f.baseline_key}  # TODO justify")
        return "\n".join(lines) + "\n"


# ------------------------------------------------------------------ runner
def repo_root() -> Path:
    """The directory that contains ``tools/`` (the lint run's path base)."""
    return Path(__file__).resolve().parents[2]


def collect_files(paths: Sequence[str],
                  excludes: Tuple[str, ...] = DEFAULT_EXCLUDES,
                  only: Optional[Set[Path]] = None) -> List[Path]:
    out: List[Path] = []
    for p in paths:
        path = Path(p)
        if path.is_dir():
            out.extend(sorted(path.rglob("*.py")))
        elif path.suffix == ".py":
            out.append(path)
    root = repo_root()
    uniq: List[Path] = []
    seen = set()
    for f in out:
        f = f.resolve()
        rel = relpath(f, root)
        if any(part in rel for part in excludes):
            continue
        if only is not None and f not in only:
            continue
        if f not in seen:
            seen.add(f)
            uniq.append(f)
    return uniq


def changed_files(ref: str) -> Set[Path]:
    """Files changed vs ``ref`` (``git diff --name-only``) plus untracked
    files, as resolved absolute paths — the ``--changed-only`` universe.
    Raises ``RuntimeError`` when the ref does not resolve."""
    root = repo_root()
    diff = subprocess.run(
        ["git", "diff", "--name-only", ref], cwd=root,
        capture_output=True, text=True)
    if diff.returncode != 0:
        raise RuntimeError(
            f"git diff --name-only {ref!r} failed: "
            f"{diff.stderr.strip() or diff.stdout.strip()}")
    untracked = subprocess.run(
        ["git", "ls-files", "--others", "--exclude-standard"], cwd=root,
        capture_output=True, text=True)
    names = diff.stdout.splitlines() + (
        untracked.stdout.splitlines() if untracked.returncode == 0 else [])
    return {(root / name).resolve() for name in names if name.strip()}


def relpath(path: Path, root: Path) -> str:
    try:
        return path.resolve().relative_to(root).as_posix()
    except ValueError:
        return path.as_posix()


def parse_files(files: Sequence[Path]
                ) -> Tuple[List[FileContext], List[Finding]]:
    """Parse every file; syntax errors become REP000 findings (a file the
    linter cannot read is a finding, not a crash)."""
    root = repo_root()
    contexts: List[FileContext] = []
    errors: List[Finding] = []
    for f in files:
        rel = relpath(f, root)
        try:
            source = f.read_text(encoding="utf-8")
            tree = ast.parse(source, filename=str(f))
        except (SyntaxError, UnicodeDecodeError) as e:
            line = getattr(e, "lineno", 1) or 1
            errors.append(Finding(path=rel, line=line, rule="REP000",
                                  message=f"file does not parse: {e.msg if hasattr(e, 'msg') else e}",
                                  symbol="<module>"))
            continue
        contexts.append(FileContext(rel, source, tree))
    return contexts, errors


def run_paths(paths: Sequence[str],
              excludes: Tuple[str, ...] = DEFAULT_EXCLUDES,
              rules: Optional[Iterable[Rule]] = None,
              only: Optional[Set[Path]] = None) -> List[Finding]:
    """Lint ``paths`` (files or directory trees) and return every
    non-suppressed finding, sorted by (path, line, rule). ``only``
    restricts the collected files to that set (``--changed-only``)."""
    files = collect_files(paths, excludes, only=only)
    contexts, findings = parse_files(files)
    project = ProjectContext(contexts)
    active = list(rules) if rules is not None else all_rules()
    for ctx in contexts:
        for rule in active:
            if not rule.applies(ctx.path):
                continue
            for f in rule.check(ctx, project):
                if not ctx.is_suppressed(f):
                    findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def render_json(findings: Sequence[Finding], new: Sequence[Finding]
                ) -> str:
    new_keys = {id(f) for f in new}
    return json.dumps({
        "findings": [dict(f.to_json(), new=(id(f) in new_keys))
                     for f in findings],
        "total": len(findings),
        "new": len(new),
        "baselined": len(findings) - len(new),
    }, indent=2)
